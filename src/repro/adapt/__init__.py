from repro.adapt.knobs import LayoutPlan
from repro.adapt.search import LayoutReoptimizer
