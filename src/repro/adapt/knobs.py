"""Plane B plan space: execution-layout knobs for one (arch x shape x mesh)
cell — the distributed-training analogue of AQORA's action space
(DESIGN.md §2, Plane B mapping table):

  attn_mode      "seq" / "heads" / "none"   ~ join-order choice (which axis
                                              the expensive operator shards)
  remat          "full" / "dots" / "none"   ~ materialize-vs-recompute, the
                                              engine's cache/pipeline choice
  ce_chunk       16k..256k                  ~ partition-size tuning
  grad_compress  int8 DP reduction          ~ shuffle compression

Each knob flip is an incremental plan modification from a working baseline
(never a from-scratch plan), evaluated by re-lowering — the same
"constrained action space + stage-level feedback" shape as the paper.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayoutPlan:
    attn_mode: str = "seq"            # seq | heads | none
    remat: str = "full"               # full | dots | none
    ce_chunk: Optional[int] = None    # None -> lm.CE_CHUNK default (65536)
    grad_compress: bool = False
    attn_remat: bool = False          # flash-bwd: recompute probs in bwd
    mla_absorb: bool = False          # MLA decode: absorbed projections
    attn_scores_bf16: bool = False    # bf16 score/prob HBM traffic
    moe_dispatch: str = "global"      # global | local (block-local scatter)
    kv_seq_shard: bool = False        # decode cache: shard KV seq axis over
                                      # model (flash-decoding) vs head_dim

    def name(self) -> str:
        return (f"attn={self.attn_mode},remat={self.remat},"
                f"ce={self.ce_chunk or 'dflt'},"
                f"gc={'1' if self.grad_compress else '0'},"
                f"ar={'1' if self.attn_remat else '0'},"
                f"ab={'1' if self.mla_absorb else '0'},"
                f"s16={'1' if self.attn_scores_bf16 else '0'},"
                f"moe={self.moe_dispatch},"
                f"kvs={'1' if self.kv_seq_shard else '0'}")

    def neighbors(self, kind: str) -> Iterator["LayoutPlan"]:
        """One-knob flips (the constrained action space)."""
        for m in ("seq", "heads", "none"):
            if m != self.attn_mode:
                yield dataclasses.replace(self, attn_mode=m)
        if kind == "train":
            for r in ("full", "dots"):
                if r != self.remat:
                    yield dataclasses.replace(self, remat=r)
            for c in (16384, 65536, 262144):
                if c != (self.ce_chunk or 65536):
                    yield dataclasses.replace(self, ce_chunk=c)
            yield dataclasses.replace(self, grad_compress=not self.grad_compress)
            yield dataclasses.replace(self, attn_remat=not self.attn_remat)
            yield dataclasses.replace(self,
                                      attn_scores_bf16=not self.attn_scores_bf16)
            yield dataclasses.replace(
                self, moe_dispatch="local" if self.moe_dispatch == "global"
                else "global")
        if kind == "decode":
            yield dataclasses.replace(self, mla_absorb=not self.mla_absorb)
            yield dataclasses.replace(self, kv_seq_shard=not self.kv_seq_shard)


BASELINE = LayoutPlan()
