"""Plane B re-optimizer: hypothesis -> re-lower -> measure -> keep/revert.

This is AQORA's loop transplanted to distributed execution plans: start
from a working baseline layout, propose one-knob modifications, predict the
roofline-term delta with napkin math (`predict_delta`), evaluate the most
promising flip by actually re-lowering the cell (the "stage feedback" —
per-term compiled costs), keep it if the dominant term improved, and log
every hypothesis with its confirmation/refutation. EXPERIMENTS.md §Perf is
generated from these logs.

The analytic predictor doubles as the fast environment for the PPO-driven
variant (examples/adaptive_layout.py): with compiles costing minutes on
this container, the RL agent trains against `predict_delta` and the final
policy's choice is validated by one real lowering — the same
"learn from cheap stage feedback, commit refinements to the real engine"
split the paper uses.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, List, Optional, Tuple

from repro.adapt.knobs import BASELINE, LayoutPlan


@dataclasses.dataclass
class IterationLog:
    iteration: int
    hypothesis: str
    layout: str
    predicted: Dict[str, float]
    before: Dict[str, float]
    after: Optional[Dict[str, float]]
    verdict: str                       # confirmed | refuted | rejected


def _terms(rec: dict) -> Dict[str, float]:
    r = rec["roofline"]
    return {"compute": r["t_compute_s"], "memory": r["t_memory_s"],
            "collective": r["t_collective_s"], "bound": r["t_bound_s"],
            "bottleneck": r["bottleneck"], "mfu_bound": r["mfu_bound"]}


def predict_delta(cur: Dict[str, float], flip: LayoutPlan, base: LayoutPlan,
                  kind: str) -> Tuple[str, Dict[str, float]]:
    """Napkin-math hypothesis for one knob flip. Returns (text, predicted
    multiplier per term)."""
    pred = {"compute": 1.0, "memory": 1.0, "collective": 1.0}
    txt = []
    if flip.attn_mode != base.attn_mode:
        if flip.attn_mode == "heads":
            txt.append("head-TP removes the per-layer q/out seq all-gathers "
                       "(collective down) but pads K heads to tp (compute up "
                       "when K<tp)")
            pred["collective"] = 0.6
            pred["compute"] = 1.15
        elif flip.attn_mode == "seq":
            txt.append("seq-TP avoids head padding (compute down) at the "
                       "cost of k/v+out gathers (collective up)")
            pred["collective"] = 1.5
            pred["compute"] = 0.9
        else:
            txt.append("dp-only attention leaves GSPMD free: collective "
                       "down, memory up (unsharded scores)")
            pred["collective"] = 0.8
            pred["memory"] = 1.6
    if flip.remat != base.remat:
        if flip.remat == "dots":
            txt.append("checkpoint_dots keeps matmul outputs: recompute "
                       "flops -25% (8ND->6ND), HBM traffic +20-40%")
            pred["compute"] = 0.75
            pred["memory"] = 1.3
        else:
            txt.append("full remat: flops +33%, memory traffic down")
            pred["compute"] = 1.33
            pred["memory"] = 0.8
    if (flip.ce_chunk or 65536) != (base.ce_chunk or 65536):
        ratio = (flip.ce_chunk or 65536) / (base.ce_chunk or 65536)
        txt.append(f"CE chunk x{ratio:g}: fewer scan trips, logits live "
                   f"{'longer' if ratio > 1 else 'shorter'} (memory "
                   f"{'up' if ratio > 1 else 'down'} slightly)")
        pred["memory"] = 1.0 + 0.05 * (1 if ratio > 1 else -1)
    if flip.grad_compress != base.grad_compress:
        if flip.grad_compress:
            txt.append("int8 grad reduction: DP-reduce wire bytes /4, small "
                       "quantize compute overhead")
            pred["collective"] = 0.75
            pred["compute"] = 1.03
        else:
            pred["collective"] = 1.3
    if flip.attn_remat != base.attn_remat:
        if flip.attn_remat:
            txt.append("flash-bwd attention remat: per-block f32 prob/alpha "
                       "tensors (the dominant HBM traffic at 4k train) are "
                       "recomputed, not stored: memory down 2-4x on "
                       "attention, compute +~10% (extra QK pass)")
            pred["memory"] = 0.55
            pred["compute"] = 1.1
        else:
            pred["memory"] = 1.8
            pred["compute"] = 0.9
    if flip.attn_scores_bf16 != base.attn_scores_bf16:
        if flip.attn_scores_bf16:
            txt.append("bf16 score/prob tensors at HBM boundaries: the "
                       "dominant memory-traffic tensors halve; f32 softmax "
                       "math preserved inside fusions")
            pred["memory"] = 0.65
        else:
            pred["memory"] = 1.5
    if flip.moe_dispatch != base.moe_dispatch:
        if flip.moe_dispatch == "local":
            txt.append("block-local MoE dispatch: per-block capacity slices "
                       "make the scatter shard-local, replacing the partial-"
                       "buffer all-reduce (2.4 TB/dev on dbrx) with buffer "
                       "resharding; collective down sharply")
            pred["collective"] = 0.35
        else:
            pred["collective"] = 3.0
    if flip.kv_seq_shard != base.kv_seq_shard:
        if flip.kv_seq_shard:
            txt.append("flash-decoding KV layout: shard the cache SEQUENCE "
                       "axis over model instead of head_dim — head_dim is "
                       "contracted in QK^T, so sharding it all-reduces the "
                       "(B,H,1,S) scores every layer; seq sharding exchanges "
                       "only softmax stats")
            pred["collective"] = 0.3
        else:
            pred["collective"] = 3.0
    if flip.mla_absorb != base.mla_absorb:
        if flip.mla_absorb:
            txt.append("MLA absorbed decode: stop re-expanding the latent "
                       "cache through wkv_b every token — score against the "
                       "latent (~30x fewer decode FLOPs, expanded KV never "
                       "materializes: memory down)")
            pred["compute"] = 0.05
            pred["memory"] = 0.4
        else:
            pred["compute"] = 20.0
    return "; ".join(txt), pred


class LayoutReoptimizer:
    """Greedy one-flip hillclimber with hypothesis logging (§Perf engine)."""

    def __init__(self, arch: str, shape: str, multi_pod: bool = False,
                 out_dir="results/perf"):
        self.arch, self.shape, self.multi = arch, shape, multi_pod
        self.out = pathlib.Path(out_dir)
        self.out.mkdir(parents=True, exist_ok=True)
        self.logs: List[IterationLog] = []

    def evaluate(self, layout: LayoutPlan) -> dict:
        from repro.launch.dryrun import run_cell
        return run_cell(self.arch, self.shape, self.multi, verbose=False,
                        layout=layout)

    def climb(self, *, max_iters: int = 8, min_gain: float = 0.05,
              start: LayoutPlan = BASELINE, kind: str = "train",
              explore_slack: float = 1.15) -> Tuple[LayoutPlan, List[IterationLog]]:
        cur_layout = start
        cur_rec = self.evaluate(cur_layout)
        cur = _terms(cur_rec)
        self._dump(cur_layout, cur_rec, "baseline")
        tried = {cur_layout.name()}
        stall = 0
        for it in range(max_iters):
            # rank UNTRIED neighbors by predicted bound; a refuted hypothesis
            # is never retried (its measurement is already logged)
            cands = []
            for nb in cur_layout.neighbors(kind):
                if nb.name() in tried:
                    continue
                txt, pred = predict_delta(cur, nb, cur_layout, kind)
                terms = {k: cur[k] * pred[k]
                         for k in ("compute", "memory", "collective")}
                cands.append((max(terms.values()), nb, txt, pred))
            cands.sort(key=lambda c: c[0])
            # explore slightly-worse-predicted flips too: predictions are
            # napkin math and refutations are informative (see qwen3 it0)
            cands = [c for c in cands if c[0] < cur["bound"] * explore_slack]
            if not cands:
                self.logs.append(IterationLog(
                    it, "no untried flip predicted within slack of the "
                    "current bound", cur_layout.name(), {}, dict(cur), None,
                    "search exhausted"))
                break
            best_pred_bound, nb, txt, pred = cands[0]
            tried.add(nb.name())
            rec = self.evaluate(nb)
            after = _terms(rec)
            gain = (cur["bound"] - after["bound"]) / cur["bound"]
            confirmed = after["bound"] < cur["bound"]
            self.logs.append(IterationLog(
                it, txt, nb.name(), pred, dict(cur), dict(after),
                f"{'confirmed' if confirmed else 'refuted'} "
                f"(bound {cur['bound']:.3f}s -> {after['bound']:.3f}s, "
                f"{gain:+.1%})"))
            if confirmed:
                cur_layout, cur, cur_rec = nb, after, rec
                self._dump(cur_layout, cur_rec, f"iter{it}")
                stall = 0 if gain >= min_gain else stall + 1
            else:
                stall += 1
            if stall >= 3:
                break
        self._write_log()
        return cur_layout, self.logs

    def _dump(self, layout, rec, tag):
        name = f"{self.arch}__{self.shape}__{tag}.json"
        (self.out / name).write_text(json.dumps(
            {"layout": layout.name(), **rec}))

    def _write_log(self):
        name = f"{self.arch}__{self.shape}__log.json"
        (self.out / name).write_text(json.dumps(
            [dataclasses.asdict(l) for l in self.logs], indent=1))
