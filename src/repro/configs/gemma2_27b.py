"""gemma2-27b — dense, local/global alternating attention + logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000. [arXiv:2408.00118]
Pattern: (local sliding-window 4096, global full) repeated 23x.
Gemma quirks: (1+scale) RMSNorm, sandwich (pre+post) norms, embeddings
scaled by sqrt(d_model), attn softcap 50, final softcap 30, gelu MLP,
head_dim=128 (decoupled from d_model/n_heads), tied embeddings.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    window=4096,
    scale_emb=4608 ** 0.5,
    act="gelu",
    tie_embeddings=True,
    block_pattern=(LayerSpec(mixer="attn_local", ffn="mlp"),
                   LayerSpec(mixer="attn", ffn="mlp")),
)
