"""falcon-mamba-7b — attention-free Mamba-1 SSM.

64L d_model=4096 d_ff=0 (the Mamba block carries its own gated channel
mixing) vocab=65024, ssm_state=16. [arXiv:2410.05355]
Sub-quadratic: runs the long_500k cell (O(1) recurrent state per step).
"""
from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    use_rope=False,
    tie_embeddings=True,
    subquadratic=True,
    block_pattern=(LayerSpec(mixer="mamba", ffn="none"),),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)
