"""Model / run configuration system.

Every assigned architecture is a ``ModelConfig``; layer heterogeneity
(local/global alternation, cross-attention interleave, mamba:attn ratios,
MoE-every-other-layer) is expressed as a *superblock pattern*: the layer stack
is ``n_superblocks`` repetitions of ``block_pattern`` (a tuple of LayerSpec),
and parameters are stacked on a leading superblock axis so the whole stack
lowers as one ``lax.scan`` — keeping HLO size O(pattern) instead of O(layers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 16
    top_k: int = 2
    capacity_factor: float = 1.25
    d_ff_expert: int = 0          # 0 -> use cfg.d_ff
    shared_expert_ff: int = 0     # >0 -> add an always-on shared expert MLP
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside a superblock."""
    mixer: str = "attn"           # attn | attn_local | attn_chunked | attn_nope | cross_attn | mamba
    ffn: str = "mlp"              # mlp | moe | none


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). Frontend is a stub:
    inputs are precomputed frame/patch embeddings."""
    n_layers: int = 4
    n_frames: int = 1500          # fixed encoder sequence length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 524_288

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0      # 0 -> off (gemma2: 50.0)
    final_logit_softcap: float = 0.0     # 0 -> off (gemma2: 30.0)
    window: int = 4096                   # sliding window for attn_local
    chunk: int = 8192                    # chunk size for attn_chunked (llama4)
    rope_theta: float = 10_000.0
    use_rope: bool = True
    scale_emb: float = 1.0               # embedding multiplier (gemma: sqrt(d), minicpm: 12)
    scale_depth: float = 0.0             # residual scale = scale_depth/sqrt(n_layers) (minicpm; 0 -> 1.0)
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    act: str = "silu"                    # silu | gelu
    tie_embeddings: bool = False
    learned_pos_emb: bool = False        # whisper decoder
    max_decoder_len: int = 32_768        # learned-pos-emb table size

    # heterogeneity
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # sub-configs
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision_tokens: int = 0               # >0 -> VLM cross-attn memory length

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_moment_dtype: str = "float32"    # bf16 for the largest archs (jamba)

    # classification of sequence-mixing complexity (for long_500k gating)
    subquadratic: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern len {len(self.block_pattern)}")

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def d_inner(self) -> int:
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        assert self.ssm is not None
        return self.ssm.dt_rank or max(1, -(-self.d_model // 16))

    @property
    def moe_d_ff(self) -> int:
        assert self.moe is not None
        return self.moe.d_ff_expert or self.d_ff

    def memory_len(self) -> int:
        """Cross-attention memory length (vision tokens or encoder frames)."""
        if self.encoder is not None:
            return self.encoder.n_frames
        return self.vision_tokens

    def encoder_cfg(self) -> "ModelConfig":
        """Derived config for the encoder stack of enc-dec models."""
        assert self.encoder is not None
        return dataclasses.replace(
            self, name=self.name + "-enc", n_layers=self.encoder.n_layers,
            block_pattern=(LayerSpec(mixer="attn_bidir", ffn="mlp"),),
            encoder=None, use_rope=False, learned_pos_emb=False)

    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; used for 6ND)."""
        import math
        from repro.models import lm
        import jax
        shapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), self))
        return sum(math.prod(x.shape) for x in jax.tree_util.tree_leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        total = self.param_count()
        if self.moe is None:
            return total
        # subtract inactive expert params
        n_moe_layers = self.n_superblocks * sum(1 for s in self.block_pattern if s.ffn == "moe")
        per_expert = 3 * self.d_model * self.moe_d_ff  # gate/up/down
        inactive = n_moe_layers * (self.moe.n_experts - self.moe.top_k) * per_expert
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell is assigned to run. Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure/partial full-attention arch (quadratic); see DESIGN.md"
    return True, ""
