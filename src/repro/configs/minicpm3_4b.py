"""minicpm3-4b — dense, Multi-head Latent Attention (MLA).

62L d_model=2560 40H d_ff=6400 vocab=73448. [hf:openbmb/MiniCPM3-4B]
MiniCPM-specific scaling: embeddings x12, residual branches x(1.4/sqrt(L)).
MLA dims follow the HF config (q_lora 768, kv_lora 256, nope 64 + rope 32,
v_head 64); the decode cache stores the *latent* (kv_lora + k_rope) only.
"""
from repro.configs.base import LayerSpec, MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    scale_emb=12.0,
    scale_depth=1.4,
    rope_theta=10_000.0,
    tie_embeddings=True,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
)
