"""qwen1.5-4b — dense MHA with QKV bias.

40L d_model=2560 20H (kv=20, i.e. full MHA) d_ff=6912 vocab=151936.
[hf:Qwen/Qwen1.5-0.5B family] head_dim = 2560/20 = 128.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
)
