"""llama-3.2-vision-90b — VLM: decoder with gated cross-attn image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision scaled] Every 5th layer is a tanh-gated
cross-attention layer over precomputed vision patch embeddings (the vision
tower is a stub per the assignment: input_specs() supplies (B, 1600, D)
patch embeddings). Pattern: 4 self-attn + 1 cross-attn, repeated 20x.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    vision_tokens=1600,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),
                   LayerSpec(mixer="attn", ffn="mlp"),
                   LayerSpec(mixer="attn", ffn="mlp"),
                   LayerSpec(mixer="attn", ffn="mlp"),
                   LayerSpec(mixer="cross_attn", ffn="mlp")),
)
