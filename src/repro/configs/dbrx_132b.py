"""dbrx-132b — fine-grained MoE, 16 experts top-4 in every layer.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.
[hf:databricks/dbrx-base] head_dim=128.
"""
from repro.configs.base import LayerSpec, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
)
