"""Architecture registry: ``get_config(arch)``, ``reduced(cfg)`` smoke
variants, and the assigned (arch x shape) cell enumeration."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.configs.base import (EncoderConfig, MLAConfig, ModelConfig,
                                MoEConfig, SHAPES, SSMConfig, ShapeConfig,
                                shape_applicable)

_MODULES = {
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "llama-3.2-vision-90b": "repro.configs.llama32_vision_90b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "jamba-1.5-large-398b": "repro.configs.jamba_15_large",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same family/quirks, toy dims: used by CPU smoke tests. Keeps the
    block pattern (so heterogeneity is exercised) but only 2 superblocks."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=2 * len(cfg.block_pattern),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab_size=512,
        window=64,
        chunk=64,
        vision_tokens=16 if cfg.vision_tokens else 0,
        max_decoder_len=256,
        scale_emb=(128 ** 0.5) if cfg.name.startswith("gemma") else cfg.scale_emb,
    )
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = 4
    else:
        kw["n_kv_heads"] = 2
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(n_experts=4, top_k=min(cfg.moe.top_k, 2),
                              d_ff_expert=128,
                              shared_expert_ff=128 if cfg.moe.shared_expert_ff else 0)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, n_frames=32)
    return dataclasses.replace(cfg, **kw)


def assigned_cells() -> List[Tuple[str, str, bool, str]]:
    """All 40 (arch, shape) cells -> (arch, shape, runs, skip_reason)."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            out.append((arch, shape.name, ok, why))
    return out
