"""qwen3-8b — dense GQA with per-head QK-RMSNorm.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936. [hf:Qwen/Qwen3-8B]
head_dim=128; qk_norm applies RMSNorm to q and k per head before RoPE.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
)
