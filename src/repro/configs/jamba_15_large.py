"""jamba-1.5-large-398b — hybrid Mamba + attention (1:7) with MoE top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
[arXiv:2403.19887] Period-8 Jamba block: attention at index 4, Mamba
elsewhere; MoE replaces the MLP on every other layer (odd indices).
Jamba attention uses no positional embeddings (NoPE). head_dim=128.
Sub-quadratic overall: runs the long_500k cell (9 attn layers' KV + O(1)
Mamba state).
"""
from repro.configs.base import LayerSpec, MoEConfig, ModelConfig, SSMConfig


def _jamba_pattern():
    pat = []
    for i in range(8):
        mixer = "attn_nope" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "mlp"
        pat.append(LayerSpec(mixer=mixer, ffn=ffn))
    return tuple(pat)


CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    use_rope=False,
    subquadratic=True,
    # 398B params: fp32 params + fp32 moments = 18.6 GB/chip > 16 GB HBM on
    # the 256-chip pod; bf16 params + bf16 moments = 9.3 GB/chip (DESIGN §5).
    param_dtype="bfloat16",
    opt_moment_dtype="bfloat16",
    block_pattern=_jamba_pattern(),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)
