"""whisper-tiny — encoder-decoder audio model (conv/mel frontend stubbed).

4 logical decoder layers, d_model=384 6H d_ff=1536 vocab=51865.
[arXiv:2212.04356] Each logical decoder layer = self-attn + cross-attn + MLP,
expressed here as TWO LayerSpec entries (self-attn with no FFN, then
cross-attn with the MLP), so n_layers=8 pattern entries == 4 logical layers.
Encoder: 4 bidirectional layers over 1500 precomputed frame embeddings
(the mel-spectrogram conv frontend is a stub per the assignment:
input_specs() supplies the (B, 1500, 384) frame embeddings directly).
LayerNorm + GELU + learned positional embeddings, no RoPE.
"""
from repro.configs.base import EncoderConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=8,                       # 2 pattern entries x 4 logical layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    use_rope=False,
    learned_pos_emb=True,
    max_decoder_len=32_768,
    tie_embeddings=True,
    block_pattern=(LayerSpec(mixer="attn", ffn="none"),
                   LayerSpec(mixer="cross_attn", ffn="mlp")),
    encoder=EncoderConfig(n_layers=4, n_frames=1500),
)
