"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert,
chunked-local attention with NoPE global layers.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E] Pattern: 3 chunked-local (8192-token
chunks, RoPE) + 1 global NoPE layer; every FFN is MoE(16, top-1) plus an
always-on shared expert of the same width. "Early fusion" multimodality is
out of scope for the LM backbone (text tokens only), per the assignment.
"""
from repro.configs.base import LayerSpec, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    chunk=8192,
    block_pattern=(LayerSpec(mixer="attn_chunked", ffn="moe"),
                   LayerSpec(mixer="attn_chunked", ffn="moe"),
                   LayerSpec(mixer="attn_chunked", ffn="moe"),
                   LayerSpec(mixer="attn_nope", ffn="moe")),
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                  shared_expert_ff=8192),
)
