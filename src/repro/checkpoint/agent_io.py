"""One serialization path for agent parameters.

`agent_state` flattens an AQORA-style agent (actor/critic pytrees plus
both AdamW states) into a single pytree that `Checkpointer` can commit
atomically; `install_agent_state` puts such a tree back onto a live agent.
Both the offline trainer (`examples/train_aqora.py --resume`) and the
online `learn.PolicyStore` (versioned hot-swap / rollback) go through
these two functions, so a checkpoint written by either side restores on
the other.

`install_agent_state` deep-copies by default: the online learner's PPO
update donates its param/optimizer buffers to XLA, so the serving agent
must never alias arrays the learner may later donate — a shared buffer
would be invalidated under the serving agent mid-stream.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def agent_state(agent) -> Dict:
    """The agent's full learnable state as one pytree (no copies)."""
    return {"actor": agent.actor, "critic": agent.critic,
            "aopt": agent.aopt, "copt": agent.copt}


def copy_tree(tree):
    """Deep-copy every leaf (host round-trip: safe against donation)."""
    return jax.tree_util.tree_map(lambda x: jnp.asarray(np.array(x)), tree)


def install_agent_state(agent, tree: Dict, copy: bool = True) -> None:
    """Put `tree` (from `agent_state` or a Checkpointer restore) onto
    `agent`. With copy=True (default) leaves are deep-copied so the source
    and target never alias device buffers."""
    if copy:
        tree = copy_tree(tree)
    agent.actor, agent.critic = tree["actor"], tree["critic"]
    agent.aopt, agent.copt = tree["aopt"], tree["copt"]


def params_finite(agent) -> bool:
    """Cheap sanity gate: every actor/critic leaf is finite."""
    for leaf in jax.tree_util.tree_leaves((agent.actor, agent.critic)):
        if not bool(np.isfinite(np.asarray(leaf)).all()):
            return False
    return True
