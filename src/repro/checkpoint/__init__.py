from repro.checkpoint.agent_io import (agent_state, copy_tree,
                                       install_agent_state, params_finite)
from repro.checkpoint.checkpointer import Checkpointer

__all__ = ["Checkpointer", "agent_state", "copy_tree",
           "install_agent_state", "params_finite"]
