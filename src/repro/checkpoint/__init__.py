from repro.checkpoint.checkpointer import Checkpointer
