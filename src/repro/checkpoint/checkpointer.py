"""Step-atomic checkpointing with manifest fencing + async writes.

Fault-tolerance contract (what a 1000-node run needs from its store):

  * Atomicity: data files are written first, the manifest LAST (with sizes
    + checksums). A checkpoint without a valid manifest does not exist —
    a host dying mid-write can never corrupt restore.
  * Async: `save(..., blocking=False)` snapshots to host memory
    synchronously (cheap np.asarray copies) and writes in a background
    thread, overlapping the next training steps.
  * Restore picks the newest VALID manifest and verifies checksums, so a
    torn write falls back to the previous step automatically.
  * Retention: keep_last prunes old steps (keeping the newest valid ones).

Arrays are stored as raw .npy per leaf (path-encoded); pytree structure
and metadata (data-pipeline state, step, mesh shape) live in the manifest.
On a real multi-host cluster each host writes only its addressable shards;
on this single-host container that is the whole (replicated) tree — the
pathing scheme (`leaf_path/shard0`) already carries the shard slot.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, np.ndarray]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, np.asarray(leaf)))
    return out


def _unflatten(tree_like, leaves: Dict[str, np.ndarray]):
    names = [n for n, _ in _flatten(tree_like)]
    flat, treedef = jax.tree_util.tree_flatten(tree_like)
    return treedef.unflatten([leaves[n] for n in names])


@dataclasses.dataclass
class _Pending:
    thread: threading.Thread
    step: int


class Checkpointer:
    def __init__(self, directory, keep_last: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._pending: Optional[_Pending] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None,
             blocking: bool = True) -> bool:
        """Returns True if the checkpoint was written (or enqueued),
        False if `step` already exists on disk and the save was skipped —
        callers reusing a directory must check or pick a fresh step."""
        self.wait()                                # never two writers racing
        if step in self.steps():
            return False                           # already committed
        leaves = _flatten(tree)                    # snapshot NOW (host copy)
        extra = dict(extra or {})

        def write():
            d = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_step_{step:08d}"
            tmp.mkdir(parents=True, exist_ok=True)
            manifest = {"step": step, "extra": extra, "arrays": {},
                        "time": time.time()}
            for name, arr in leaves:
                fn = name.replace("/", "__") + ".npy"
                np.save(tmp / fn, arr)
                manifest["arrays"][name] = {
                    "file": fn, "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha1": hashlib.sha1(arr.tobytes()).hexdigest(),
                }
            # manifest LAST = commit point
            (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
            tmp.rename(d)
            self._prune()

        if blocking:
            write()
        else:
            t = threading.Thread(target=write, daemon=True)
            t.start()
            self._pending = _Pending(t, step)
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.thread.join()
            self._pending = None

    def next_step(self, hint: int = 0) -> int:
        """Smallest step >= `hint` that is strictly newer than every step
        on disk or in flight — safe to save() (no silent skip-existing)
        and guaranteed to become the newest, so restore() picks it up."""
        pending = [self._pending.step + 1] if self._pending else []
        return max([hint] + pending + [s + 1 for s in self.steps()])

    # ------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        out = []
        for d in sorted(self.dir.glob("step_*")):
            if (d / "MANIFEST.json").exists():
                out.append(int(d.name.split("_")[1]))
        return out

    def restore(self, tree_like, step: Optional[int] = None,
                verify: bool = True):
        """Returns (tree, step, extra) from the newest valid checkpoint
        (or `step`). Raises FileNotFoundError if none exists."""
        cands = self.steps() if step is None else [step]
        for s in sorted(cands, reverse=True):
            d = self.dir / f"step_{s:08d}"
            try:
                manifest = json.loads((d / "MANIFEST.json").read_text())
                leaves = {}
                for name, meta in manifest["arrays"].items():
                    arr = np.load(d / meta["file"])
                    if verify:
                        if hashlib.sha1(arr.tobytes()).hexdigest() != meta["sha1"]:
                            raise IOError(f"checksum mismatch: {name}")
                    leaves[name] = arr
                return _unflatten(tree_like, leaves), s, manifest["extra"]
            except Exception:
                if step is not None:
                    raise
                continue                            # torn write: fall back
        raise FileNotFoundError(f"no valid checkpoint under {self.dir}")

    def _prune(self):
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            d = self.dir / f"step_{s:08d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()
