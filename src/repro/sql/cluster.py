"""Deterministic cluster latency model (the "6-executor Spark cluster").

The container is a single CPU core, so wall-clock Spark latencies cannot be
measured; instead every stage is charged against this calibrated model.
Magnitudes are chosen so the paper's phenomena reproduce at our data scale:
good plans run in seconds, bad join orders shuffle 10^7-row intermediates
into the minutes/OOM regime, broadcasting a large build side OOMs an
executor, and per-stage scheduling overhead makes extra shuffles visible.
EXPERIMENTS.md validates the paper's *relative* claims under this model.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    n_executors: int = 6
    executor_mem: float = 12e6         # bytes usable for a broadcast build
    bjt: float = 300e3                 # autoBroadcastJoinThreshold (bytes)
    scan_bw: float = 400e6             # bytes/s aggregate
    shuffle_bw: float = 100e6          # bytes/s aggregate (write+read)
    broadcast_bw: float = 150e6        # bytes/s (driver fan-out)
    cpu_rows_per_s: float = 25e6       # aggregate probe/merge throughput
    sort_factor: float = 1.6           # SMJ sort overhead multiplier
    stage_overhead: float = 0.25       # scheduler cost per stage (s)
    shuffle_partition_bytes: float = 16e6
    partition_overhead: float = 0.05   # per shuffle partition (s); AQE
    aqe_coalesce: bool = True          #   coalesces small partitions
    timeout: float = 300.0             # per-query cap (s), as in §VII-A4d
    materialize_cap: int = 10_000_000  # rows; beyond this the join OOMs
    # ---- failure pricing ---------------------------------------------------
    # "timeout": an OOM is charged the full per-query timeout (the PR-1..5
    #   pricing — the job burns its whole slot before anyone notices).
    # "detect": an OOM is charged at DETECTION time (virtual seconds elapsed
    #   when the executor died) plus `oom_spill_penalty` seconds of spill /
    #   teardown — the failure frees the lane when it actually happens,
    #   which is what makes retry ladders worth their backoff.
    # Injected faults ("crash"/"transient", see serve.recover.faults) are
    # always charged at detection time; a wall-clock "timeout" is always the
    # full timeout. Default preserves bit-identity with the legacy pricing.
    oom_charge: str = "timeout"        # "timeout" | "detect"
    oom_spill_penalty: float = 0.0     # extra seconds charged on detect OOM

    # ---- stage cost terms -------------------------------------------------
    def scan_time(self, bytes_: float) -> float:
        return bytes_ / self.scan_bw

    def shuffle_time(self, bytes_: float) -> float:
        nparts = max(1, int(bytes_ / self.shuffle_partition_bytes))
        if self.aqe_coalesce:
            nparts = min(nparts, 32)
        return bytes_ / self.shuffle_bw + nparts * self.partition_overhead

    def broadcast_time(self, build_bytes: float) -> float:
        return build_bytes * self.n_executors / self.broadcast_bw

    def smj_cpu(self, l_rows: float, r_rows: float, out_rows: float) -> float:
        return (self.sort_factor * (l_rows + r_rows) + out_rows) / self.cpu_rows_per_s

    def bhj_cpu(self, build_rows: float, probe_rows: float, out_rows: float) -> float:
        return (2.0 * build_rows + probe_rows + out_rows) / self.cpu_rows_per_s

    def broadcast_oom(self, build_bytes: float) -> bool:
        return build_bytes > self.executor_mem

    def failure_charge(self, kind: str, elapsed: float) -> float:
        """Virtual seconds a failed run occupies its lane. `elapsed` is the
        simulated time at which the failure was detected."""
        assert self.oom_charge in ("timeout", "detect"), self.oom_charge
        if kind == "timeout":
            return self.timeout
        if kind == "oom" and self.oom_charge == "timeout":
            return self.timeout
        extra = self.oom_spill_penalty if kind == "oom" else 0.0
        return min(self.timeout, elapsed + extra)
