"""Schema-faithful synthetic benchmark databases.

The offline container cannot ship IMDb/StackExchange Parquet dumps, so we
generate databases with the same *shape of hardness*: 21-table JOB-like and
10-table STACK-like schemas, Zipf-skewed foreign keys (breaks the CBO's
independence/uniformity assumptions), correlated predicates, and a fact
table with a `production_year` column so the paper's dynamic evaluation
(IMDb-1950 / IMDb-1980 -> full) filters apply (§VII-B5).

Scale is set so that plan-choice effects dominate: bad join orders produce
million-row intermediates (OOM/timeout territory under the cluster cost
model) while good orders stay in the thousands.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.sql.catalog import Database, Table, analyze


def _zipf_fk(rng, n, n_parent, a=0.8):
    """Foreign keys into 0..n_parent-1 with bounded power-law skew: parent k
    gets weight (k+1)^-a. With a=0.6 the hottest parent draws ~0.5% of all
    rows (popular-movie realism: hub joins blow up under bad orders but a
    good order still finishes), and hub identity is SHARED across fact
    tables referencing the same parent — the cross-table correlation that
    breaks the CBO's independence assumption."""
    w = (np.arange(1, n_parent + 1, dtype=np.float64)) ** (-a)
    w /= w.sum()
    return rng.choice(n_parent, size=n, p=w).astype(np.int64)


def _uniform_fk(rng, n, n_parent):
    return rng.integers(0, n_parent, size=n, dtype=np.int64)


def make_job_like(scale: float = 1.0, seed: int = 0,
                  year_max: Optional[int] = None) -> Database:
    """21-table IMDb-like star/snowflake schema. `year_max` filters the fact
    table (and cascades to FK tables) to build IMDb-1950/-1980 snapshots."""
    rng = np.random.default_rng(seed)
    S = lambda n: max(16, int(n * scale))

    n_title = S(60_000)
    years = rng.integers(1900, 2014, size=n_title).astype(np.int64)
    # correlated kind: newer movies skew to kinds 0/1
    kind = np.where(years > 1990, rng.integers(0, 3, n_title),
                    rng.integers(0, 7, n_title)).astype(np.int64)
    title = {"id": np.arange(n_title, dtype=np.int64),
             "kind_id": kind, "production_year": years}

    if year_max is not None:
        keep = years <= year_max
        title = {k: v[keep] for k, v in title.items()}
        # reindex ids compactly so FK generation stays dense
        old_ids = np.flatnonzero(keep)
        remap = -np.ones(n_title, np.int64)
        remap[old_ids] = np.arange(len(old_ids))
        n_title = len(old_ids)
        title["id"] = np.arange(n_title, dtype=np.int64)

    def fact(n, skew=True, extra=None):
        n = S(n) if year_max is None else max(16, int(S(n) * n_title / S(60_000)))
        cols = {"movie_id": (_zipf_fk(rng, n, n_title) if skew
                             else _uniform_fk(rng, n, n_title))}
        cols.update(extra(n) if extra else {})
        return cols

    n_name = S(40_000)
    n_company = S(3_000)
    n_keyword = S(8_000)

    tables = {
        "title": title,
        "movie_companies": fact(80_000, extra=lambda n: {
            "company_id": _zipf_fk(rng, n, n_company),
            "company_type_id": rng.integers(0, 4, n).astype(np.int64)}),
        "cast_info": fact(300_000, extra=lambda n: {
            "person_id": _zipf_fk(rng, n, n_name),
            "role_id": rng.integers(0, 12, n).astype(np.int64)}),
        "movie_info": fact(150_000, extra=lambda n: {
            "info_type_id": rng.integers(0, 110, n).astype(np.int64)}),
        "movie_info_idx": fact(40_000, extra=lambda n: {
            "info_type_id": rng.integers(0, 110, n).astype(np.int64)}),
        "movie_keyword": fact(120_000, extra=lambda n: {
            "keyword_id": _zipf_fk(rng, n, n_keyword)}),
        "aka_title": fact(10_000, skew=False),
        "complete_cast": fact(20_000, skew=False, extra=lambda n: {
            "subject_id": rng.integers(0, 4, n).astype(np.int64),
            "status_id": rng.integers(0, 4, n).astype(np.int64)}),
        "movie_link": fact(8_000, skew=False, extra=lambda n: {
            "link_type_id": rng.integers(0, 18, n).astype(np.int64),
            "linked_movie_id": _uniform_fk(rng, n, n_title)}),
        "name": {"id": np.arange(n_name, dtype=np.int64),
                 "gender": rng.integers(0, 3, n_name).astype(np.int64)},
        "aka_name": {"person_id": _zipf_fk(rng, S(15_000), n_name)},
        "person_info": {"person_id": _zipf_fk(rng, S(60_000), n_name),
                        "info_type_id": rng.integers(0, 40, S(60_000)).astype(np.int64)},
        "char_name": {"id": np.arange(S(20_000), dtype=np.int64)},
        "company_name": {"id": np.arange(n_company, dtype=np.int64),
                         "country_code": rng.integers(0, 60, n_company).astype(np.int64)},
        "company_type": {"id": np.arange(4, dtype=np.int64)},
        "info_type": {"id": np.arange(110, dtype=np.int64)},
        "keyword": {"id": np.arange(n_keyword, dtype=np.int64)},
        "kind_type": {"id": np.arange(7, dtype=np.int64)},
        "role_type": {"id": np.arange(12, dtype=np.int64)},
        "comp_cast_type": {"id": np.arange(4, dtype=np.int64)},
        "link_type": {"id": np.arange(18, dtype=np.int64)},
    }
    db = Database(name=f"job{'' if year_max is None else year_max}",
                  tables={k: Table(k, v) for k, v in tables.items()})
    db.stats = analyze(db, rng=np.random.default_rng(seed + 1))
    return db


def delta_rows(table: Table, n: int,
               rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Fresh rows for a delta-table append, shaped like the table's current
    contents: non-key columns are bootstrap-resampled from the existing
    rows (preserving the Zipf skew and keeping every FK pointing at a live
    parent), while dense `id` primary keys extend past the current max so
    appended dimension rows stay unique."""
    cols: Dict[str, np.ndarray] = {}
    for name, arr in table.columns.items():
        if name == "id":
            start = int(arr.max()) + 1 if len(arr) else 0
            cols[name] = np.arange(start, start + n, dtype=arr.dtype)
        elif len(arr):
            cols[name] = rng.choice(arr, size=n)
        else:
            cols[name] = np.zeros(n, arr.dtype)
    return cols


def make_stack_like(scale: float = 1.0, seed: int = 1) -> Database:
    """10-table StackExchange-like schema."""
    rng = np.random.default_rng(seed)
    S = lambda n: max(16, int(n * scale))
    n_site, n_user, n_q = 40, S(30_000), S(80_000)
    n_acc = S(25_000)
    n_tag = S(2_000)
    q_site = _zipf_fk(rng, n_q, n_site, a=1.2)
    tables = {
        "site": {"id": np.arange(n_site, dtype=np.int64)},
        "account": {"id": np.arange(n_acc, dtype=np.int64),
                    "website_kind": rng.integers(0, 5, n_acc).astype(np.int64)},
        "so_user": {"id": np.arange(n_user, dtype=np.int64),
                    "site_id": _zipf_fk(rng, n_user, n_site, a=1.2),
                    "account_id": _uniform_fk(rng, n_user, n_acc),
                    "reputation": rng.integers(0, 100, n_user).astype(np.int64)},
        "question": {"id": np.arange(n_q, dtype=np.int64),
                     "site_id": q_site,
                     "owner_user_id": _zipf_fk(rng, n_q, n_user),
                     "score": rng.integers(-5, 50, n_q).astype(np.int64)},
        "answer": {"question_id": _zipf_fk(rng, S(400_000), n_q, a=0.9),
                   "site_id": q_site[_zipf_fk(rng, S(400_000), n_q)],
                   "owner_user_id": _zipf_fk(rng, S(400_000), n_user)},
        "tag": {"id": np.arange(n_tag, dtype=np.int64),
                "site_id": _zipf_fk(rng, n_tag, n_site, a=1.2)},
        "tag_question": {"question_id": _zipf_fk(rng, S(500_000), n_q, a=0.9),
                         "tag_id": _zipf_fk(rng, S(500_000), n_tag)},
        "badge": {"user_id": _zipf_fk(rng, S(200_000), n_user, a=0.9),
                  "site_id": _zipf_fk(rng, S(200_000), n_site, a=1.2),
                  "badge_kind": rng.integers(0, 40, S(200_000)).astype(np.int64)},
        "comment": {"site_id": _zipf_fk(rng, S(300_000), n_site, a=1.2),
                    "post_id": _zipf_fk(rng, S(300_000), n_q, a=0.9)},
        "post_link": {"question_id": _zipf_fk(rng, S(15_000), n_q),
                      "related_question_id": _uniform_fk(rng, S(15_000), n_q)},
    }
    db = Database(name="stack", tables={k: Table(k, v) for k, v in tables.items()})
    db.stats = analyze(db, rng=np.random.default_rng(seed + 1))
    return db
