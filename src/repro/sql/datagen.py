"""Schema-faithful synthetic benchmark databases.

The offline container cannot ship IMDb/StackExchange Parquet dumps, so we
generate databases with the same *shape of hardness*: 21-table JOB-like and
10-table STACK-like schemas, Zipf-skewed foreign keys (breaks the CBO's
independence/uniformity assumptions), correlated predicates, and a fact
table with a `production_year` column so the paper's dynamic evaluation
(IMDb-1950 / IMDb-1980 -> full) filters apply (§VII-B5).

Scale is set so that plan-choice effects dominate: bad join orders produce
million-row intermediates (OOM/timeout territory under the cluster cost
model) while good orders stay in the thousands.

Materialization is spec-driven: `make_db_from_spec` interprets any
`repro.gen.spec.SchemaSpec` (the seeded schema sampler's output), and the
hand-built worlds are thin instances — `JOB_SPEC`/`STACK_SPEC` plus the
same interpreter, bit-identical at fixed seeds to the original inline
builders (pinned by tests/test_gen.py)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.gen.spec import (SchemaSpec, TableSpec, cat, cat2, fk, id_col,
                            spec_rows)
from repro.sql.catalog import Database, Table, analyze


def _zipf_fk(rng, n, n_parent, a=0.8):
    """Foreign keys into 0..n_parent-1 with bounded power-law skew: parent k
    gets weight (k+1)^-a. With a=0.6 the hottest parent draws ~0.5% of all
    rows (popular-movie realism: hub joins blow up under bad orders but a
    good order still finishes), and hub identity is SHARED across fact
    tables referencing the same parent — the cross-table correlation that
    breaks the CBO's independence assumption."""
    w = (np.arange(1, n_parent + 1, dtype=np.float64)) ** (-a)
    w /= w.sum()
    return rng.choice(n_parent, size=n, p=w).astype(np.int64)


def _uniform_fk(rng, n, n_parent):
    return rng.integers(0, n_parent, size=n, dtype=np.int64)


# ------------------------------------------------------ spec interpreter
def _realized_rows(spec: SchemaSpec, t: TableSpec, scale: float,
                   realized: Dict[str, int]) -> int:
    """Row count of `t` after scale + size_with cascades (`realized` maps
    already-materialized tables to their actual row counts)."""
    n = spec_rows(t, scale)
    if t.size_with:
        base = spec_rows(spec.table(t.size_with), scale)
        actual = realized[t.size_with]
        if actual != base:         # a snapshot filter shrank the parent
            n = max(16, int(n * actual / base))
    return n


def _draw_column(col, n: int, rng: np.random.Generator, cols: Dict,
                 tables: Dict[str, Dict],
                 realized: Dict[str, int]) -> np.ndarray:
    """One column's numpy draw — the spec grammar's entire runtime. FK
    domain sizes come from `realized` row counts (spec arithmetic), so a
    draw never needs its parent MATERIALIZED — only `via` gathers read
    parent columns, and validation pins those parents earlier."""
    if col.kind == "id":
        return np.arange(n, dtype=np.int64)
    if col.kind == "cat":
        return rng.integers(col.lo, col.hi, n).astype(np.int64)
    if col.kind == "cat2":
        src = cols[col.src]
        hi = rng.integers(0, col.hi_k, n)
        lo = rng.integers(0, col.lo_k, n)
        return np.where(src > col.threshold, hi, lo).astype(np.int64)
    if col.kind == "fk":
        keys = _zipf_fk(rng, n, realized[col.parent], a=col.a) if col.skew \
            else _uniform_fk(rng, n, realized[col.parent])
        if col.via:
            gathered = tables[col.parent].get(col.via)
            assert gathered is not None, \
                f"via gather {col.parent}.{col.via} not materialized yet"
            return gathered[keys]
        return keys
    raise ValueError(col.kind)


def materialize_table(spec: SchemaSpec, t: TableSpec, n: int,
                      rng: np.random.Generator,
                      tables: Optional[Dict[str, Dict]] = None,
                      realized: Optional[Dict[str, int]] = None
                      ) -> Dict[str, np.ndarray]:
    """All of one table's columns: draws follow the hoist order (columns
    with `order` set first), the returned dict keeps spec column order."""
    cols: Dict[str, np.ndarray] = {c.name: None for c in t.columns}
    hoisted = sorted((c for c in t.columns if c.order is not None),
                     key=lambda c: c.order)
    for c in hoisted + [c for c in t.columns if c.order is None]:
        cols[c.name] = _draw_column(c, n, rng, cols, tables or {},
                                    realized or {})
    return cols


def make_db_from_spec(spec: SchemaSpec, *, scale: float = 1.0, seed: int = 0,
                      rng: Optional[np.random.Generator] = None,
                      overrides: Optional[Dict[str, Dict]] = None,
                      name: Optional[str] = None,
                      analyze_seed: Optional[int] = None) -> Database:
    """Materialize a `SchemaSpec` into a `Database`.

    The draw sequence is table-major/column-minor in spec order, except
    columns with `order` set, which are hoisted to the front (sorted by
    `order`) — `fk` parent sizes come from the spec arithmetic, so a
    hoisted draw never needs an unmaterialized table, only `via` gathers
    do (validated by `spec.assert_valid`). `overrides` supplies
    precomputed column dicts (snapshot-filtered roots): overridden tables
    consume NO draws and downstream `size_with` cascades see their
    realized row count. Passing a live `rng` continues an existing
    stream (the hand-built builders draw their root first, filter, then
    hand the rng over); `analyze_seed` defaults to ``seed + 1`` — the
    hand-built worlds' statistics seed."""
    rng = rng if rng is not None else np.random.default_rng(seed)
    overrides = overrides or {}
    realized: Dict[str, int] = {}
    out: Dict[str, Dict[str, np.ndarray]] = {}
    plan = []                       # (table, column, n) draw steps
    for t in spec.tables:
        if t.name in overrides:
            out[t.name] = dict(overrides[t.name])
            realized[t.name] = len(next(iter(out[t.name].values())))
            continue
        # pre-populate in spec column order: hoisting reorders only the
        # DRAWS below, never where a column lands in the table dict
        out[t.name] = {c.name: None for c in t.columns}
        n = _realized_rows(spec, t, scale, realized)
        realized[t.name] = n
        for c in t.columns:
            plan.append((t.name, c, n))
    hoisted = sorted((s for s in plan if s[1].order is not None),
                     key=lambda s: s[1].order)
    for tname, c, n in hoisted + [s for s in plan if s[1].order is None]:
        out[tname][c.name] = _draw_column(c, n, rng, out[tname], out,
                                          realized)
    db = Database(name=name if name is not None else spec.name,
                  tables={t.name: Table(t.name, out[t.name])
                          for t in spec.tables})
    db.stats = analyze(db, rng=np.random.default_rng(
        seed + 1 if analyze_seed is None else analyze_seed))
    return db


# ------------------------------------------------------ hand-built specs
def _fact(name: str, n: int, *extra, skew: bool = True) -> TableSpec:
    """JOB-like movie-fact table: Zipf movie_id into title + extras,
    shrinking with title under snapshot filters."""
    return TableSpec(name, n, (fk("movie_id", "title", skew=skew),) + extra,
                     size_with="title")


JOB_SPEC = SchemaSpec("job", (
    TableSpec("title", 60_000, (
        id_col(),
        # year drawn FIRST (order=0) even though kind_id precedes it in
        # column order — cat2 skews newer movies to kinds 0/1
        cat2("kind_id", "production_year", 1990, 3, 7),
        dataclasses.replace(cat("production_year", 1900, 2014), order=0))),
    _fact("movie_companies", 80_000,
          fk("company_id", "company_name"), cat("company_type_id", 0, 4)),
    _fact("cast_info", 300_000,
          fk("person_id", "name"), cat("role_id", 0, 12)),
    _fact("movie_info", 150_000, cat("info_type_id", 0, 110)),
    _fact("movie_info_idx", 40_000, cat("info_type_id", 0, 110)),
    _fact("movie_keyword", 120_000, fk("keyword_id", "keyword")),
    _fact("aka_title", 10_000, skew=False),
    _fact("complete_cast", 20_000, cat("subject_id", 0, 4),
          cat("status_id", 0, 4), skew=False),
    _fact("movie_link", 8_000, cat("link_type_id", 0, 18),
          fk("linked_movie_id", "title", skew=False), skew=False),
    TableSpec("name", 40_000, (id_col(), cat("gender", 0, 3))),
    TableSpec("aka_name", 15_000, (fk("person_id", "name"),)),
    TableSpec("person_info", 60_000, (fk("person_id", "name"),
                                      cat("info_type_id", 0, 40))),
    TableSpec("char_name", 20_000, (id_col(),)),
    TableSpec("company_name", 3_000, (id_col(),
                                      cat("country_code", 0, 60))),
    TableSpec("company_type", 4, (id_col(),), fixed=True),
    TableSpec("info_type", 110, (id_col(),), fixed=True),
    TableSpec("keyword", 8_000, (id_col(),)),
    TableSpec("kind_type", 7, (id_col(),), fixed=True),
    TableSpec("role_type", 12, (id_col(),), fixed=True),
    TableSpec("comp_cast_type", 4, (id_col(),), fixed=True),
    TableSpec("link_type", 18, (id_col(),), fixed=True),
))

# title's kind_id is a cat2 over production_year, but the ORIGINAL builder
# drew years/kind in title-order too, so the spec draw sequence matches.
# The one stream quirk the STACK schema carries: question.site_id was
# drawn before every other column (order=0), and answer.site_id is a hub
# gather — a fresh Zipf fk into question whose stored values are the
# question's site (the shared-hub cross-table correlation).
STACK_SPEC = SchemaSpec("stack", (
    TableSpec("site", 40, (id_col(),), fixed=True),
    TableSpec("account", 25_000, (id_col(), cat("website_kind", 0, 5))),
    TableSpec("so_user", 30_000, (id_col(), fk("site_id", "site", a=1.2),
                                  fk("account_id", "account", skew=False),
                                  cat("reputation", 0, 100))),
    TableSpec("question", 80_000, (id_col(),
                                   fk("site_id", "site", a=1.2, order=0),
                                   fk("owner_user_id", "so_user"),
                                   cat("score", -5, 50))),
    TableSpec("answer", 400_000, (fk("question_id", "question", a=0.9),
                                  fk("site_id", "question", via="site_id"),
                                  fk("owner_user_id", "so_user"))),
    TableSpec("tag", 2_000, (id_col(), fk("site_id", "site", a=1.2))),
    TableSpec("tag_question", 500_000, (fk("question_id", "question", a=0.9),
                                        fk("tag_id", "tag"))),
    TableSpec("badge", 200_000, (fk("user_id", "so_user", a=0.9),
                                 fk("site_id", "site", a=1.2),
                                 cat("badge_kind", 0, 40))),
    TableSpec("comment", 300_000, (fk("site_id", "site", a=1.2),
                                   fk("post_id", "question", a=0.9))),
    TableSpec("post_link", 15_000, (fk("question_id", "question"),
                                    fk("related_question_id", "question",
                                       skew=False))),
))


def make_job_like(scale: float = 1.0, seed: int = 0,
                  year_max: Optional[int] = None) -> Database:
    """21-table IMDb-like star/snowflake schema: `JOB_SPEC` through the
    spec interpreter. `year_max` filters the fact-root (and cascades to
    FK tables via size_with) to build IMDb-1950/-1980 snapshots — the
    root is drawn first, filtered and reindexed dense, then passed as an
    override so the remaining draw stream is unchanged."""
    rng = np.random.default_rng(seed)
    overrides = {}
    if year_max is not None:
        tspec = JOB_SPEC.table("title")
        title = materialize_table(JOB_SPEC, tspec,
                                  spec_rows(tspec, scale), rng, {})
        keep = title["production_year"] <= year_max
        title = {k: v[keep] for k, v in title.items()}
        title["id"] = np.arange(int(keep.sum()), dtype=np.int64)
        overrides["title"] = title
    return make_db_from_spec(
        JOB_SPEC, scale=scale, seed=seed, rng=rng, overrides=overrides,
        name=f"job{'' if year_max is None else year_max}")


def delta_rows(table: Table, n: int,
               rng: np.random.Generator) -> Dict[str, np.ndarray]:
    """Fresh rows for a delta-table append, shaped like the table's current
    contents: non-key columns are bootstrap-resampled from the existing
    rows (preserving the Zipf skew and keeping every FK pointing at a live
    parent), while dense `id` primary keys extend past the current max so
    appended dimension rows stay unique."""
    cols: Dict[str, np.ndarray] = {}
    for name, arr in table.columns.items():
        if name == "id":
            start = int(arr.max()) + 1 if len(arr) else 0
            cols[name] = np.arange(start, start + n, dtype=arr.dtype)
        elif len(arr):
            cols[name] = rng.choice(arr, size=n)
        else:
            cols[name] = np.zeros(n, arr.dtype)
    return cols


def make_stack_like(scale: float = 1.0, seed: int = 1) -> Database:
    """10-table StackExchange-like schema: `STACK_SPEC` interpreted."""
    return make_db_from_spec(STACK_SPEC, scale=scale, seed=seed)
