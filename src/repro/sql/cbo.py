"""Cost-based optimizer: estimation + DP join enumeration (System-R style).

Reproduces Spark CBO's behaviour AND its failure mode (paper Fig. 3): the
DP over connected subgraphs is exponential, so planning time blows up with
join count — measured wall time is charged to C_plan. Cardinality
estimates use sampled statistics + independence assumptions, which the
Zipf-skewed data deliberately violates.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.sql.catalog import Database, Stats
from repro.sql.plans import BHJ, Join, Leaf, Node, SMJ, build_left_deep
from repro.sql.query import Query

DP_MAX_RELATIONS = 12          # beyond this, fall back to greedy (and pay
                               # the measured DP time up to the cutoff)


@dataclasses.dataclass
class Estimator:
    """CBO's belief about cardinalities (pre-execution only)."""
    db: Database
    stats: Stats

    def base_rows(self, query: Query, alias: str) -> float:
        rel = query.relation(alias)
        ts = self.stats.tables[rel.table]
        rows = ts.nrows
        for f in rel.filters:
            rows *= f.selectivity_est(ts.columns[f.column])
        return max(rows, 1.0)

    def base_bytes(self, query: Query, alias: str) -> float:
        rel = query.relation(alias)
        width = 8 * max(1, len(self.db.tables[rel.table].columns))
        return self.base_rows(query, alias) * width

    def ndv(self, query: Query, alias: str, col: str) -> float:
        rel = query.relation(alias)
        return max(self.stats.tables[rel.table].columns[col].n_distinct, 1.0)

    def join_rows(self, query: Query, l_set: FrozenSet[str], l_rows: float,
                  r_set: FrozenSet[str], r_rows: float) -> float:
        """|L x R| * prod_conds 1/max(ndv_l, ndv_r) (independence)."""
        sel = 1.0
        for c in query.conds:
            if c.left in l_set and c.right in r_set:
                sel /= max(self.ndv(query, c.left, c.lcol),
                           self.ndv(query, c.right, c.rcol))
            elif c.right in l_set and c.left in r_set:
                sel /= max(self.ndv(query, c.right, c.rcol),
                           self.ndv(query, c.left, c.lcol))
        if sel == 1.0:
            return l_rows * r_rows          # cross join (never chosen)
        return max(l_rows * r_rows * sel, 1.0)

    def width(self, query: Query, aliases: FrozenSet[str]) -> float:
        return 8 * sum(max(1, len(self.db.tables[query.relation(a).table].columns))
                       for a in aliases)


def _connected(query: Query, s: FrozenSet[str]) -> bool:
    if not s:
        return False
    adj = query.adjacency()
    seen = {next(iter(s))}
    stack = [next(iter(s))]
    while stack:
        for nxt in adj[stack.pop()]:
            if nxt in s and nxt not in seen:
                seen.add(nxt)
                stack.append(nxt)
    return len(seen) == len(s)


def dp_join_order(query: Query, est: Estimator) -> Tuple[Optional[Node], float, int]:
    """DPsize over connected subgraphs, C_out cost metric.
    Returns (plan, measured_seconds, n_subproblems)."""
    t0 = time.perf_counter()
    aliases = [r.alias for r in query.relations]
    n = len(aliases)
    best: Dict[FrozenSet[str], Tuple[float, float, Node]] = {}
    for a in aliases:
        s = frozenset([a])
        rows = est.base_rows(query, a)
        best[s] = (0.0, rows, Leaf(s))
    n_sub = 0
    for size in range(2, n + 1):
        for combo in itertools.combinations(aliases, size):
            s = frozenset(combo)
            if not _connected(query, s):
                continue
            best_cost = None
            # split into (left, right) with left a connected proper subset
            members = sorted(s)
            anchor = members[0]
            for lsize in range(1, size):
                for lcombo in itertools.combinations(members, lsize):
                    lset = frozenset(lcombo)
                    if anchor not in lset:      # canonical split (halves work)
                        continue
                    rset = s - lset
                    if lset not in best or rset not in best:
                        continue
                    if not query.conds_between(lset, rset):
                        continue
                    n_sub += 1
                    lcost, lrows, lplan = best[lset]
                    rcost, rrows, rplan = best[rset]
                    out = est.join_rows(query, lset, lrows, rset, rrows)
                    cost = lcost + rcost + out
                    if best_cost is None or cost < best_cost[0]:
                        conds = tuple(query.conds_between(lset, rset))
                        best_cost = (cost, out,
                                     Join(lplan, rplan, conds, SMJ))
            if best_cost is not None:
                best[s] = best_cost
    full = frozenset(aliases)
    elapsed = time.perf_counter() - t0
    if full not in best:
        return None, elapsed, n_sub
    return best[full][2], elapsed, n_sub


def greedy_join_order(query: Query, est: Estimator) -> Node:
    """Min-output-first greedy (what we fall back to past DP_MAX_RELATIONS)."""
    remaining = {r.alias: (est.base_rows(query, r.alias),
                           Leaf(frozenset([r.alias])))
                 for r in query.relations}
    # start from the smallest estimated relation
    cur_alias = min(remaining, key=lambda a: remaining[a][0])
    cur_rows, plan = remaining.pop(cur_alias)
    cur_set = frozenset([cur_alias])
    while remaining:
        cands = []
        for a, (rows, leaf) in remaining.items():
            if query.conds_between(cur_set, frozenset(leaf.covered())):
                out = est.join_rows(query, cur_set, cur_rows,
                                    frozenset([a]), rows)
                cands.append((out, a))
        if not cands:
            a = next(iter(remaining))   # disconnected: take any (cross)
            out = cur_rows * remaining[a][0]
        else:
            out, a = min(cands)
        rows, leaf = remaining.pop(a)
        conds = tuple(query.conds_between(cur_set, frozenset([a])))
        plan = Join(plan, leaf, conds, SMJ)
        cur_set = cur_set | {a}
        cur_rows = out
    return plan


def cbo_plan(query: Query, est: Estimator) -> Tuple[Node, float]:
    """Full CBO: DP when tractable, greedy beyond. Returns (plan, C_plan)."""
    if query.n_relations <= DP_MAX_RELATIONS:
        plan, t, _ = dp_join_order(query, est)
        if plan is not None:
            return plan, t
        return greedy_join_order(query, est), t
    # emulate Spark: DP attempts the prefix, blows up, greedy finishes.
    sub = Query(query.name, query.relations[:DP_MAX_RELATIONS], query.conds)
    _, t_burn, _ = dp_join_order(_restrict(query, DP_MAX_RELATIONS), est)
    return greedy_join_order(query, est), t_burn


def _restrict(query: Query, k: int) -> Query:
    keep = {r.alias for r in query.relations[:k]}
    conds = tuple(c for c in query.conds
                  if c.left in keep and c.right in keep)
    q = Query(query.name, query.relations[:k], conds)
    if not q.is_connected():            # ensure DP has work but stays sane
        keep_rel = [query.relations[0]]
        seen = {query.relations[0].alias}
        adj = query.adjacency()
        frontier = [query.relations[0].alias]
        while frontier and len(keep_rel) < k:
            nxt_alias = frontier.pop(0)
            for nb in adj[nxt_alias]:
                if nb not in seen and len(keep_rel) < k:
                    seen.add(nb)
                    keep_rel.append(query.relation(nb))
                    frontier.append(nb)
        conds = tuple(c for c in query.conds if c.left in seen and c.right in seen)
        q = Query(query.name, tuple(keep_rel), conds)
    return q
