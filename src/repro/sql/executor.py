"""Stage-based adaptive executor with exact cardinalities.

Execution is Spark-AQE-shaped: the remaining plan's next executable join
(leftmost join whose children are both materialized) runs as one *query
stage*; after each stage the runtime re-examines the remainder with TRUE
sizes — the rule-based AQE switches SMJ<->BHJ exactly like Spark 3.x, and
the *extension hook* (AQORA's planner extension, §VI) may rewrite the
remaining plan (swap/lead/broadcast/cbo) before execution resumes.

Joins compute exact match counts first (cheap: sort + searchsorted), so an
exploding intermediate is detected and charged as OOM *without*
materializing it — the same way a Spark executor dies before finishing.

Latency is charged against `ClusterModel` (see cluster.py); cardinalities,
shuffle counts and bytes are exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Generator, List, Optional, Tuple

import numpy as np

from repro.serve.cache import StageCache
from repro.sql.catalog import Database
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.plans import (BHJ, Join, Leaf, Node, SMJ, copy_plan, joins,
                             leaves)
from repro.sql.query import Query


class QueryFailure(Exception):
    # natural kinds: "oom" | "timeout"; injected (serve.recover.faults):
    # "crash" (lane lost, in-flight work gone) | "transient" (stage error)
    def __init__(self, kind: str, msg: str = ""):
        super().__init__(f"{kind}: {msg}")
        self.kind = kind


@dataclasses.dataclass
class MaterializedRel:
    aliases: frozenset
    columns: Dict[Tuple[str, str], np.ndarray]   # (alias, col) -> values
    nrows: int
    width: float                                 # modeled row width (bytes)
    partitioned_on: Optional[Tuple[str, str]] = None
    sig: Optional[tuple] = None                  # structural signature: the
    #   deterministic derivation of this rel (stage-reuse cache key)

    @property
    def bytes(self) -> float:
        return self.nrows * self.width


@dataclasses.dataclass
class StageRecord:
    """Telemetry for one completed stage (one join or scan batch)."""
    covered: frozenset
    method: str
    out_rows: int
    out_bytes: float
    shuffles: int
    shuffle_bytes: float
    seconds: float


@dataclasses.dataclass
class RunResult:
    latency: float                 # C_execute (simulated seconds, capped)
    plan_time: float               # C_plan contribution from the optimizer
    failed: bool
    failure_kind: str
    stages: List[StageRecord]
    total_shuffles: int
    total_shuffle_bytes: float
    final_plan: Optional[Node]
    bushy: bool

    @property
    def total(self) -> float:
        return self.latency + self.plan_time


# ------------------------------------------------------------------ joins
def _join_indices(lkey: np.ndarray, rkey: np.ndarray, cap: int):
    """Exact inner-join row indices. Counts matches first; raises on blowup."""
    order = np.argsort(rkey, kind="stable")
    rs = rkey[order]
    lo = np.searchsorted(rs, lkey, "left")
    hi = np.searchsorted(rs, lkey, "right")
    cnt = hi - lo
    total = int(cnt.sum())
    if total > cap:
        raise QueryFailure("oom", f"join output {total} rows exceeds cap")
    lidx = np.repeat(np.arange(len(lkey)), cnt)
    starts = np.repeat(lo, cnt)
    offs = np.arange(total) - np.repeat(np.cumsum(cnt) - cnt, cnt)
    ridx = order[starts + offs]
    return lidx, ridx


def _needed_cols(query: Query, alias: str) -> List[str]:
    cols = set()
    for c in query.conds:
        if c.left == alias:
            cols.add(c.lcol)
        if c.right == alias:
            cols.add(c.rcol)
    return sorted(cols) or ["id"]      # no join key: keep the row id


class Executor:
    """Stage executor with cross-run stage reuse (Spark's ReuseExchange,
    lifted across episodes and live queries): scans and join ROW SETS are
    deterministic given (table@version, filters, conds), so repeated
    executions of the same query — the training loop replays its workload
    every episode; the serving layer sees repeated template hits — skip
    the numpy work and only re-charge the modeled latency. Latency,
    shuffle accounting and OOM checks are always recomputed against THIS
    run's cluster, so results are bit-identical with the cache off.

    The cache itself is a `serve.cache.StageCache` shared via the database
    object: LRU eviction under a byte budget, and every signature embeds
    the base tables' version tags, so delta-table updates invalidate
    derived entries in O(1)."""

    _CACHE_MAX_BYTES = 256 * 1024 * 1024   # default budget for auto-created
    _ENTRY_MAX_BYTES = 32 * 1024 * 1024    #   caches; huge stages not pinned

    def __init__(self, db: Database, cluster: Optional[ClusterModel] = None,
                 reuse_stages: bool = True,
                 cache: Optional[StageCache] = None):
        self.db = db
        self.cluster = cluster if cluster is not None else ClusterModel()
        if not reuse_stages:
            self._cache = None
        elif cache is not None:
            # explicit cache (e.g. one tenant's partition of a
            # serve.cache.PartitionedStageCache, routed by the scheduler)
            self._cache = cache
        else:
            cache = getattr(db, "_stage_cache", None)
            if not isinstance(cache, StageCache):
                cache = StageCache(self._CACHE_MAX_BYTES,
                                   self._ENTRY_MAX_BYTES)
                db._stage_cache = cache
            self._cache = cache

    @property
    def cache_stats(self):
        """hit/miss/evict/invalidate counters of the attached stage cache
        (`serve.cache.CacheStats`), or None when reuse is off."""
        return None if self._cache is None else self._cache.stats

    # -------------------------------------------------- base scan
    def scan(self, query: Query, alias: str) -> Tuple[MaterializedRel, float]:
        rel = query.relation(alias)
        t = self.db.table(rel.table)
        need = tuple(_needed_cols(query, alias))
        sig = ("s", alias, rel.table, rel.filters, need,
               self.db.table_version(rel.table))
        secs = self.cluster.scan_time(t.bytes())
        if self._cache is not None:
            hit = self._cache.get(sig)
            if hit is not None:
                cols, nrows = hit
                width = 8.0 * max(1, t.ncols)
                return MaterializedRel(frozenset([alias]), dict(cols), nrows,
                                       width, sig=sig), secs
        mask = np.ones(t.nrows, bool)
        for f in rel.filters:
            mask &= f.apply(t.columns[f.column])
        idx = np.flatnonzero(mask)
        cols = {}
        for c in need:
            if c in t.columns:
                cols[(alias, c)] = t.columns[c][idx]
            else:                        # implicit PK "id" = row index
                cols[(alias, c)] = idx.astype(np.int64)
        width = 8.0 * max(1, t.ncols)
        m = MaterializedRel(frozenset([alias]), cols, len(idx), width,
                            sig=sig)
        if self._cache is not None:
            nbytes = sum(v.nbytes for v in cols.values())
            self._cache.put(sig, (dict(cols), len(idx)), nbytes)
        return m, secs

    # -------------------------------------------------- join stage
    def join(self, query: Query, left: MaterializedRel, right: MaterializedRel,
             conds, method: str) -> Tuple[MaterializedRel, StageRecord]:
        cl = self.cluster
        c0 = conds[0]
        # orient: c0.left must live in `left`
        if c0.left in left.aliases:
            key_l, key_r = (c0.left, c0.lcol), (c0.right, c0.rcol)
        else:
            key_l, key_r = (c0.right, c0.rcol), (c0.left, c0.lcol)

        sig = None
        if self._cache is not None and left.sig is not None \
                and right.sig is not None:
            sig = ("j", left.sig, right.sig, tuple(conds))
        hit = self._cache.get(sig) if sig is not None else None
        if hit is not None:
            out_cols, nrows, pre_total = hit
            # the matched-rows cap guards THIS run's cluster, not the one
            # that populated the cache
            if pre_total > cl.materialize_cap:
                raise QueryFailure(
                    "oom", f"join output {pre_total} rows exceeds cap")
            out = MaterializedRel(left.aliases | right.aliases,
                                  dict(out_cols), nrows,
                                  left.width + right.width, sig=sig)
        else:
            lkey = left.columns[key_l]
            rkey = right.columns[key_r]
            lidx, ridx = _join_indices(lkey, rkey, cl.materialize_cap)
            pre_total = len(lidx)
            # residual equality conditions
            keep = np.ones(len(lidx), bool)
            for c in conds[1:]:
                if c.left in left.aliases:
                    la, ra = (c.left, c.lcol), (c.right, c.rcol)
                else:
                    la, ra = (c.right, c.rcol), (c.left, c.lcol)
                keep &= left.columns[la][lidx] == right.columns[ra][ridx]
            if not keep.all():
                lidx, ridx = lidx[keep], ridx[keep]
            out_cols = {k: v[lidx] for k, v in left.columns.items()}
            out_cols.update({k: v[ridx] for k, v in right.columns.items()})
            out = MaterializedRel(left.aliases | right.aliases, out_cols,
                                  len(lidx), left.width + right.width,
                                  sig=sig)
            if sig is not None:
                nbytes = sum(v.nbytes for v in out_cols.values())
                self._cache.put(sig, (dict(out_cols), len(lidx), pre_total),
                                nbytes)

        # ---- latency + shuffle accounting
        shuffles = 0
        shuffle_bytes = 0.0
        if method == SMJ:
            t = cl.stage_overhead
            for side, key in ((left, key_l), (right, key_r)):
                if side.partitioned_on != key:
                    shuffles += 1
                    shuffle_bytes += side.bytes
                    t += cl.shuffle_time(side.bytes)
            t += cl.smj_cpu(left.nrows, right.nrows, out.nrows)
            out.partitioned_on = key_l
        else:  # BHJ: smaller side broadcast
            build, probe = (left, right) if left.bytes <= right.bytes else (right, left)
            if cl.broadcast_oom(build.bytes):
                raise QueryFailure("oom",
                                   f"broadcast build {build.bytes/1e6:.1f} MB")
            t = cl.stage_overhead + cl.broadcast_time(build.bytes)
            t += cl.bhj_cpu(build.nrows, probe.nrows, out.nrows)
            out.partitioned_on = probe.partitioned_on
        rec = StageRecord(out.aliases, method, out.nrows, out.bytes,
                          shuffles, shuffle_bytes, t)
        return out, rec


# ------------------------------------------------------------------ AQE run
@dataclasses.dataclass
class RuntimeState:
    """What the extension hook sees at a stage boundary."""
    query: Query
    plan: Node                                   # remaining plan
    mats: Dict[frozenset, MaterializedRel]       # materialized leaves
    est: Estimator
    step: int                                    # hook invocations so far
    elapsed: float
    stages_done: int
    cluster: Optional[ClusterModel] = None       # the run's configured cluster

    def leaf_rows(self, leaf: Leaf) -> Optional[int]:
        m = self.mats.get(leaf.covered())
        return None if m is None else m.nrows

    def leaf_bytes(self, leaf: Leaf) -> Optional[float]:
        m = self.mats.get(leaf.covered())
        return None if m is None else m.bytes

    def leaf_bytes_est(self, leaf: Leaf) -> float:
        m = self.mats.get(leaf.covered())
        if m is not None:
            return m.bytes
        return self.est.base_bytes(self.query, leaf.alias)

    def planned_shuffles(self) -> int:
        return planned_shuffles(self.plan, self)


def planned_shuffles(plan: Node, state: RuntimeState) -> int:
    """Shuffle exchanges the remaining plan would execute, using actual
    sizes where known and estimates elsewhere (drives the shaping reward
    r_i = -(Δ shuffles)/10)."""
    cluster = state.cluster if state.cluster is not None else ClusterModel()
    count = 0

    def visit(node) -> Tuple[float, Optional[Tuple[str, str]]]:
        nonlocal count
        if isinstance(node, Leaf):
            m = state.mats.get(node.covered())
            if m is not None:
                return m.bytes, m.partitioned_on
            return state.leaf_bytes_est(node), None
        lb, lpart = visit(node.left)
        rb, rpart = visit(node.right)
        c0 = node.conds[0]
        lkey = (c0.left, c0.lcol) if c0.left in node.left.covered() else (c0.right, c0.rcol)
        rkey = (c0.right, c0.rcol) if c0.left in node.left.covered() else (c0.left, c0.lcol)
        method = node.method
        if any(isinstance(ch, Leaf) and ch.broadcast_hint
               for ch in (node.left, node.right)):
            method = BHJ
        elif min(lb, rb) < cluster.bjt:
            method = BHJ
        if method == SMJ:
            if lpart != lkey:
                count += 1
            if rpart != rkey:
                count += 1
            out_part = lkey
        else:
            out_part = rpart if lb <= rb else lpart
        # crude size propagation for planning purposes only
        return max(lb, rb), out_part

    visit(plan)
    return count


HookFn = Callable[[RuntimeState], Optional[Node]]


def annotate_methods(plan: Node, query: Query, est: Estimator,
                     cluster: ClusterModel) -> Node:
    """Static (pre-execution) operator selection from ESTIMATES — what the
    planner believes; AQE may later override with actual sizes."""
    def est_bytes(node) -> float:
        if isinstance(node, Leaf):
            return est.base_bytes(query, node.alias)
        return max(est_bytes(node.left), est_bytes(node.right))

    def visit(node):
        if isinstance(node, Leaf):
            return
        visit(node.left)
        visit(node.right)
        lb, rb = est_bytes(node.left), est_bytes(node.right)
        node.method = BHJ if min(lb, rb) < cluster.bjt else SMJ
    visit(plan)
    return plan


class AdaptiveRun:
    """Resumable adaptive execution of ONE query.

    The extension hook becomes a suspension point instead of a callback:
    `start()` advances execution to the first stage boundary with hook
    budget remaining and returns the `RuntimeState`; `resume(new_plan)`
    injects the hook's decision (a replacement remaining plan, or None to
    keep the current one) and advances to the next boundary. When the query
    runs to completion or fails, the call returns None and `result` holds
    the finished `RunResult`.

    This is what lets `core.vec_rollout` hold B suspended runs and feed all
    their pending states through one batched policy call per lockstep step;
    `run_adaptive` below drives a single run with the legacy callback.
    """

    def __init__(self, db: Database, query: Query, plan: Node, est: Estimator,
                 cluster: Optional[ClusterModel] = None,
                 max_hook_steps: int = 3,
                 plan_time: float = 0.0,
                 aqe_switching: bool = True,
                 reuse_stages: bool = True,
                 cache: Optional[StageCache] = None,
                 faults=None,
                 init_mats: Optional[Dict[frozenset, MaterializedRel]] = None,
                 init_stages_done: int = 0,
                 trace=None):
        """`faults` is an optional per-run fault profile (an object with
        `charge(seconds, state) -> seconds` that may raise `QueryFailure`,
        see serve.recover.faults) consulted at every latency charge; None
        keeps the execution path bit-identical. `init_mats` /
        `init_stages_done` seed the run with already-materialized stage
        results (a retry resuming from its failed attempt's last stage
        boundary: it pays only the stages the plan still contains).
        `trace` is an optional per-attempt sink (duck-typed like
        serve.obs.RunTrace: `scan`/`stage`/`fail`) that receives elapsed-
        offset stage notes; None skips every note, bit-identically."""
        self.cluster = cluster if cluster is not None else ClusterModel()
        self.query = query
        self.max_hook_steps = max_hook_steps
        self.plan_time = plan_time
        self.aqe_switching = aqe_switching
        self.state = RuntimeState(query, copy_plan(plan),
                                  dict(init_mats) if init_mats else {},
                                  est, 0, 0.0, int(init_stages_done),
                                  self.cluster)
        self._faults = faults
        self._trace = trace
        self.result: Optional[RunResult] = None
        self._ex = Executor(db, self.cluster, reuse_stages=reuse_stages,
                            cache=cache)
        self._stages: List[StageRecord] = []
        self._tot_shuffles = 0
        self._tot_sbytes = 0.0
        self._bushy = False
        self._failure: Optional[QueryFailure] = None
        self._gen = self._drive()
        self._started = False

    @property
    def done(self) -> bool:
        return self.result is not None

    # ------------------------------------------------------------- driving
    def start(self) -> Optional[RuntimeState]:
        """Advance to the first suspension point (or to completion)."""
        assert not self._started, "start() may only be called once"
        self._started = True
        return self._step(lambda: next(self._gen))

    def resume(self, new_plan: Optional[Node] = None) -> Optional[RuntimeState]:
        """Deliver the hook's decision and advance to the next boundary."""
        assert self._started, "call start() before resume()"
        if self.result is not None:
            return None
        return self._step(lambda: self._gen.send(new_plan))

    def _step(self, advance) -> Optional[RuntimeState]:
        try:
            return advance()
        except StopIteration:
            cl, st = self.cluster, self.state
            if self._failure is not None:
                # failure pricing is the cluster's call: full timeout for
                # the legacy modes, detection-time + spill otherwise
                charge = cl.failure_charge(self._failure.kind, st.elapsed)
                self.result = RunResult(charge, self.plan_time, True,
                                        self._failure.kind, self._stages,
                                        self._tot_shuffles, self._tot_sbytes,
                                        st.plan, self._bushy)
            else:
                self.result = RunResult(st.elapsed, self.plan_time, False, "",
                                        self._stages, self._tot_shuffles,
                                        self._tot_sbytes, st.plan, self._bushy)
            return None

    # ----------------------------------------------------------- execution
    def _drive(self) -> Generator[RuntimeState, Optional[Node], None]:
        state, cluster, ex, query = (self.state, self.cluster, self._ex,
                                     self.query)
        trace = self._trace

        def charge(seconds: float):
            if self._faults is not None:
                # the fault profile may stretch the charge (straggler
                # multiplier) or abort it mid-stage (crash/transient)
                seconds = self._faults.charge(seconds, state)
            state.elapsed += seconds
            if state.elapsed >= cluster.timeout:
                raise QueryFailure("timeout", f"{state.elapsed:.1f}s")

        def scan_charged(alias: str) -> MaterializedRel:
            """Scan + charge, with an optional trace note (cache hit
            detected by the stats delta around the executor call)."""
            if trace is None:
                m, secs = ex.scan(query, alias)
                charge(secs)
                return m
            cs = ex.cache_stats
            h0 = cs.hits if cs is not None else 0
            e0 = state.elapsed
            m, secs = ex.scan(query, alias)
            charge(secs)
            trace.scan(alias, e0, state.elapsed, m.nrows,
                       cs is not None and cs.hits > h0)
            return m

        try:
            while True:
                # ---- extension hook (pre-exec at step 0, then per stage)
                if state.step < self.max_hook_steps:
                    new_plan = yield state
                    state.step += 1
                    if new_plan is not None:
                        state.plan = new_plan
                if isinstance(state.plan, Leaf):
                    # plan may be a single leaf only if query has 1 relation
                    if state.plan.covered() not in state.mats:
                        m = scan_charged(state.plan.alias)
                        state.mats[m.aliases] = m
                    return

                # ---- find next executable join (leftmost-deepest)
                def next_join(node) -> Optional[Join]:
                    if isinstance(node, Leaf):
                        return None
                    j = next_join(node.left)
                    if j is not None:
                        return j
                    j = next_join(node.right)
                    if j is not None:
                        return j
                    if isinstance(node.left, Leaf) and isinstance(node.right, Leaf):
                        return node
                    return None

                jn = next_join(state.plan)
                assert jn is not None
                # materialize child scans
                sides = []
                for ch in (jn.left, jn.right):
                    key = ch.covered()
                    if key not in state.mats:
                        state.mats[key] = scan_charged(ch.alias)
                    sides.append(state.mats[key])
                left_m, right_m = sides

                # ---- AQE operator selection with ACTUAL sizes (Spark rule)
                method = jn.method
                hinted = any(isinstance(ch, Leaf) and ch.broadcast_hint
                             for ch in (jn.left, jn.right))
                if hinted:
                    method = BHJ
                elif self.aqe_switching:
                    # Spark AQE: re-decide from ACTUAL sizes at the boundary
                    method = BHJ if min(left_m.bytes, right_m.bytes) < cluster.bjt \
                        else SMJ

                # joining two multi-alias intermediates == bushy shape (§VI-B1)
                if len(left_m.aliases) > 1 and len(right_m.aliases) > 1:
                    self._bushy = True
                if trace is None:
                    out, rec = ex.join(query, left_m, right_m, jn.conds,
                                       method)
                    charge(rec.seconds)
                else:
                    # estimated-vs-actual rows only priced when tracing:
                    # the estimate is pure observation, never fed back
                    est_rows = state.est.join_rows(
                        query, left_m.aliases, float(left_m.nrows),
                        right_m.aliases, float(right_m.nrows))
                    cs = ex.cache_stats
                    h0 = cs.hits if cs is not None else 0
                    e0 = state.elapsed
                    out, rec = ex.join(query, left_m, right_m, jn.conds,
                                       method)
                    charge(rec.seconds)
                    trace.stage(out.aliases, method, e0, state.elapsed,
                                out.nrows, est_rows, rec.shuffles,
                                cs is not None and cs.hits > h0)
                self._stages.append(rec)
                self._tot_shuffles += rec.shuffles
                self._tot_sbytes += rec.shuffle_bytes
                state.stages_done += 1
                state.mats[out.aliases] = out

                # ---- replace the executed join by a stage-result leaf
                new_leaf = Leaf(out.aliases, stage_id=state.stages_done)

                def replace(node):
                    if node is jn:
                        return new_leaf
                    if isinstance(node, Leaf):
                        return node
                    node.left = replace(node.left)
                    node.right = replace(node.right)
                    return node

                state.plan = replace(state.plan)
                if isinstance(state.plan, Leaf):
                    return
        except QueryFailure as f:
            self._failure = f
            if trace is not None:
                trace.fail(f.kind, state.elapsed)
            return


def run_adaptive(db: Database, query: Query, plan: Node, est: Estimator,
                 cluster: Optional[ClusterModel] = None,
                 hook: Optional[HookFn] = None,
                 max_hook_steps: int = 3,
                 plan_time: float = 0.0,
                 aqe_switching: bool = True,
                 reuse_stages: bool = True) -> RunResult:
    """Execute `plan` stage-by-stage with AQE + optional extension hook.

    The hook is invoked at stage boundaries (including once pre-execution,
    matching AQORA's two-phase optimization) at most `max_hook_steps` times;
    it may return a REPLACEMENT remaining plan (built from the same leaves).
    Implemented by driving an `AdaptiveRun` to completion.
    """
    run = AdaptiveRun(db, query, plan, est, cluster,
                      max_hook_steps=max_hook_steps if hook is not None else 0,
                      plan_time=plan_time, aqe_switching=aqe_switching,
                      reuse_stages=reuse_stages)
    st = run.start()
    while st is not None:
        st = run.resume(hook(st))
    return run.result


