"""Stage-based adaptive executor with exact cardinalities.

Execution is Spark-AQE-shaped: the remaining plan's next executable join
(leftmost join whose children are both materialized) runs as one *query
stage*; after each stage the runtime re-examines the remainder with TRUE
sizes — the rule-based AQE switches SMJ<->BHJ exactly like Spark 3.x, and
the *extension hook* (AQORA's planner extension, §VI) may rewrite the
remaining plan (swap/lead/broadcast/cbo) before execution resumes.

Joins compute exact match counts first (cheap: sort + searchsorted), so an
exploding intermediate is detected and charged as OOM *without*
materializing it — the same way a Spark executor dies before finishing.

Latency is charged against `ClusterModel` (see cluster.py); cardinalities,
shuffle counts and bytes are exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.sql.catalog import Database
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.plans import (BHJ, Join, Leaf, Node, SMJ, copy_plan, joins,
                             leaves)
from repro.sql.query import Query


class QueryFailure(Exception):
    def __init__(self, kind: str, msg: str = ""):
        super().__init__(f"{kind}: {msg}")
        self.kind = kind               # "oom" | "timeout"


@dataclasses.dataclass
class MaterializedRel:
    aliases: frozenset
    columns: Dict[Tuple[str, str], np.ndarray]   # (alias, col) -> values
    nrows: int
    width: float                                 # modeled row width (bytes)
    partitioned_on: Optional[Tuple[str, str]] = None

    @property
    def bytes(self) -> float:
        return self.nrows * self.width


@dataclasses.dataclass
class StageRecord:
    """Telemetry for one completed stage (one join or scan batch)."""
    covered: frozenset
    method: str
    out_rows: int
    out_bytes: float
    shuffles: int
    shuffle_bytes: float
    seconds: float


@dataclasses.dataclass
class RunResult:
    latency: float                 # C_execute (simulated seconds, capped)
    plan_time: float               # C_plan contribution from the optimizer
    failed: bool
    failure_kind: str
    stages: List[StageRecord]
    total_shuffles: int
    total_shuffle_bytes: float
    final_plan: Optional[Node]
    bushy: bool

    @property
    def total(self) -> float:
        return self.latency + self.plan_time


# ------------------------------------------------------------------ joins
def _join_indices(lkey: np.ndarray, rkey: np.ndarray, cap: int):
    """Exact inner-join row indices. Counts matches first; raises on blowup."""
    order = np.argsort(rkey, kind="stable")
    rs = rkey[order]
    lo = np.searchsorted(rs, lkey, "left")
    hi = np.searchsorted(rs, lkey, "right")
    cnt = hi - lo
    total = int(cnt.sum())
    if total > cap:
        raise QueryFailure("oom", f"join output {total} rows exceeds cap")
    lidx = np.repeat(np.arange(len(lkey)), cnt)
    starts = np.repeat(lo, cnt)
    offs = np.arange(total) - np.repeat(np.cumsum(cnt) - cnt, cnt)
    ridx = order[starts + offs]
    return lidx, ridx


def _needed_cols(query: Query, alias: str) -> List[str]:
    cols = set()
    for c in query.conds:
        if c.left == alias:
            cols.add(c.lcol)
        if c.right == alias:
            cols.add(c.rcol)
    return sorted(cols) or ["id"]      # no join key: keep the row id


class Executor:
    def __init__(self, db: Database, cluster: ClusterModel = ClusterModel()):
        self.db = db
        self.cluster = cluster

    # -------------------------------------------------- base scan
    def scan(self, query: Query, alias: str) -> Tuple[MaterializedRel, float]:
        rel = query.relation(alias)
        t = self.db.table(rel.table)
        mask = np.ones(t.nrows, bool)
        for f in rel.filters:
            mask &= f.apply(t.columns[f.column])
        idx = np.flatnonzero(mask)
        cols = {}
        for c in _needed_cols(query, alias):
            if c in t.columns:
                cols[(alias, c)] = t.columns[c][idx]
            else:                        # implicit PK "id" = row index
                cols[(alias, c)] = idx.astype(np.int64)
        width = 8.0 * max(1, t.ncols)
        m = MaterializedRel(frozenset([alias]), cols, len(idx), width)
        secs = self.cluster.scan_time(t.bytes())
        return m, secs

    # -------------------------------------------------- join stage
    def join(self, query: Query, left: MaterializedRel, right: MaterializedRel,
             conds, method: str) -> Tuple[MaterializedRel, StageRecord]:
        cl = self.cluster
        c0 = conds[0]
        # orient: c0.left must live in `left`
        if c0.left in left.aliases:
            lkey = left.columns[(c0.left, c0.lcol)]
            rkey = right.columns[(c0.right, c0.rcol)]
            key_l, key_r = (c0.left, c0.lcol), (c0.right, c0.rcol)
        else:
            lkey = left.columns[(c0.right, c0.rcol)]
            rkey = right.columns[(c0.left, c0.lcol)]
            key_l, key_r = (c0.right, c0.rcol), (c0.left, c0.lcol)

        lidx, ridx = _join_indices(lkey, rkey, cl.materialize_cap)
        # residual equality conditions
        keep = np.ones(len(lidx), bool)
        for c in conds[1:]:
            if c.left in left.aliases:
                la, ra = (c.left, c.lcol), (c.right, c.rcol)
            else:
                la, ra = (c.right, c.rcol), (c.left, c.lcol)
            keep &= left.columns[la][lidx] == right.columns[ra][ridx]
        if not keep.all():
            lidx, ridx = lidx[keep], ridx[keep]
        out_cols = {k: v[lidx] for k, v in left.columns.items()}
        out_cols.update({k: v[ridx] for k, v in right.columns.items()})
        out = MaterializedRel(left.aliases | right.aliases, out_cols,
                              len(lidx), left.width + right.width)

        # ---- latency + shuffle accounting
        shuffles = 0
        shuffle_bytes = 0.0
        if method == SMJ:
            t = cl.stage_overhead
            for side, key in ((left, key_l), (right, key_r)):
                if side.partitioned_on != key:
                    shuffles += 1
                    shuffle_bytes += side.bytes
                    t += cl.shuffle_time(side.bytes)
            t += cl.smj_cpu(left.nrows, right.nrows, out.nrows)
            out.partitioned_on = key_l
        else:  # BHJ: smaller side broadcast
            build, probe = (left, right) if left.bytes <= right.bytes else (right, left)
            if cl.broadcast_oom(build.bytes):
                raise QueryFailure("oom",
                                   f"broadcast build {build.bytes/1e6:.1f} MB")
            t = cl.stage_overhead + cl.broadcast_time(build.bytes)
            t += cl.bhj_cpu(build.nrows, probe.nrows, out.nrows)
            out.partitioned_on = probe.partitioned_on
        rec = StageRecord(out.aliases, method, out.nrows, out.bytes,
                          shuffles, shuffle_bytes, t)
        return out, rec


# ------------------------------------------------------------------ AQE run
@dataclasses.dataclass
class RuntimeState:
    """What the extension hook sees at a stage boundary."""
    query: Query
    plan: Node                                   # remaining plan
    mats: Dict[frozenset, MaterializedRel]       # materialized leaves
    est: Estimator
    step: int                                    # hook invocations so far
    elapsed: float
    stages_done: int

    def leaf_rows(self, leaf: Leaf) -> Optional[int]:
        m = self.mats.get(leaf.covered())
        return None if m is None else m.nrows

    def leaf_bytes(self, leaf: Leaf) -> Optional[float]:
        m = self.mats.get(leaf.covered())
        return None if m is None else m.bytes

    def leaf_bytes_est(self, leaf: Leaf) -> float:
        m = self.mats.get(leaf.covered())
        if m is not None:
            return m.bytes
        return self.est.base_bytes(self.query, leaf.alias)

    def planned_shuffles(self) -> int:
        return planned_shuffles(self.plan, self)


def planned_shuffles(plan: Node, state: RuntimeState) -> int:
    """Shuffle exchanges the remaining plan would execute, using actual
    sizes where known and estimates elsewhere (drives the shaping reward
    r_i = -(Δ shuffles)/10)."""
    cl = state.est and state.est.db and None   # noqa - just for readability
    cluster = ClusterModel()
    count = 0

    def visit(node) -> Tuple[float, Optional[Tuple[str, str]]]:
        nonlocal count
        if isinstance(node, Leaf):
            m = state.mats.get(node.covered())
            if m is not None:
                return m.bytes, m.partitioned_on
            return state.leaf_bytes_est(node), None
        lb, lpart = visit(node.left)
        rb, rpart = visit(node.right)
        c0 = node.conds[0]
        lkey = (c0.left, c0.lcol) if c0.left in node.left.covered() else (c0.right, c0.rcol)
        rkey = (c0.right, c0.rcol) if c0.left in node.left.covered() else (c0.left, c0.lcol)
        method = node.method
        if any(isinstance(ch, Leaf) and ch.broadcast_hint
               for ch in (node.left, node.right)):
            method = BHJ
        elif min(lb, rb) < cluster.bjt:
            method = BHJ
        if method == SMJ:
            if lpart != lkey:
                count += 1
            if rpart != rkey:
                count += 1
            out_part = lkey
        else:
            out_part = rpart if lb <= rb else lpart
        # crude size propagation for planning purposes only
        return max(lb, rb), out_part

    visit(plan)
    return count


HookFn = Callable[[RuntimeState], Optional[Node]]


def annotate_methods(plan: Node, query: Query, est: Estimator,
                     cluster: ClusterModel) -> Node:
    """Static (pre-execution) operator selection from ESTIMATES — what the
    planner believes; AQE may later override with actual sizes."""
    def est_bytes(node) -> float:
        if isinstance(node, Leaf):
            return est.base_bytes(query, node.alias)
        return max(est_bytes(node.left), est_bytes(node.right))

    def visit(node):
        if isinstance(node, Leaf):
            return
        visit(node.left)
        visit(node.right)
        lb, rb = est_bytes(node.left), est_bytes(node.right)
        node.method = BHJ if min(lb, rb) < cluster.bjt else SMJ
    visit(plan)
    return plan


def run_adaptive(db: Database, query: Query, plan: Node, est: Estimator,
                 cluster: ClusterModel = ClusterModel(),
                 hook: Optional[HookFn] = None,
                 max_hook_steps: int = 3,
                 plan_time: float = 0.0,
                 aqe_switching: bool = True) -> RunResult:
    """Execute `plan` stage-by-stage with AQE + optional extension hook.

    The hook is invoked at stage boundaries (including once pre-execution,
    matching AQORA's two-phase optimization) at most `max_hook_steps` times;
    it may return a REPLACEMENT remaining plan (built from the same leaves).
    """
    ex = Executor(db, cluster)
    state = RuntimeState(query, copy_plan(plan), {}, est, 0, 0.0, 0)
    stages: List[StageRecord] = []
    tot_shuffles, tot_sbytes = 0, 0.0
    bushy = False

    def charge(seconds: float):
        state.elapsed += seconds
        if state.elapsed >= cluster.timeout:
            raise QueryFailure("timeout", f"{state.elapsed:.1f}s")

    try:
        while True:
            # ---- extension hook (pre-exec at step 0, then per stage)
            if hook is not None and state.step < max_hook_steps:
                new_plan = hook(state)
                state.step += 1
                if new_plan is not None:
                    state.plan = new_plan
            if isinstance(state.plan, Leaf):
                # plan may be a single leaf only if query has 1 relation
                if state.plan.covered() not in state.mats:
                    m, secs = ex.scan(query, state.plan.alias)
                    charge(secs)
                    state.mats[m.aliases] = m
                break

            # ---- find next executable join (leftmost-deepest)
            def next_join(node) -> Optional[Join]:
                if isinstance(node, Leaf):
                    return None
                j = next_join(node.left)
                if j is not None:
                    return j
                j = next_join(node.right)
                if j is not None:
                    return j
                if isinstance(node.left, Leaf) and isinstance(node.right, Leaf):
                    return node
                return None

            jn = next_join(state.plan)
            assert jn is not None
            # materialize child scans
            sides = []
            for ch in (jn.left, jn.right):
                key = ch.covered()
                if key not in state.mats:
                    m, secs = ex.scan(query, ch.alias)
                    charge(secs)
                    state.mats[key] = m
                sides.append(state.mats[key])
            left_m, right_m = sides

            # ---- AQE operator selection with ACTUAL sizes (Spark rule)
            method = jn.method
            hinted = any(isinstance(ch, Leaf) and ch.broadcast_hint
                         for ch in (jn.left, jn.right))
            if hinted:
                method = BHJ
            elif aqe_switching:
                # Spark AQE: re-decide from ACTUAL sizes at the boundary
                method = BHJ if min(left_m.bytes, right_m.bytes) < cluster.bjt \
                    else SMJ

            # joining two multi-alias intermediates == bushy shape (§VI-B1)
            if len(left_m.aliases) > 1 and len(right_m.aliases) > 1:
                bushy = True
            out, rec = ex.join(query, left_m, right_m, jn.conds, method)
            charge(rec.seconds)
            stages.append(rec)
            tot_shuffles += rec.shuffles
            tot_sbytes += rec.shuffle_bytes
            state.stages_done += 1
            state.mats[out.aliases] = out

            # ---- replace the executed join by a stage-result leaf
            new_leaf = Leaf(out.aliases, stage_id=state.stages_done)

            def replace(node):
                if node is jn:
                    return new_leaf
                if isinstance(node, Leaf):
                    return node
                node.left = replace(node.left)
                node.right = replace(node.right)
                return node

            state.plan = replace(state.plan)
            if isinstance(state.plan, Leaf):
                break
    except QueryFailure as f:
        return RunResult(cluster.timeout, plan_time, True, f.kind, stages,
                         tot_shuffles, tot_sbytes, state.plan, bushy)
    return RunResult(state.elapsed, plan_time, False, "", stages,
                     tot_shuffles, tot_sbytes, state.plan, bushy)


