"""Query model: join graphs with per-relation filters.

A Query is a connected equi-join graph over table *aliases* (self-joins get
distinct aliases, as in JOB) plus conjunctive filters. The syntactic order
of `relations` is what Spark executes when the CBO is off ("directly
executes the join order specified in the input SQL text", §VII-B2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Filter:
    column: str
    op: str              # "<=", ">=", "==", "in"
    value: Tuple         # scalar or tuple of values

    def apply(self, arr: np.ndarray) -> np.ndarray:
        if self.op == "<=":
            return arr <= self.value[0]
        if self.op == ">=":
            return arr >= self.value[0]
        if self.op == "==":
            return arr == self.value[0]
        if self.op == "in":
            return np.isin(arr, np.asarray(self.value))
        raise ValueError(self.op)

    def selectivity_est(self, cstats) -> float:
        """CBO selectivity estimate (uniformity assumption)."""
        lo, hi, nd = cstats.min_val, cstats.max_val, cstats.n_distinct
        width = max(hi - lo, 1.0)
        if self.op == "<=":
            return float(np.clip((self.value[0] - lo + 1) / width, 0.0, 1.0))
        if self.op == ">=":
            return float(np.clip((hi - self.value[0] + 1) / width, 0.0, 1.0))
        if self.op == "==":
            return 1.0 / nd
        if self.op == "in":
            return min(1.0, len(self.value) / nd)
        raise ValueError(self.op)


@dataclasses.dataclass(frozen=True)
class Relation:
    alias: str
    table: str
    filters: Tuple[Filter, ...] = ()


@dataclasses.dataclass(frozen=True)
class JoinCond:
    """Equi-join: left_alias.left_col == right_alias.right_col."""
    left: str
    lcol: str
    right: str
    rcol: str

    def touches(self, alias: str) -> bool:
        return self.left == alias or self.right == alias

    def other(self, alias: str) -> str:
        return self.right if self.left == alias else self.left


@dataclasses.dataclass(frozen=True)
class Query:
    name: str
    relations: Tuple[Relation, ...]          # syntactic order
    conds: Tuple[JoinCond, ...]

    @property
    def n_relations(self) -> int:
        return len(self.relations)

    def relation(self, alias: str) -> Relation:
        for r in self.relations:
            if r.alias == alias:
                return r
        raise KeyError(alias)

    def adjacency(self) -> Dict[str, List[str]]:
        adj: Dict[str, List[str]] = {r.alias: [] for r in self.relations}
        for c in self.conds:
            adj[c.left].append(c.right)
            adj[c.right].append(c.left)
        return adj

    def conds_between(self, covered: frozenset, alias_set: frozenset):
        """Join conditions linking two disjoint alias sets."""
        out = []
        for c in self.conds:
            if ((c.left in covered and c.right in alias_set) or
                    (c.right in covered and c.left in alias_set)):
                out.append(c)
        return out

    def is_connected(self) -> bool:
        if not self.relations:
            return False
        adj = self.adjacency()
        seen = {self.relations[0].alias}
        stack = [self.relations[0].alias]
        while stack:
            for nxt in adj[stack.pop()]:
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append(nxt)
        return len(seen) == len(self.relations)
