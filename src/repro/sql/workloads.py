"""Benchmark workloads: templated query generators (§VII-A4b).

Each template fixes a join graph; instantiation randomizes predicate
constants while preserving the join structure — exactly the paper's query
generation. JOB-like: 16 templates over the 21-table schema joining 4-17
relations. ExtJOB-like: 12 templates with *different join graphs* over the
same schema (snowflake chains and person-centric shapes). STACK-like: 12
templates over the 10-table schema joining 4-12 relations.

Train sets are generated from templates with a seeded RNG; test sets use a
disjoint seed range (JOB/ExtJOB test = the canonical instantiation per
template variant, STACK test = extra instantiations), mirroring §VII-A4b.
The partition is the repo-wide contract in `repro.gen.seeds`: train draws
from `default_rng(train_seed(base))`, test from
`default_rng(test_seed(base))` = base + TRAIN_TEST_SEED_GAP, and
`make_workload` asserts the base seed sits inside one partitionable span
so no caller's train range can collide with another's test range.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import numpy as np

from repro.gen.seeds import split_train_test
from repro.sql.query import Filter, JoinCond, Query, Relation


def _yr(rng, lo=1920, hi=2013, width=(3, 40)):
    a = int(rng.integers(lo, hi))
    w = int(rng.integers(*width))
    return Filter("production_year", ">=", (a - w,)), Filter("production_year", "<=", (a,))


def _in(rng, col, n_max, k=(1, 6)):
    kk = int(rng.integers(*k))
    vals = tuple(int(v) for v in rng.choice(n_max, size=min(kk, n_max), replace=False))
    return Filter(col, "in", vals)


# ------------------------------------------------------------------ JOB-like
def _job_templates() -> List[Tuple[str, Callable]]:
    """Each returns (relations, conds) given an rng. Aliases follow JOB
    conventions (t=title, mc=movie_companies, ci=cast_info, mi=movie_info,
    mk=movie_keyword, ...)."""
    T = []

    def base(rng, extra: Sequence[str], t_filters=True, fact_filters=()):
        rels = [Relation("t", "title",
                         tuple(_yr(rng)) if t_filters else ())]
        conds = []
        fk = {"mc": ("movie_companies", "movie_id"),
              "ci": ("cast_info", "movie_id"),
              "mi": ("movie_info", "movie_id"),
              "miidx": ("movie_info_idx", "movie_id"),
              "mk": ("movie_keyword", "movie_id"),
              "at": ("aka_title", "movie_id"),
              "cc": ("complete_cast", "movie_id"),
              "ml": ("movie_link", "movie_id")}
        dim = {"cn": ("company_name", "mc", "company_id", "id"),
               "ct": ("company_type", "mc", "company_type_id", "id"),
               "n": ("name", "ci", "person_id", "id"),
               "rt": ("role_type", "ci", "role_id", "id"),
               "chn": ("char_name", "ci", "person_id", "id"),
               "it": ("info_type", "mi", "info_type_id", "id"),
               "it2": ("info_type", "miidx", "info_type_id", "id"),
               "k": ("keyword", "mk", "keyword_id", "id"),
               "kt": ("kind_type", "t", "kind_id", "id"),
               "lt": ("link_type", "ml", "link_type_id", "id"),
               "cct": ("comp_cast_type", "cc", "subject_id", "id"),
               "an": ("aka_name", "n", "id", "person_id"),
               "pi": ("person_info", "n", "id", "person_id")}
        for a in extra:
            if a in fk:
                tab, col = fk[a]
                f = []
                if a == "mi":
                    f = [_in(rng, "info_type_id", 110, (1, 4))]
                if a == "mk" and rng.random() < 0.7:
                    f = [_in(rng, "keyword_id", 400, (1, 8))]
                if a == "ci" and rng.random() < 0.5:
                    f = [_in(rng, "role_id", 12, (1, 3))]
                rels.append(Relation(a, tab, tuple(f)))
                conds.append(JoinCond("t", "id", a, "movie_id"))
            else:
                tab, parent, pcol, mycol = dim[a]
                f = []
                if a == "cn":
                    f = [_in(rng, "country_code", 60, (1, 3))]
                if a == "n" and rng.random() < 0.5:
                    f = [Filter("gender", "==", (int(rng.integers(0, 3)),))]
                if a == "k":
                    f = [_in(rng, "id", 400, (1, 10))]
                rels.append(Relation(a, tab, tuple(f)))
                conds.append(JoinCond(parent, pcol, a, mycol))
        return tuple(rels), tuple(conds)

    T.append(("q1", lambda rng: base(rng, ["mc", "cn", "ct"])))                       # 4
    T.append(("q2", lambda rng: base(rng, ["mk", "k", "mc", "cn"])))                  # 5
    T.append(("q3", lambda rng: base(rng, ["mi", "it", "mk", "k"])))                  # 5
    T.append(("q4", lambda rng: base(rng, ["ci", "n", "rt", "mc"])))                  # 5
    T.append(("q5", lambda rng: base(rng, ["ci", "n", "mk", "k", "kt"])))             # 6
    T.append(("q6", lambda rng: base(rng, ["mc", "cn", "mi", "it", "mk", "k"])))      # 7
    T.append(("q7", lambda rng: base(rng, ["ci", "n", "an", "pi", "mc", "cn"])))      # 7
    T.append(("q8", lambda rng: base(rng, ["ci", "n", "rt", "mi", "it", "mk", "k"])))  # 8
    T.append(("q9", lambda rng: base(rng, ["mc", "cn", "ct", "mi", "miidx", "it", "it2"])))  # 8
    T.append(("q10", lambda rng: base(rng, ["ci", "n", "chn", "rt", "mc", "cn", "ct", "kt"])))  # 9
    T.append(("q11", lambda rng: base(rng, ["ml", "lt", "mk", "k", "mc", "cn", "mi", "it"])))   # 9
    T.append(("q12", lambda rng: base(rng, ["cc", "cct", "mk", "k", "mi", "it", "ci", "n", "kt"])))  # 10
    T.append(("q13", lambda rng: base(rng, ["ci", "n", "an", "pi", "mi", "it", "mk", "k", "mc", "cn", "ct"])))  # 12
    T.append(("q14", lambda rng: base(rng, ["ml", "lt", "cc", "cct", "mk", "k", "mi", "miidx", "it", "it2", "mc", "cn"])))  # 13
    T.append(("q15", lambda rng: base(rng, ["ci", "n", "chn", "rt", "an", "pi", "mi", "it", "mk", "k", "mc", "cn", "ct", "kt"])))  # 15
    T.append(("q16", lambda rng: base(rng, ["ml", "lt", "ci", "n", "rt", "an", "pi", "mi", "miidx", "it", "it2", "mk", "k", "mc", "cn", "ct"])))  # 17
    return T


# --------------------------------------------------------------- ExtJOB-like
def _extjob_templates() -> List[Tuple[str, Callable]]:
    """Different join graphs over the same schema: person-centric snowflakes
    and link-chains absent from the JOB-like set (the paper's ExtJOB has
    'entirely different join graphs and predicates')."""
    T = []

    def person_centric(rng, extra):
        """Root at `name`, hang the movie side off cast_info."""
        rels = [Relation("n", "name",
                         (Filter("gender", "==", (int(rng.integers(0, 3)),)),)),
                Relation("ci", "cast_info",
                         (_in(rng, "role_id", 12, (1, 4)),)),
                Relation("t", "title", tuple(_yr(rng)))]
        conds = [JoinCond("n", "id", "ci", "person_id"),
                 JoinCond("ci", "movie_id", "t", "id")]
        grow = {"pi": ("person_info", JoinCond("n", "id", "pi", "person_id"),
                       [_in(rng, "info_type_id", 40, (1, 4))]),
                "an": ("aka_name", JoinCond("n", "id", "an", "person_id"), []),
                "mk": ("movie_keyword", JoinCond("t", "id", "mk", "movie_id"),
                       [_in(rng, "keyword_id", 400, (1, 8))]),
                "k": ("keyword", JoinCond("mk", "keyword_id", "k", "id"), []),
                "mc": ("movie_companies", JoinCond("t", "id", "mc", "movie_id"), []),
                "cn": ("company_name", JoinCond("mc", "company_id", "cn", "id"),
                       [_in(rng, "country_code", 60, (1, 4))]),
                "mi": ("movie_info", JoinCond("t", "id", "mi", "movie_id"),
                       [_in(rng, "info_type_id", 110, (1, 4))]),
                "kt": ("kind_type", JoinCond("t", "kind_id", "kt", "id"), [])}
        for a in extra:
            tab, cond, f = grow[a]
            rels.append(Relation(a, tab, tuple(f)))
            conds.append(cond)
        return tuple(rels), tuple(conds)

    def link_chain(rng, extra):
        """movie_link chain: t -(ml)-> t2 with decorations."""
        rels = [Relation("t", "title", tuple(_yr(rng))),
                Relation("ml", "movie_link", ()),
                Relation("t2", "title", ()),
                Relation("lt", "link_type",
                         (_in(rng, "id", 18, (1, 4)),))]
        conds = [JoinCond("t", "id", "ml", "movie_id"),
                 JoinCond("ml", "linked_movie_id", "t2", "id"),
                 JoinCond("ml", "link_type_id", "lt", "id")]
        grow = {"mk2": ("movie_keyword", JoinCond("t2", "id", "mk2", "movie_id"),
                        [_in(rng, "keyword_id", 400, (1, 8))]),
                "mc": ("movie_companies", JoinCond("t", "id", "mc", "movie_id"), []),
                "cn": ("company_name", JoinCond("mc", "company_id", "cn", "id"),
                       [_in(rng, "country_code", 60, (1, 4))]),
                "mi2": ("movie_info", JoinCond("t2", "id", "mi2", "movie_id"),
                        [_in(rng, "info_type_id", 110, (1, 4))]),
                "ci2": ("cast_info", JoinCond("t2", "id", "ci2", "movie_id"), []),
                "n2": ("name", JoinCond("ci2", "person_id", "n2", "id"), [])}
        for a in extra:
            tab, cond, f = grow[a]
            rels.append(Relation(a, tab, tuple(f)))
            conds.append(cond)
        return tuple(rels), tuple(conds)

    T.append(("e1", lambda rng: person_centric(rng, [])))                      # 3
    T.append(("e2", lambda rng: person_centric(rng, ["pi"])))                  # 4
    T.append(("e3", lambda rng: person_centric(rng, ["an", "pi"])))            # 5
    T.append(("e4", lambda rng: person_centric(rng, ["mk", "k"])))             # 5
    T.append(("e5", lambda rng: person_centric(rng, ["mk", "k", "kt"])))       # 6
    T.append(("e6", lambda rng: person_centric(rng, ["mc", "cn", "mi"])))      # 6
    T.append(("e7", lambda rng: person_centric(rng, ["pi", "an", "mk", "k", "mc", "cn"])))  # 9
    T.append(("e8", lambda rng: link_chain(rng, [])))                          # 4
    T.append(("e9", lambda rng: link_chain(rng, ["mk2"])))                     # 5
    T.append(("e10", lambda rng: link_chain(rng, ["mc", "cn"])))               # 6
    T.append(("e11", lambda rng: link_chain(rng, ["mi2", "ci2", "n2"])))       # 7
    T.append(("e12", lambda rng: link_chain(rng, ["mk2", "mi2", "mc", "cn", "ci2", "n2"])))  # 10
    return T


# ---------------------------------------------------------------- STACK-like
def _stack_templates() -> List[Tuple[str, Callable]]:
    T = []

    def base(rng, extra):
        rels = [Relation("s", "site", (_in(rng, "id", 40, (1, 4)),)),
                Relation("q", "question",
                         (Filter("score", ">=", (int(rng.integers(0, 20)),)),)),
                Relation("tq", "tag_question", ()),
                Relation("tg", "tag", (_in(rng, "id", 600, (1, 10)),))]
        conds = [JoinCond("q", "site_id", "s", "id"),
                 JoinCond("tq", "question_id", "q", "id"),
                 JoinCond("tq", "tag_id", "tg", "id")]
        grow = {"a": ("answer", JoinCond("a", "question_id", "q", "id"), []),
                "u": ("so_user", JoinCond("q", "owner_user_id", "u", "id"),
                      [Filter("reputation", ">=", (int(rng.integers(0, 60)),))]),
                "u2": ("so_user", JoinCond("a", "owner_user_id", "u2", "id"), []),
                "acc": ("account", JoinCond("u", "account_id", "acc", "id"),
                        [_in(rng, "website_kind", 5, (1, 2))]),
                "b": ("badge", JoinCond("b", "user_id", "u", "id"),
                      [_in(rng, "badge_kind", 40, (1, 6))]),
                "c": ("comment", JoinCond("c", "post_id", "q", "id"), []),
                "pl": ("post_link", JoinCond("pl", "question_id", "q", "id"), []),
                "q2": ("question", JoinCond("pl", "related_question_id", "q2", "id"), [])}
        for a in extra:
            tab, cond, f = grow[a]
            rels.append(Relation(a, tab, tuple(f)))
            conds.append(cond)
        return tuple(rels), tuple(conds)

    T.append(("s1", lambda rng: base(rng, [])))                                # 4
    T.append(("s2", lambda rng: base(rng, ["a"])))                             # 5
    T.append(("s3", lambda rng: base(rng, ["u"])))                             # 5
    T.append(("s4", lambda rng: base(rng, ["u", "acc"])))                      # 6
    T.append(("s5", lambda rng: base(rng, ["a", "u2"])))                       # 6
    T.append(("s6", lambda rng: base(rng, ["u", "b"])))                        # 6
    T.append(("s7", lambda rng: base(rng, ["c"])))                             # 5
    T.append(("s8", lambda rng: base(rng, ["pl", "q2"])))                      # 6
    T.append(("s9", lambda rng: base(rng, ["a", "u", "acc"])))                 # 7
    T.append(("s10", lambda rng: base(rng, ["a", "u2", "c", "pl", "q2"])))     # 9
    T.append(("s11", lambda rng: base(rng, ["u", "acc", "b", "a", "u2"])))     # 9
    T.append(("s12", lambda rng: base(rng, ["a", "u", "u2", "acc", "b", "c", "pl", "q2"])))  # 12
    return T


def shuffle_relations(rels, conds, rng) -> Tuple:
    """Randomize the FROM-clause order (real SQL authors don't order joins
    for the executor; Spark's no-CBO path executes the text order, which is
    what makes the paper's Spark-default baseline fail on 9-30% of queries).
    The first relation is kept with prob 0.5 so some queries stay easy."""
    rels = list(rels)
    if rng.random() < 0.5:
        head, tail = rels[:1], rels[1:]
        rng.shuffle(tail)
        rels = head + tail
    else:
        rng.shuffle(rels)
    return tuple(rels), conds


@dataclasses.dataclass
class Workload:
    name: str
    max_tables: int
    train: List[Query]
    test: List[Query]


_BENCH = {"job": _job_templates, "extjob": _extjob_templates,
          "stack": _stack_templates}


def query_stream(bench: str, seed: int = 0):
    """Endless generator of fresh template instantiations (round-robin over
    the benchmark's templates) — the unbounded query source the online
    serving driver (`serve.driver`) feeds from."""
    templates = _BENCH[bench]()
    rng = np.random.default_rng(seed)
    i = 0
    while True:
        tname, fn = templates[i % len(templates)]
        rels, conds = shuffle_relations(*fn(rng), rng)
        yield Query(f"{bench}/{tname}#st{i}", rels, conds)
        i += 1


def make_workload(bench: str, n_train: int = 200, n_test_per_template: int = 2,
                  seed: int = 7) -> Workload:
    """Train/test instantiations of `bench`'s templates. `seed` is a BASE
    seed under the `repro.gen.seeds` partition: train constants come from
    the train stream, test constants from the disjoint test stream
    (asserted partitionable — the streams provably never overlap)."""
    templates = _BENCH[bench]()
    train_s, test_s = split_train_test(seed)
    train: List[Query] = []
    rng = np.random.default_rng(train_s)
    i = 0
    while len(train) < n_train:
        tname, fn = templates[i % len(templates)]
        rels, conds = shuffle_relations(*fn(rng), rng)
        train.append(Query(f"{bench}/{tname}#tr{len(train)}", rels, conds))
        i += 1
    test: List[Query] = []
    rng_t = np.random.default_rng(test_s)
    for tname, fn in templates:
        for j in range(n_test_per_template):
            rels, conds = shuffle_relations(*fn(rng_t), rng_t)
            test.append(Query(f"{bench}/{tname}#{j}", rels, conds))
    mt = max(q.n_relations for q in train + test)
    return Workload(bench, mt, train, test)
