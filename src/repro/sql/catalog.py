"""Catalog: tables as column arrays + (possibly stale) optimizer statistics.

The engine executes on exact numpy columns; the CBO sees only `Stats`
(row counts + per-column distinct counts estimated FROM A SAMPLE, optionally
computed on an older version of the data) — reproducing the paper's central
premise that pre-execution estimates are unreliable while runtime
cardinalities are exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

ROW_OVERHEAD_BYTES = 8          # per column per row (int64 columns)


@dataclasses.dataclass
class Table:
    name: str
    columns: Dict[str, np.ndarray]

    @property
    def nrows(self) -> int:
        return 0 if not self.columns else len(next(iter(self.columns.values())))

    @property
    def ncols(self) -> int:
        return len(self.columns)

    def bytes(self) -> int:
        return self.nrows * self.ncols * ROW_OVERHEAD_BYTES


@dataclasses.dataclass
class ColumnStats:
    n_distinct: float
    min_val: float
    max_val: float


@dataclasses.dataclass
class TableStats:
    nrows: float
    columns: Dict[str, ColumnStats]


@dataclasses.dataclass
class Stats:
    """What the CBO believes. Built by `analyze(db, sample, noise)`; can be
    built from an old snapshot for the dynamic-evaluation experiments.
    `versions` records each table's data version AT ANALYZE TIME, so the
    drift detector can measure catalog lag even for staleness that
    predates its attachment (None for hand-built snapshots: lag is then
    baselined at attach)."""
    tables: Dict[str, TableStats]
    versions: Optional[Dict[str, int]] = None


@dataclasses.dataclass
class Database:
    name: str
    tables: Dict[str, Table]
    stats: Optional[Stats] = None
    # per-table data versions: bumped by delta application (serve.deltas);
    # stage-cache signatures embed these tags, so a bump invalidates every
    # cached stage derived from the old data in O(1). `stats` is NOT
    # refreshed on a bump — stale optimizer statistics over fresh data is
    # the paper's dynamic-evaluation premise.
    versions: Dict[str, int] = dataclasses.field(default_factory=dict)

    def table(self, name: str) -> Table:
        return self.tables[name]

    def table_version(self, name: str) -> int:
        return self.versions.get(name, 0)

    def bump_version(self, name: str) -> int:
        """Record that `name`'s data changed; notifies an attached stage
        cache (if any) so invalidations are observable in its counters."""
        self.versions[name] = self.versions.get(name, 0) + 1
        cache = getattr(self, "_stage_cache", None)
        if cache is not None and hasattr(cache, "note_invalidation"):
            cache.note_invalidation(name)
        return self.versions[name]


def analyze_table(db: Database, name: str, sample_frac: float = 0.05,
                  rng: Optional[np.random.Generator] = None) -> TableStats:
    """ANALYZE one table: sample-based statistics (distinct counts via
    sample-scale-up — systematically wrong under skew, as in real systems).
    The incremental unit behind `analyze`; the drift control plane
    (`serve.drift`) calls it per drifted table instead of re-scanning the
    whole catalog."""
    rng = rng if rng is not None else np.random.default_rng(0)
    t = db.table(name)
    cols: Dict[str, ColumnStats] = {}
    n = t.nrows
    k = max(32, int(n * sample_frac))
    idx = rng.integers(0, max(n, 1), size=min(k, n)) if n else np.zeros(0, np.int64)
    for cname, arr in t.columns.items():
        s = arr[idx] if n else arr
        d_sample = len(np.unique(s)) if len(s) else 0
        # first-order jackknife scale-up (biased low under Zipf skew)
        frac = len(s) / max(n, 1)
        nd = d_sample / max(frac ** 0.5, 1e-9) if n else 0
        nd = min(nd, n)
        cols[cname] = ColumnStats(
            n_distinct=max(nd, 1.0),
            min_val=float(arr.min()) if n else 0.0,
            max_val=float(arr.max()) if n else 0.0)
    return TableStats(nrows=float(n), columns=cols)


def analyze(db: Database, sample_frac: float = 0.05,
            rng: Optional[np.random.Generator] = None) -> Stats:
    """ANALYZE TABLE over the whole catalog (one shared rng, so the draw
    sequence is unchanged from the original single-pass implementation);
    stamps the data versions the statistics were taken at."""
    rng = rng if rng is not None else np.random.default_rng(0)
    return Stats(tables={name: analyze_table(db, name, sample_frac, rng)
                         for name in db.tables},
                 versions={name: db.table_version(name)
                           for name in db.tables})
