"""Physical plan trees + the paper's Alg. 2 swap/lead transformation.

A plan is a binary tree of Join nodes over Leaf nodes. A Leaf is either a
base-table scan or a *stage result* (a materialized intermediate covering
several aliases) — during adaptive execution the remaining plan's leaves
are exactly these two kinds, matching the paper's observation that "during
AQE, even leaf nodes may touch multiple tables" (§V-B2).

Join methods: SMJ (shuffle sort-merge: both inputs hash-repartitioned on the
join key unless already partitioned on it) and BHJ (broadcast hash join:
build side replicated to every executor, probe side pipelined, no shuffle).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

from repro.sql.query import JoinCond, Query

SMJ = "SMJ"
BHJ = "BHJ"


@dataclasses.dataclass
class Leaf:
    aliases: frozenset                 # alias set covered
    stage_id: Optional[int] = None     # None -> base scan, else intermediate
    broadcast_hint: bool = False

    @property
    def alias(self) -> str:
        assert len(self.aliases) == 1
        return next(iter(self.aliases))

    def covered(self) -> frozenset:
        return self.aliases


@dataclasses.dataclass
class Join:
    left: "Node"
    right: "Node"
    conds: Tuple[JoinCond, ...]
    method: str = SMJ                  # planner's choice; AQE may switch

    def covered(self) -> frozenset:
        return self.left.covered() | self.right.covered()


Node = object  # Leaf | Join


# ------------------------------------------------------------------ helpers
def leaves(plan: Node) -> List[Leaf]:
    """Left-to-right leaf order (the paper's 1-indexed leaf positions)."""
    if isinstance(plan, Leaf):
        return [plan]
    return leaves(plan.left) + leaves(plan.right)


def joins(plan: Node) -> List[Join]:
    if isinstance(plan, Leaf):
        return []
    return joins(plan.left) + joins(plan.right) + [plan]


def count_nodes(plan: Node) -> int:
    if isinstance(plan, Leaf):
        return 1
    return 1 + count_nodes(plan.left) + count_nodes(plan.right)


def is_bushy(plan: Node) -> bool:
    """True if some join's right child is itself a join."""
    if isinstance(plan, Leaf):
        return False
    return isinstance(plan.right, Join) or is_bushy(plan.left) or is_bushy(plan.right)


def copy_plan(plan: Node) -> Node:
    if isinstance(plan, Leaf):
        return Leaf(plan.aliases, plan.stage_id, plan.broadcast_hint)
    return Join(copy_plan(plan.left), copy_plan(plan.right), plan.conds,
                plan.method)


# ------------------------------------------------------------------ builders
def build_left_deep(query: Query, leaf_order: List[Leaf]) -> Optional[Node]:
    """Alg. 2 core loop: fold leaves left-deep, requiring a join condition
    connecting each new leaf to the prefix (no Cartesian products).
    Returns None if the order is infeasible."""
    plan: Node = leaf_order[0]
    for lf in leaf_order[1:]:
        cs = query.conds_between(frozenset(plan.covered()), frozenset(lf.covered()))
        if not cs:
            return None
        plan = Join(plan, lf, tuple(cs), SMJ)
    return plan


def syntactic_plan(query: Query) -> Node:
    """Spark's no-CBO behaviour: the join order as written in the SQL text."""
    order = [Leaf(frozenset([r.alias])) for r in query.relations]
    plan = build_left_deep(query, order)
    if plan is None:                    # re-greedy from the first relation
        plan = greedy_connected(query, order)
    return plan


def greedy_connected(query: Query, order: List[Leaf]) -> Node:
    """Fallback: keep syntactic order but defer leaves until connected."""
    remaining = list(order)
    plan: Node = remaining.pop(0)
    while remaining:
        for i, lf in enumerate(remaining):
            cs = query.conds_between(frozenset(plan.covered()),
                                     frozenset(lf.covered()))
            if cs:
                plan = Join(plan, remaining.pop(i), tuple(cs), SMJ)
                break
        else:
            raise ValueError(f"{query.name}: join graph disconnected")
    return plan


# ------------------------------------------------------------------ Alg. 2
def apply_swap(query: Query, plan: Node, i: int, j: int) -> Optional[Node]:
    """swap(i, j): exchange the i-th and j-th leaves (1-indexed), rebuild
    left-deep over the new order; None if infeasible (would need a cross
    join) — the runtime then keeps the original plan (Alg. 2 line 9)."""
    lvs = [copy_leaf(l) for l in leaves(plan)]
    n = len(lvs)
    if not (1 <= i < j <= n):
        return None
    lvs[i - 1], lvs[j - 1] = lvs[j - 1], lvs[i - 1]
    return build_left_deep(query, lvs)


def apply_lead(query: Query, plan: Node, i: int) -> Optional[Node]:
    """lead(i): move the i-th leaf to the front (join it first)."""
    lvs = [copy_leaf(l) for l in leaves(plan)]
    n = len(lvs)
    if not (1 <= i <= n) or i == 1:
        return None
    lvs = [lvs[i - 1]] + lvs[:i - 1] + lvs[i:]
    return build_left_deep(query, lvs)


def apply_broadcast(plan: Node, i: int) -> Optional[Node]:
    """broadcast(i): annotate the i-th leaf with a BROADCAST hint; the
    planner then forces BHJ for the join touching it (bottom-up search,
    §VI-B2)."""
    new = copy_plan(plan)
    lvs = leaves(new)
    if not (1 <= i <= len(lvs)) or lvs[i - 1].broadcast_hint:
        return None
    lvs[i - 1].broadcast_hint = True
    return new


def copy_leaf(l: Leaf) -> Leaf:
    return Leaf(l.aliases, l.stage_id, l.broadcast_hint)
