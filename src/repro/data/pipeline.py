"""Deterministic, shardable, resumable LM data pipeline.

Properties a 1000-node deployment needs, all present here:

  * Determinism: batch(step, shard) is a pure function of (seed, step,
    shard) — recomputable anywhere, so a restarted/migrated host produces
    byte-identical data with no coordination.
  * Elastic resharding: shards are logical (n_logical >> n_hosts); a host
    owns a contiguous range, so pods joining/leaving only remaps ranges
    (runtime/elastic.py) without touching the stream contents.
  * Resumability: DataState is just (step,), checkpointed with the model.
  * Prefetch: a background thread keeps `depth` batches ready so host
    data work overlaps device compute.

The token source is a synthetic Zipf-distributed stream with document
structure (BOS-delimited docs, packed to seq_len) — the statistical shape
a real tokenized corpus has where it matters for throughput testing.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataState:
    step: int = 0

    def to_dict(self):
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(step=int(d["step"]))


class SyntheticLMPipeline:
    def __init__(self, *, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_logical_shards: int = 256,
                 shard_range=(0, 256), mean_doc_len: int = 512,
                 prefetch_depth: int = 2):
        assert global_batch % n_logical_shards == 0 or \
            n_logical_shards % global_batch == 0 or True
        self.vocab = vocab_size
        self.seq = seq_len
        self.gb = global_batch
        self.seed = seed
        self.n_logical = n_logical_shards
        self.shard_range = shard_range
        self.mean_doc = mean_doc_len
        self.state = DataState()
        self._q: Optional[queue.Queue] = None
        self._depth = prefetch_depth
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ----------------------------------------------------------- core
    def _shard_rows(self) -> int:
        lo, hi = self.shard_range
        frac = (hi - lo) / self.n_logical
        rows = int(round(self.gb * frac))
        return rows

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, shard_range): the host's slice of
        the global batch for `step`."""
        lo, hi = self.shard_range
        rows_per_shard = max(1, self.gb // self.n_logical)
        toks = []
        for shard in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, shard]))
            t = self._pack(rng, rows_per_shard)
            toks.append(t)
        tokens = np.concatenate(toks, axis=0)
        mask = (tokens != 0).astype(np.float32)
        return {"tokens": tokens, "loss_mask": mask}

    def _pack(self, rng, rows: int) -> np.ndarray:
        """BOS-delimited Zipf docs packed into rows of seq_len."""
        out = np.empty((rows, self.seq), np.int32)
        for r in range(rows):
            pos = 0
            row = np.empty(self.seq, np.int32)
            while pos < self.seq:
                dl = min(int(rng.exponential(self.mean_doc)) + 8,
                         self.seq - pos)
                row[pos] = 1                                   # BOS
                body = rng.zipf(1.3, size=dl - 1)
                row[pos + 1:pos + dl] = np.clip(body + 1, 2, self.vocab - 1)
                pos += dl
            out[r] = row
        return out

    # ----------------------------------------------------------- iteration
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._q is not None:
            b = self._q.get()
        else:
            b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # ----------------------------------------------------------- prefetch
    def start_prefetch(self):
        self._q = queue.Queue(maxsize=self._depth)
        self._stop.clear()
        start = self.state.step

        def worker():
            s = start
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop_prefetch(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
        self._q = None

    # ----------------------------------------------------------- elastic
    def reshard(self, shard_range) -> "SyntheticLMPipeline":
        """New pipeline serving a different logical-shard range at the SAME
        step (used on pod loss/join)."""
        p = SyntheticLMPipeline(
            vocab_size=self.vocab, seq_len=self.seq, global_batch=self.gb,
            seed=self.seed, n_logical_shards=self.n_logical,
            shard_range=shard_range, mean_doc_len=self.mean_doc,
            prefetch_depth=self._depth)
        p.state = DataState(self.state.step)
        return p
