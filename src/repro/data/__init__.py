from repro.data.pipeline import DataState, SyntheticLMPipeline
