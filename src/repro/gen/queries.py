"""QuerySampler: acyclic join templates over a sampled schema's FK graph.

BRAD-style: a template is a random TREE WALK over `spec.join_edges` —
each step attaches a NEW table through one fk edge, so every template is
connected and acyclic by construction and every join condition is an
equi-join between a real fk column and the dense key its values were
drawn from (no empty-result joins by construction). Filter SLOTS are
chosen per template (which columns, which shape); the CONSTANTS are
drawn per instantiation, always inside the column's declared [lo, hi)
domain, mirroring how the hand-built JOB/STACK templates randomize
predicates while preserving join structure:

  narrow cat (domain < 64)   IN filter, 1-5 values
  wide cat                   production_year-style closed range
  cat2                       IN over the union regime [0, max(hi_k, lo_k))
  id of a FIXED table        IN over [0, n_rows) (site-style; only fixed
                             tables, whose row count is scale-invariant,
                             can safely pin id constants)

`sample_templates(spec, seed, ...)` returns (name, fn(rng)) pairs with
the exact shape `sql.workloads` templates have, and
`make_gen_workload` / `gen_query_stream` mirror
`workloads.make_workload` / `workloads.query_stream` — including the
disjoint train/test seed partition from `repro.gen.seeds`, so generated
workloads plug into `WorkloadMeta.from_workload`, `AqoraAgent` and the
serving driver unchanged.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.gen.seeds import split_train_test
from repro.gen.spec import SchemaSpec, join_edges
from repro.sql.query import Filter, JoinCond, Query, Relation
from repro.sql.workloads import Workload, shuffle_relations

__all__ = ["sample_templates", "make_gen_workload", "gen_query_stream"]


# ------------------------------------------------------------ filter slots
def _filter_slots(spec: SchemaSpec, table: str, rng,
                  p_root: float) -> Tuple[Tuple, ...]:
    """Pick the filterable columns of `table` for one template. Each slot
    is (col_name, kind, lo, hi) with constants drawn at instantiation."""
    t = spec.table(table)
    slots = []
    for c in t.columns:
        if c.kind == "cat":
            if rng.random() >= p_root:
                continue
            if c.hi - c.lo < 64:
                slots.append((c.name, "in", c.lo, c.hi))
            else:
                slots.append((c.name, "range", c.lo, c.hi))
        elif c.kind == "cat2" and rng.random() < p_root:
            slots.append((c.name, "in", 0, max(c.hi_k, c.lo_k)))
        elif c.kind == "id" and t.fixed and rng.random() < p_root:
            slots.append((c.name, "in", 0, t.n_rows))
    return tuple(slots)


def _draw_filters(slots: Sequence[Tuple], rng) -> Tuple[Filter, ...]:
    out: List[Filter] = []
    for name, kind, lo, hi in slots:
        if kind == "in":
            k = int(rng.integers(1, min(6, hi - lo) + 1))
            vals = tuple(int(v) for v in
                         lo + rng.choice(hi - lo, size=k, replace=False))
            out.append(Filter(name, "in", vals))
        else:
            a = int(rng.integers(lo + 1, hi))
            w = int(rng.integers(max(1, (hi - lo) // 50),
                                 max(2, (hi - lo) // 3)))
            out.append(Filter(name, ">=", (max(lo, a - w),)))
            out.append(Filter(name, "<=", (a,)))
    return tuple(out)


# --------------------------------------------------------------- templates
def _sample_structure(spec: SchemaSpec, rng, n_tables: int):
    """One random join tree: (ordered tables, alias map, alias-level join
    conds). Walks fk edges outward from a random fact-ish root, adding
    only unvisited tables (acyclic + connected by construction). At most
    TWO fk children may share one parent key per template: a k-spoke star
    through a tiny Zipf hub multiplies the spokes' row counts and blows
    the executor's materialize cap under EVERY join order — the
    generator's job is controlled selectivity, so unfixable-by-planning
    queries are excluded by construction (deliberate stragglers live in
    tests/scenarios.py, not here)."""
    edges = join_edges(spec)
    assert edges, f"{spec.name}: no joinable fk edges"
    # roots that actually have edges; prefer fk-rich children (facts)
    fanout: Dict[str, int] = {}
    for c, _, p, _ in edges:
        fanout[c] = fanout.get(c, 0) + 1
        fanout.setdefault(p, 0)
    roots = sorted(fanout, key=lambda t: (-fanout[t], t))
    root = roots[int(rng.integers(max(1, min(3, len(roots)))))]
    chosen = [root]
    alias = {root: "r0"}
    kids: Dict[str, int] = {}        # parent table -> fk children in tree
    conds: List[Tuple[str, str, str, str]] = []   # (table, col, ptable, pcol)
    while len(chosen) < n_tables:
        grow = [(c, cc, p, pc) for c, cc, p, pc in edges
                if (c in alias) != (p in alias) and kids.get(p, 0) < 2]
        if not grow:
            break
        c, cc, p, pc = grow[int(rng.integers(len(grow)))]
        new = p if c in alias else c
        alias[new] = f"r{len(chosen)}"
        chosen.append(new)
        kids[p] = kids.get(p, 0) + 1
        conds.append((c, cc, p, pc))
    return chosen, alias, conds


def sample_templates(spec: SchemaSpec, seed: int, *, n_templates: int = 10,
                     t_min: int = 3, t_max: int = 8,
                     p_filter: float = 0.55
                     ) -> List[Tuple[str, Callable]]:
    """Template family for one schema: join structure + filter slots are
    fixed per template here; each call of a template's fn(rng) draws
    fresh predicate constants (exactly the `sql.workloads` contract)."""
    rng = np.random.default_rng(seed)
    n_avail = len({t for e in join_edges(spec) for t in (e[0], e[2])})
    templates: List[Tuple[str, Callable]] = []
    for i in range(n_templates):
        want = int(rng.integers(t_min, min(t_max, n_avail) + 1))
        tables, alias, conds = _sample_structure(spec, rng, want)
        slots = {t: _filter_slots(spec, t, rng,
                                  p_filter if t == tables[0] else
                                  p_filter * 0.6)
                 for t in tables}
        if not any(slots.values()):   # every template filters SOMETHING
            t0 = spec.table(tables[0])
            cands = [c for c in t0.columns if c.kind == "cat"]
            if cands:
                c = cands[0]
                kind = "in" if c.hi - c.lo < 64 else "range"
                slots[tables[0]] = ((c.name, kind, c.lo, c.hi),)

        def fn(rng, tables=tables, alias=alias, conds=conds, slots=slots):
            rels = tuple(Relation(alias[t], t, _draw_filters(slots[t], rng))
                         for t in tables)
            jc = tuple(JoinCond(alias[c], cc, alias[p], pc)
                       for c, cc, p, pc in conds)
            return rels, jc

        templates.append((f"g{i + 1}", fn))
    return templates


# ------------------------------------------------- workload / stream build
def make_gen_workload(spec: SchemaSpec, base_seed: int, *,
                      n_templates: int = 10, n_train: int = 40,
                      n_test_per_template: int = 2,
                      t_min: int = 3, t_max: int = 8) -> Workload:
    """`workloads.make_workload` over a sampled schema: train constants
    from the train seed stream, test from the disjoint test stream
    (`gen.seeds.split_train_test` — same partition the hand-built
    benchmarks use)."""
    templates = sample_templates(spec, base_seed, n_templates=n_templates,
                                 t_min=t_min, t_max=t_max)
    tr_seed, te_seed = split_train_test(base_seed)
    train: List[Query] = []
    rng = np.random.default_rng(tr_seed)
    i = 0
    while len(train) < n_train:
        tname, fn = templates[i % len(templates)]
        rels, conds = shuffle_relations(*fn(rng), rng)
        train.append(Query(f"{spec.name}/{tname}#tr{len(train)}", rels,
                           conds))
        i += 1
    test: List[Query] = []
    rng_t = np.random.default_rng(te_seed)
    for tname, fn in templates:
        for j in range(n_test_per_template):
            rels, conds = shuffle_relations(*fn(rng_t), rng_t)
            test.append(Query(f"{spec.name}/{tname}#{j}", rels, conds))
    mt = max(q.n_relations for q in train + test)
    return Workload(spec.name, mt, train, test)


def gen_query_stream(spec: SchemaSpec, base_seed: int, *,
                     n_templates: int = 10, t_min: int = 3, t_max: int = 8):
    """Endless generator of fresh instantiations (round-robin over the
    schema's templates) — the generated-world analogue of
    `workloads.query_stream`, for the open-loop serving driver."""
    templates = sample_templates(spec, base_seed, n_templates=n_templates,
                                 t_min=t_min, t_max=t_max)
    tr_seed, _ = split_train_test(base_seed)
    rng = np.random.default_rng(tr_seed)
    i = 0
    while True:
        tname, fn = templates[i % len(templates)]
        rels, conds = shuffle_relations(*fn(rng), rng)
        yield Query(f"{spec.name}/{tname}#st{i}", rels, conds)
        i += 1
