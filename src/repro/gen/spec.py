"""The world generator's schema grammar: declarative table/column specs.

A `SchemaSpec` is pure data — an ordered tuple of `TableSpec`s, each an
ordered tuple of `ColumnSpec`s — expressive enough that the hand-built
JOB-like and STACK-like schemas in `sql.datagen` are thin instances of
it, and constrained enough that every sampled instance is valid by
construction (acyclic FK DAG, dense join keys, joinable templates).

Column kinds (each maps onto exactly one numpy draw sequence, so a spec
plus a seed determines the database bit-for-bit — see
`sql.datagen.make_db_from_spec`):

  id    dense primary key 0..n-1 (no RNG draw). Any table that is the
        parent of an `fk` column must have one.
  cat   categorical/ordinal: uniform integers in [lo, hi). Wide ranges
        (e.g. production_year-like timestamps) support range filters;
        narrow ones support IN filters.
  cat2  two-regime categorical correlated with an earlier column of the
        same table: rows where `src` > `threshold` draw from [0, hi_k),
        the rest from [0, lo_k) — the title.kind_id-style correlation
        that breaks the CBO's independence assumption.
  fk    foreign key into `parent`'s dense id: Zipf-skewed with exponent
        `a` (hub identity SHARED across every fk into the same parent —
        the cross-table correlation) or uniform when `skew=False`. With
        `via=<col>` the drawn key is not stored; the parent's `via`
        column is gathered through it instead (the STACK
        answer.site_id = question.site_id[fk] hub correlation), which
        makes the column joinable against whatever `via` itself
        references.

`order` hoists a column's RNG draw ahead of the natural
table-major/column-minor sequence (the STACK schema draws
question.site_id before any other column); hoisting changes only WHEN
the draw happens, never where the column lands.

This module is dependency-free (numpy only): `sql.datagen` imports it to
materialize specs, and the samplers in `repro.gen` build on top.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["ColumnSpec", "TableSpec", "SchemaSpec", "id_col", "cat", "cat2",
           "fk", "spec_rows", "join_edges", "fk_parents", "assert_valid",
           "delete_safe_tables"]


@dataclasses.dataclass(frozen=True)
class ColumnSpec:
    name: str
    kind: str                      # "id" | "cat" | "cat2" | "fk"
    # cat: uniform integers in [lo, hi)
    lo: int = 0
    hi: int = 2
    # cat2: two-regime categorical correlated with `src` of the same table
    src: str = ""
    threshold: int = 0
    hi_k: int = 2                  # domain where src > threshold
    lo_k: int = 2                  # domain elsewhere
    # fk: keys into parent's dense id
    parent: str = ""
    a: float = 0.8                 # Zipf exponent (skew=True)
    skew: bool = True
    via: str = ""                  # gather parent's `via` column instead
    # global draw-order hoist (None = natural sequence)
    order: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """`n_rows` is the row count at scale=1.0; scaled tables follow
    `max(16, int(n_rows * scale))` while `fixed=True` tables (tiny
    enumeration dims like info_type) keep `n_rows` literally.
    `size_with` scales the realized count by the named table's
    realized/spec ratio — the cascade that shrinks fact tables when a
    root snapshot filter (e.g. IMDb-1980) drops rows."""
    name: str
    n_rows: int
    columns: Tuple[ColumnSpec, ...]
    fixed: bool = False
    size_with: str = ""


@dataclasses.dataclass(frozen=True)
class SchemaSpec:
    name: str
    tables: Tuple[TableSpec, ...]
    family: str = ""               # sampler family ("" = hand-built)

    def table(self, name: str) -> TableSpec:
        for t in self.tables:
            if t.name == name:
                return t
        raise KeyError(name)


# ------------------------------------------------------- column factories
def id_col() -> ColumnSpec:
    return ColumnSpec("id", "id")


def cat(name: str, lo: int, hi: int) -> ColumnSpec:
    return ColumnSpec(name, "cat", lo=lo, hi=hi)


def cat2(name: str, src: str, threshold: int, hi_k: int,
         lo_k: int) -> ColumnSpec:
    return ColumnSpec(name, "cat2", src=src, threshold=threshold,
                      hi_k=hi_k, lo_k=lo_k)


def fk(name: str, parent: str, a: float = 0.8, skew: bool = True,
       via: str = "", order: Optional[int] = None) -> ColumnSpec:
    return ColumnSpec(name, "fk", parent=parent, a=a, skew=skew, via=via,
                      order=order)


# ------------------------------------------------------------- derived
def spec_rows(t: TableSpec, scale: float) -> int:
    """Row count of `t` at `scale` before size_with cascades."""
    return t.n_rows if t.fixed else max(16, int(t.n_rows * scale))


def _resolve_join_target(spec: SchemaSpec, col: ColumnSpec,
                         depth: int = 0) -> Tuple[str, str]:
    """The (table, column) a fk column's VALUES join against: the
    parent's id for plain fks; for `via` gathers, whatever the parent's
    via column itself joins against (chased transitively)."""
    if not col.via:
        return col.parent, "id"
    assert depth < 8, "via chain too deep (cycle?)"
    pcol = next(c for c in spec.table(col.parent).columns
                if c.name == col.via)
    if pcol.kind == "fk":
        return _resolve_join_target(spec, pcol, depth + 1)
    return col.parent, col.via     # gathered attribute, not a key


def join_edges(spec: SchemaSpec) -> List[Tuple[str, str, str, str]]:
    """Equi-joinable edges (child_table, child_col, parent_table,
    parent_col): every fk column against the dense id (or gathered key)
    its values actually come from — the walkable graph the query sampler
    draws acyclic join trees over."""
    edges = []
    for t in spec.tables:
        for c in t.columns:
            if c.kind == "fk":
                pt, pc = _resolve_join_target(spec, c)
                if pc == "id":     # only key-valued columns are join edges
                    edges.append((t.name, c.name, pt, pc))
    return edges


def fk_parents(spec: SchemaSpec) -> Dict[str, List[str]]:
    """child table -> parent tables over RAW fk references (the FK DAG:
    `via` gathers still reference their immediate parent)."""
    out: Dict[str, List[str]] = {t.name: [] for t in spec.tables}
    for t in spec.tables:
        for c in t.columns:
            if c.kind == "fk":
                out[t.name].append(c.parent)
    return out


def delete_safe_tables(spec: SchemaSpec) -> Tuple[str, ...]:
    """Tables where row deletion cannot dangle a foreign key: no other
    table's fk targets them, and they carry no dense id (so no external
    contract on key density). These are the stream sampler's legal
    delete/update targets."""
    referenced = {c.parent for t in spec.tables for c in t.columns
                  if c.kind == "fk"}
    return tuple(t.name for t in spec.tables
                 if t.name not in referenced
                 and not any(c.kind == "id" for c in t.columns))


def assert_valid(spec: SchemaSpec) -> None:
    """Structural validity: unique names, every fk parent exists and has
    a dense id, `via`/`src` references resolve to earlier-materialized
    columns, and the FK reference graph is acyclic (so the join graph is
    walkable and materialization order is well-defined)."""
    names = [t.name for t in spec.tables]
    assert len(names) == len(set(names)), f"duplicate tables in {spec.name}"
    by_name = {t.name: t for t in spec.tables}
    pos = {t.name: i for i, t in enumerate(spec.tables)}
    for t in spec.tables:
        cnames = [c.name for c in t.columns]
        assert len(cnames) == len(set(cnames)), \
            f"duplicate columns in {spec.name}.{t.name}"
        seen = set()
        for c in t.columns:
            if c.kind == "fk":
                assert c.parent in by_name, \
                    f"{t.name}.{c.name}: unknown parent {c.parent}"
                parent = by_name[c.parent]
                assert any(pc.kind == "id" for pc in parent.columns), \
                    f"{t.name}.{c.name}: parent {c.parent} has no dense id"
                if c.via:
                    assert any(pc.name == c.via for pc in parent.columns), \
                        f"{t.name}.{c.name}: via {c.parent}.{c.via} missing"
                    assert pos[c.parent] < pos[t.name], \
                        f"{t.name}.{c.name}: via-parent {c.parent} must " \
                        f"be materialized earlier"
            elif c.kind == "cat2":
                src = next((s for s in t.columns if s.name == c.src), None)
                assert src is not None, \
                    f"{t.name}.{c.name}: cat2 src {c.src} missing"
                # the src must be DRAWN first: earlier in column order, or
                # hoisted ahead of this column's own draw slot
                drawn_first = c.src in seen or (
                    src.order is not None and
                    (c.order is None or src.order < c.order))
                assert drawn_first, \
                    f"{t.name}.{c.name}: cat2 src {c.src} drawn later"
            seen.add(c.name)
        if t.size_with:
            assert t.size_with in by_name and pos[t.size_with] < pos[t.name]
    # FK reference graph (child -> parent) must be acyclic
    parents = fk_parents(spec)
    state: Dict[str, int] = {}     # 0 visiting, 1 done

    def visit(n: str, trail: Tuple[str, ...]) -> None:
        if state.get(n) == 1:
            return
        assert state.get(n) != 0, \
            f"FK cycle in {spec.name}: {' -> '.join(trail + (n,))}"
        state[n] = 0
        for p in parents[n]:
            visit(p, trail + (n,))
        state[n] = 1

    for t in spec.tables:
        visit(t.name, ())
