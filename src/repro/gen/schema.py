"""SchemaSampler: seeded random FK-DAG schemas in three families.

Each family mirrors a real analytic shape (and one of the hand-built
worlds), so the serve/learn stack meets the same *kinds* of correlation
it trains on — at different arities, skews and sizes:

  star       2-4 fact tables over a shared rim of dims (JOB-like): facts
             carry Zipf fks whose hub identity is SHARED across facts
             into the same dim, plus optional cat2 intra-table
             correlations.
  snowflake  dims are themselves normalized into root -> mid chains, so
             join trees have depth >2 and the sampler's templates grow
             chain-shaped (ExtJOB's link-chains).
  person     two entity hubs (person/item) with activity satellites and
             a `via`-gathered hub key (STACK's answer.site_id =
             question.site_id[fk]) — the cross-table hub correlation
             that breaks per-table independence assumptions.

`sample_schema(seed)` is a pure function of its arguments: same seed,
same `SchemaSpec`, bit-for-bit (pinned by tests/test_gen.py). Every
sampled spec passes `spec.assert_valid` BY CONSTRUCTION: tables are
emitted parents-first, so the FK graph is acyclic and `via` parents are
always materialized earlier; facts/satellites never carry a dense id, so
`delete_safe_tables` is non-empty and the stream sampler always has a
legal delete target.

To add a family: write a `_family(rng) -> List[TableSpec]` builder that
(1) emits parents before children, (2) gives every fk parent a dense id,
(3) leaves at least one childless, id-free table, then register it in
`FAMILIES`.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.gen.spec import (ColumnSpec, SchemaSpec, TableSpec, assert_valid,
                            cat, cat2, fk, id_col, join_edges)

__all__ = ["FAMILIES", "sample_schema"]

FAMILIES = ("star", "snowflake", "person")


# ------------------------------------------------------------ draw helpers
def _zipf_a(rng) -> float:
    """Zipf exponent for a skewed fk; the hand-built worlds span 0.8-1.2."""
    return round(float(rng.uniform(0.6, 1.3)), 2)


def _narrow(rng, name: str) -> ColumnSpec:
    """IN-filterable categorical: small domain like role_id/badge_kind."""
    return cat(name, 0, int(rng.integers(3, 60)))


def _wide(rng, name: str) -> ColumnSpec:
    """Range-filterable ordinal: wide domain like production_year/score."""
    lo = int(rng.integers(0, 1000))
    return cat(name, lo, lo + int(rng.integers(200, 2000)))


def _maybe_cat2(rng, cols: List[ColumnSpec], p: float = 0.4) -> None:
    """With prob p, append a two-regime categorical correlated with the
    last cat column (the title.kind_id pattern) — src precedes, so the
    spec stays valid without hoisting."""
    srcs = [c for c in cols if c.kind == "cat"]
    if srcs and rng.random() < p:
        src = srcs[-1]
        thr = int((src.lo + src.hi) // 2)
        cols.append(cat2("mode", src.name, thr,
                         int(rng.integers(2, 5)), int(rng.integers(4, 10))))


def _fact_fks(rng, parents: List[str], n_min: int = 1) -> List[ColumnSpec]:
    """Fk columns into a random subset of `parents` (>= n_min, unique)."""
    k = int(rng.integers(n_min, len(parents) + 1))
    picks = list(rng.choice(len(parents), size=k, replace=False))
    cols = []
    for i in picks:
        skew = bool(rng.random() < 0.75)
        cols.append(fk(f"{parents[i]}_id", parents[i],
                       a=_zipf_a(rng), skew=skew))
    return cols


# ---------------------------------------------------------------- families
def _star(rng) -> List[TableSpec]:
    tables: List[TableSpec] = []
    n_enum = int(rng.integers(1, 3))
    enums = [f"et{i}" for i in range(n_enum)]
    for name in enums:
        tables.append(TableSpec(name, int(rng.integers(4, 24)),
                                (id_col(),), fixed=True))
    n_dims = int(rng.integers(2, 5))
    dims = [f"dim{i}" for i in range(n_dims)]
    for name in dims:
        cols = [id_col(), _narrow(rng, "k0")]
        if rng.random() < 0.5:
            cols.append(_wide(rng, "ts"))
        tables.append(TableSpec(name, int(rng.integers(1000, 8000)),
                                tuple(cols)))
    n_facts = int(rng.integers(2, 5))
    for i in range(n_facts):
        # every fact references dim0 — the shared hub that (a) keeps the
        # join graph connected and (b) gives all facts the SAME Zipf hub
        # rows; dim i % n_dims is also guaranteed so dims get coverage
        anchor = dims[i % n_dims]
        cols = [fk(f"{anchor}_id", anchor, a=_zipf_a(rng))]
        if anchor != dims[0]:
            cols.append(fk(f"{dims[0]}_id", dims[0], a=_zipf_a(rng)))
        others = [d for d in dims if d != anchor and d != dims[0]]
        if others:
            cols += _fact_fks(rng, others, n_min=0)
        if rng.random() < 0.7:
            e = enums[int(rng.integers(n_enum))]
            cols.append(fk(f"{e}_id", e, skew=False))
        cols.append(_narrow(rng, "f0"))
        if rng.random() < 0.5:
            cols.append(_wide(rng, "f1"))
        _maybe_cat2(rng, cols)
        tables.append(TableSpec(f"fact{i}", int(rng.integers(20_000, 80_000)),
                                tuple(cols)))
    return tables


def _snowflake(rng) -> List[TableSpec]:
    tables: List[TableSpec] = []
    n_roots = int(rng.integers(1, 3))
    roots = [f"root{i}" for i in range(n_roots)]
    for name in roots:
        tables.append(TableSpec(name, int(rng.integers(300, 2000)),
                                (id_col(), _narrow(rng, "k0"))))
    n_mids = int(rng.integers(2, 5))
    mids = [f"dim{i}" for i in range(n_mids)]
    for i, name in enumerate(mids):
        # every mid chains to a root — join trees get depth >= 3
        root = roots[i % n_roots]
        cols = [id_col(), fk(f"{root}_id", root, a=_zipf_a(rng),
                             skew=bool(rng.random() < 0.6)),
                _narrow(rng, "k0")]
        if rng.random() < 0.4:
            cols.append(_wide(rng, "ts"))
        tables.append(TableSpec(name, int(rng.integers(2000, 12_000)),
                                tuple(cols)))
    n_facts = int(rng.integers(2, 4))
    for i in range(n_facts):
        # mids[0] is the shared hub every fact references (connectivity +
        # shared Zipf rows); the rotating anchor spreads mid coverage
        anchor = mids[i % n_mids]
        cols = [fk(f"{anchor}_id", anchor, a=_zipf_a(rng))]
        if anchor != mids[0]:
            cols.append(fk(f"{mids[0]}_id", mids[0], a=_zipf_a(rng)))
        others = [d for d in mids if d != anchor and d != mids[0]]
        if others:
            cols += _fact_fks(rng, others, n_min=0)
        if rng.random() < 0.5:    # occasional shortcut edge straight to a root
            r = roots[int(rng.integers(n_roots))]
            cols.append(fk(f"{r}_id", r, skew=False))
        cols.append(_narrow(rng, "f0"))
        _maybe_cat2(rng, cols)
        tables.append(TableSpec(f"fact{i}", int(rng.integers(20_000, 70_000)),
                                tuple(cols)))
    return tables


def _person(rng) -> List[TableSpec]:
    tables: List[TableSpec] = []
    # the site-like hub: tiny, fixed, heavily Zipf-referenced
    hub_n = int(rng.integers(16, 64))
    tables.append(TableSpec("hub", hub_n, (id_col(),), fixed=True))
    tables.append(TableSpec("person", int(rng.integers(4000, 20_000)), (
        id_col(),
        fk("hub_id", "hub", a=round(float(rng.uniform(1.0, 1.4)), 2)),
        cat("reputation", 0, int(rng.integers(50, 200))))))
    tables.append(TableSpec("item", int(rng.integers(15_000, 60_000)), (
        id_col(),
        fk("hub_id", "hub", a=round(float(rng.uniform(1.0, 1.4)), 2)),
        fk("owner_id", "person", a=_zipf_a(rng)),
        _wide(rng, "score"))))
    n_sat = int(rng.integers(2, 5))
    for i in range(n_sat):
        cols = [fk("item_id", "item", a=_zipf_a(rng))]
        if rng.random() < 0.8:
            # the STACK-style hub gather: this satellite's hub_id is the
            # parent item's hub_id looked up through a fresh fk draw
            cols.append(fk("hub_id", "item", a=_zipf_a(rng), via="hub_id"))
        if rng.random() < 0.5:
            cols.append(fk("owner_id", "person", a=_zipf_a(rng)))
        cols.append(_narrow(rng, "k0"))
        _maybe_cat2(rng, cols)
        tables.append(TableSpec(f"act{i}", int(rng.integers(30_000, 120_000)),
                                tuple(cols)))
    if rng.random() < 0.6:        # tag-like dim + bridge
        tables.append(TableSpec("label", int(rng.integers(300, 2000)), (
            id_col(),
            fk("hub_id", "hub", a=round(float(rng.uniform(1.0, 1.4)), 2)))))
        tables.append(TableSpec("item_label",
                                int(rng.integers(40_000, 150_000)), (
                                    fk("item_id", "item", a=_zipf_a(rng)),
                                    fk("label_id", "label",
                                       a=_zipf_a(rng)))))
    return tables


_BUILDERS = {"star": _star, "snowflake": _snowflake, "person": _person}


def sample_schema(seed: int, family: Optional[str] = None) -> SchemaSpec:
    """Draw one random schema. `family=None` picks uniformly (the draw is
    consumed either way, so fixing the family never shifts the rest of
    the stream)."""
    rng = np.random.default_rng(seed)
    pick = FAMILIES[int(rng.integers(len(FAMILIES)))]
    fam = family if family is not None else pick
    assert fam in _BUILDERS, f"unknown schema family {fam!r}"
    spec = SchemaSpec(f"{fam}{seed}", tuple(_BUILDERS[fam](rng)), family=fam)
    assert_valid(spec)
    _assert_connected(spec)
    return spec


def _assert_connected(spec: SchemaSpec) -> None:
    """All joinable tables must sit in ONE fk component — otherwise the
    query sampler stalls below its requested join arity. Families
    guarantee this via the shared hub fk; this catches regressions."""
    edges = join_edges(spec)
    adj: dict = {}
    for c, _, p, _ in edges:
        adj.setdefault(c, set()).add(p)
        adj.setdefault(p, set()).add(c)
    if not adj:
        raise AssertionError(f"{spec.name}: no joinable fk edges")
    seen, todo = set(), [next(iter(adj))]
    while todo:
        t = todo.pop()
        if t in seen:
            continue
        seen.add(t)
        todo.extend(adj[t])
    assert seen == set(adj), \
        f"{spec.name}: disconnected fk graph {sorted(set(adj) - seen)}"
