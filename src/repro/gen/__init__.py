"""Seeded world generator: random schemas, workloads and delta streams.

Layered samplers, each a pure function of its seed:

  spec      the schema grammar (`SchemaSpec`) + validity checks
  seeds     the disjoint train/test seed-partition contract
  schema    `SchemaSampler`: star/snowflake/person-centric FK DAGs
  queries   `QuerySampler`: acyclic join templates over a spec's FK graph
  streams   `StreamSampler`: mixed delta/tenant/fault arrival streams
  world     `sample_world`: one seed -> (spec, db, workload, stream)

Only the dependency-free layers are imported eagerly (``sql.datagen``
imports ``repro.gen.spec``, which triggers this package ``__init__`` —
pulling the serve-layer samplers in here would cycle back through
``serve.deltas`` into ``sql.datagen``). Import the samplers from their
modules: ``from repro.gen.world import sample_world``.
"""
from repro.gen import seeds, spec                              # noqa: F401
from repro.gen.spec import (ColumnSpec, SchemaSpec, TableSpec,  # noqa: F401
                            assert_valid, delete_safe_tables, join_edges)

__all__ = ["seeds", "spec", "ColumnSpec", "SchemaSpec", "TableSpec",
           "assert_valid", "delete_safe_tables", "join_edges"]
