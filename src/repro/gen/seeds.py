"""Seed bookkeeping: the disjoint train/test partition and sub-stream
derivation every sampler in `repro.gen` (and `sql.workloads`) shares.

The contract: one base seed names a workload; the TRAIN RNG stream is
`default_rng(train_seed(base))`, the TEST stream
`default_rng(test_seed(base))`, and the two are guaranteed disjoint —
no query instantiation is ever drawn from both, so a policy evaluated on
the test split has provably never trained on those constants. The
partition is a fixed offset of `TRAIN_TEST_SEED_GAP`; callers that sweep
base seeds must stay inside one span (`assert_partitionable` checks),
otherwise one sweep's train range would collide with another's test
range.

`substream` derives independent child seeds for the world sampler's
layered stages (schema vs data vs queries vs stream) from one world
seed: a splitmix-style integer hash, so neighbouring world seeds do not
produce overlapping numpy streams the way raw `seed + k` offsets would.
"""
from __future__ import annotations

from typing import Tuple

__all__ = ["TRAIN_TEST_SEED_GAP", "train_seed", "test_seed",
           "split_train_test", "seed_ranges", "assert_partitionable",
           "substream"]

# One span of base seeds maps onto [base, base+GAP) for train and
# [base+GAP, base+2*GAP) for test. 10_000 is load-bearing: it is the
# offset `sql.workloads.make_workload` has used since the seed PR, so
# every pinned workload stays bit-identical.
TRAIN_TEST_SEED_GAP = 10_000


def train_seed(base: int) -> int:
    return base


def test_seed(base: int) -> int:
    return base + TRAIN_TEST_SEED_GAP


def split_train_test(base: int) -> Tuple[int, int]:
    """(train_seed, test_seed) for one workload base seed."""
    assert_partitionable(base)
    return train_seed(base), test_seed(base)


def seed_ranges(base0: int = 0) -> Tuple[range, range]:
    """The disjoint (train, test) seed ranges for bases in
    [base0, base0 + GAP)."""
    return (range(base0, base0 + TRAIN_TEST_SEED_GAP),
            range(base0 + TRAIN_TEST_SEED_GAP,
                  base0 + 2 * TRAIN_TEST_SEED_GAP))


def assert_partitionable(base: int, base0: int = 0) -> None:
    """`base` must sit inside one span so its train range cannot reach
    into any test range."""
    assert base0 <= base < base0 + TRAIN_TEST_SEED_GAP, \
        f"base seed {base} outside the partitionable span " \
        f"[{base0}, {base0 + TRAIN_TEST_SEED_GAP}): its train/test " \
        f"ranges would collide with a neighbouring span's"


def substream(seed: int, stage: int) -> int:
    """Deterministic child seed for sampler `stage` of world `seed` —
    a splitmix64 round, truncated to numpy's int seed range."""
    z = (seed * 0x9E3779B97F4A7C15 + stage * 0xBF58476D1CE4E5B9) % (1 << 64)
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) % (1 << 64)
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) % (1 << 64)
    return int((z ^ (z >> 31)) % (1 << 31))
