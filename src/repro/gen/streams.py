"""StreamSampler: seeded delta/tenant/fault arrival streams for a world.

Composes the serve-layer primitives into one ready-to-run `Arrival`
list, all drawn from a single seed:

  deltas   mixed append / update (append+delete) / delete `DeltaBatch`es
           cycling over the schema's `delete_safe_tables` (no dangling
           fks by construction), merged at deterministic times so they
           act as write barriers for every tenant;
  tenants  1-3 tenants with their own Poisson rates and SLOs over
           disjoint slices of the workload's train queries, via
           `driver.multi_tenant_stream` (each tenant's sub-stream is
           identical alone or in the mix);
  bursts   optionally one burst tenant: a short, hot arrival clump
           starting mid-horizon — the overload shape the QoS admission
           and SLO watchdog suites care about;
  faults   a sampled `FaultInjector` profile (crash/transient/slow
           probabilities, optionally confined to a seq window = a
           seeded outage burst). Returned as kwargs so callers opt in;
           `make_fault_injector` builds the injector.

Everything is a pure function of (spec, workload, profile, seed): same
inputs, same stream, bit-for-bit — pinned by tests/test_gen.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.gen.spec import SchemaSpec, delete_safe_tables
from repro.serve.deltas import DeltaBatch
from repro.serve.driver import TenantTraffic, multi_tenant_stream
from repro.serve.recover.faults import FaultInjector
from repro.serve.scheduler import Arrival

__all__ = ["StreamProfile", "sample_profile", "build_stream",
           "make_fault_injector"]

_DELTA_KINDS = ("append", "update", "delete")


@dataclasses.dataclass(frozen=True)
class StreamProfile:
    """One sampled serving scenario (plain data; `build_stream` renders
    it against a concrete workload)."""
    n_queries: int
    rate: float
    n_tenants: int
    slos: Tuple[Optional[float], ...]      # per tenant; None = best-effort
    delta_every: int                       # 0 = static data
    delta_rows: int
    delete_frac: float
    delta_tables: Tuple[str, ...]
    burst: Optional[Tuple[float, float, int]]  # (start_frac, rate_mult, n)
    faults: Tuple[Tuple[str, float], ...]  # FaultInjector kwargs (sorted)

    def fault_kwargs(self) -> Dict:
        return dict(self.faults)


def sample_profile(spec: SchemaSpec, seed: int, *,
                   n_queries: int = 30) -> StreamProfile:
    """Draw one scenario shape for `spec`. Fault probabilities are kept
    small enough that most queries succeed (the recover ladder, not the
    stream, is under test when they fire)."""
    rng = np.random.default_rng(seed)
    rate = round(float(rng.uniform(1.0, 6.0)), 2)
    n_tenants = int(rng.integers(1, 4))
    slos = tuple(None if rng.random() < 0.3
                 else round(float(rng.uniform(40.0, 400.0)), 1)
                 for _ in range(n_tenants))
    targets = delete_safe_tables(spec)
    delta_every = 0 if (not targets or rng.random() < 0.25) else \
        int(rng.integers(4, 12))
    delta_rows = int(rng.integers(200, 2000))
    delete_frac = round(float(rng.uniform(0.02, 0.15)), 3)
    burst = None
    if rng.random() < 0.4:
        burst = (round(float(rng.uniform(0.3, 0.7)), 2),
                 round(float(rng.uniform(2.0, 5.0)), 1),
                 int(rng.integers(4, 10)))
    faults: Dict[str, float] = {}
    if rng.random() < 0.5:
        faults["p_transient"] = round(float(rng.uniform(0.01, 0.08)), 3)
    if rng.random() < 0.3:
        faults["p_crash"] = round(float(rng.uniform(0.01, 0.05)), 3)
    if rng.random() < 0.4:
        faults["p_slow"] = round(float(rng.uniform(0.02, 0.10)), 3)
    if faults and rng.random() < 0.5:      # seeded outage burst
        lo = int(rng.integers(0, max(1, n_queries // 2)))
        faults["window"] = (lo, lo + int(rng.integers(4, n_queries)))
    return StreamProfile(
        n_queries=n_queries, rate=rate, n_tenants=n_tenants, slos=slos,
        delta_every=delta_every, delta_rows=delta_rows,
        delete_frac=delete_frac, delta_tables=targets, burst=burst,
        faults=tuple(sorted(faults.items())))


def _delta_arrivals(profile: StreamProfile, horizon: float,
                    rng) -> List[Arrival]:
    if not profile.delta_every or not profile.delta_tables:
        return []
    n = max(1, profile.n_queries // profile.delta_every)
    times = np.sort(rng.uniform(0.0, horizon, size=n))
    out = []
    for k in range(n):
        kind = _DELTA_KINDS[k % len(_DELTA_KINDS)]
        table = profile.delta_tables[k % len(profile.delta_tables)]
        out.append(Arrival(float(times[k]), delta=DeltaBatch(
            table,
            n_append=0 if kind == "delete" else profile.delta_rows,
            delete_frac=0.0 if kind == "append" else profile.delete_frac,
            seed=int(rng.integers(2 ** 31)))))
    return out


def build_stream(workload, profile: StreamProfile,
                 seed: int) -> List[Arrival]:
    """Render `profile` against `workload`: tenants cycle disjoint slices
    of the train queries (so the stream exercises every template), plus
    the profile's deltas and optional burst tenant, merged on the
    virtual clock."""
    rng = np.random.default_rng(seed)
    qs = list(workload.train)
    per = max(1, len(qs) // profile.n_tenants)
    traffics = []
    share = max(1, profile.n_queries // profile.n_tenants)
    for i in range(profile.n_tenants):
        chunk = qs[i * per:(i + 1) * per] or qs
        traffics.append(TenantTraffic(
            f"t{i}", chunk, rate=profile.rate / profile.n_tenants,
            n_queries=share, slo=profile.slos[i],
            seed=int(rng.integers(2 ** 31))))
    horizon = profile.n_queries / profile.rate
    if profile.burst is not None:
        start_frac, mult, n_b = profile.burst
        traffics.append(TenantTraffic(
            "burst", qs[:per] or qs, rate=profile.rate * mult,
            n_queries=n_b, slo=None, seed=int(rng.integers(2 ** 31)),
            start=horizon * start_frac))
    deltas = _delta_arrivals(profile, horizon, rng)
    return multi_tenant_stream(traffics, deltas=deltas)


def make_fault_injector(profile: StreamProfile,
                        seed: int) -> Optional[FaultInjector]:
    """The profile's chaos, keyed by `seed`; None when the profile drew
    no faults (the common case — streams are mostly healthy)."""
    kw = profile.fault_kwargs()
    if not kw:
        return None
    return FaultInjector(seed=seed, **kw)
