"""sample_world: one seed -> a complete runnable world.

The top of the generator stack: derives independent sub-seeds for each
layer via `seeds.substream` (schema / data / queries / stream / faults),
then runs the layered samplers:

    spec     = schema.sample_schema(substream(seed, 1))
    db       = datagen.make_db_from_spec(spec, seed=substream(seed, 2))
    workload = queries.make_gen_workload(spec, substream(seed, 3) % GAP)
    stream   = streams.build_stream(workload, profile, substream(seed, 4))

so same world seed => bit-identical everything, and any layer can be
resampled independently (e.g. many data seeds over one schema, or many
streams over one workload) by fixing the others' sub-seeds.

`World.meta` is the `WorkloadMeta` the serving agent encodes against;
for cross-schema serving (train on world A, serve world B) keep A's
meta — B's unseen tables encode as all-zero bits (§V-B2), which is
exactly the generalization question `benchmarks/bench_generalize.py`
measures.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.encoding import WorkloadMeta
from repro.gen import schema, streams
from repro.gen.queries import make_gen_workload
from repro.gen.seeds import TRAIN_TEST_SEED_GAP, substream
from repro.gen.spec import SchemaSpec
from repro.serve.scheduler import Arrival
from repro.sql import datagen
from repro.sql.workloads import Workload

__all__ = ["World", "sample_world"]

# substream stage tags (stable: changing one resamples ONLY that layer)
STAGE_SCHEMA, STAGE_DATA, STAGE_QUERIES, STAGE_STREAM, STAGE_FAULTS = \
    1, 2, 3, 4, 5


@dataclasses.dataclass
class World:
    seed: int
    spec: SchemaSpec
    db: object                         # None when materialize=False
    workload: Workload
    meta: WorkloadMeta
    stream: List[Arrival]              # [] when with_stream=False
    profile: Optional[streams.StreamProfile]

    def fault_injector(self):
        """The world's sampled chaos (None for fault-free profiles)."""
        if self.profile is None:
            return None
        return streams.make_fault_injector(
            self.profile, substream(self.seed, STAGE_FAULTS))


def sample_world(seed: int, *, family: Optional[str] = None,
                 scale: float = 0.05, n_templates: int = 8,
                 n_train: int = 16, n_test_per_template: int = 1,
                 t_min: int = 3, t_max: int = 7, n_queries: int = 30,
                 materialize: bool = True,
                 with_stream: bool = True) -> World:
    """Sample one world. `materialize=False` skips building the database
    (schema/workload-only property tests over hundreds of worlds);
    `with_stream=False` skips the arrival stream."""
    spec = schema.sample_schema(substream(seed, STAGE_SCHEMA),
                                family=family)
    db = None
    if materialize:
        db = datagen.make_db_from_spec(spec, scale=scale,
                                       seed=substream(seed, STAGE_DATA))
    base = substream(seed, STAGE_QUERIES) % TRAIN_TEST_SEED_GAP
    workload = make_gen_workload(spec, base, n_templates=n_templates,
                                 n_train=n_train,
                                 n_test_per_template=n_test_per_template,
                                 t_min=t_min, t_max=t_max)
    meta = WorkloadMeta.from_workload(workload)
    profile = None
    stream: List[Arrival] = []
    if with_stream:
        stream_seed = substream(seed, STAGE_STREAM)
        profile = streams.sample_profile(spec, stream_seed,
                                         n_queries=n_queries)
        stream = streams.build_stream(workload, profile, stream_seed)
    return World(seed=seed, spec=spec, db=db, workload=workload, meta=meta,
                 stream=stream, profile=profile)
