"""Background PPO learner: closes the serve→train loop.

The learner rides the scheduler's completion hook, so "background" means
interleaved with scheduler ticks on the virtual clock, not a thread:
every K-th completion it draws a prioritized sample from the replay
buffer and runs ONE deterministic `ppo_update_batch` on its own copy of
the agent (the serving agent's params are never touched by training —
updates donate buffers to XLA, swaps always deep-copy). Every
`gate_every` updates the candidate faces the `PolicyStore` gate:
shadow-eval on the held-out probe set against the incumbent on the live
(possibly drifted) database, hot-swap only if no worse, learner reset to
the incumbent on reject. The whole loop — sampling, updates, gate
verdicts, swaps, curriculum promotions — is a deterministic function of
(stream, seeds), so a served run is bit-reproducible with learning on.

Budgeting: one bounded-size update per `update_every` completions keeps
the host-side learning cost a small, tunable fraction of serving work;
none of it lands on the virtual clock, so reported query latencies are
scheduling-identical to a learning-off run until a swap changes the
policy (which is the point).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List, Optional

import numpy as np

from repro.checkpoint import agent_state, install_agent_state
from repro.learn.curriculum import AdaptiveCurriculum
from repro.learn.harvest import TrajectoryHarvester
from repro.learn.policy_store import PolicyStore
from repro.learn.replay import ReplayBuffer

log = logging.getLogger("repro.learn")


@dataclasses.dataclass
class LearnStats:
    completions: int = 0
    updates: int = 0
    gates: int = 0
    swaps: int = 0
    rejects: int = 0
    host_seconds: float = 0.0          # total learning cost (updates+gates)
    final_stage: int = 3

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["host_seconds"] = round(d["host_seconds"], 4)
        return d


class BackgroundLearner:
    def __init__(self, serving_agent, replay: ReplayBuffer, *,
                 store: Optional[PolicyStore] = None,
                 curriculum: Optional[AdaptiveCurriculum] = None,
                 update_every: int = 8, sample_size: int = 8,
                 gate_every: int = 2, min_buffer: Optional[int] = None,
                 seed: int = 0, reset_on_reject: bool = True,
                 learner_agent=None,
                 explore_below_stage: Optional[int] = None):
        """update_every  run one PPO update per this many completions
        sample_size     trajectories per update (one jitted episode-batch)
        gate_every      gate + maybe hot-swap every this many updates
        min_buffer      don't update until the buffer holds this many
        learner_agent   optional pre-built agent to train (lets callers
                        reuse a warm jit cache across runs); defaults to a
                        fresh clone of the serving agent's architecture
        explore_below_stage  with a curriculum: serve exploring while
                        curriculum.stage < this, greedy (argmax) once the
                        stage is earned — so exploration only runs while
                        the governor says the policy is still learning
                        (e.g. 3: greedy at full stage, exploring after a
                        drift-triggered demotion)
        """
        self.serving_agent = serving_agent
        self.replay = replay
        self.store = store
        self.curriculum = curriculum
        self.update_every = max(update_every, 1)
        self.sample_size = max(sample_size, 1)
        self.gate_every = max(gate_every, 1)
        self.min_buffer = sample_size if min_buffer is None else min_buffer
        self.reset_on_reject = reset_on_reject
        assert explore_below_stage is None or curriculum is not None, \
            "explore_below_stage needs a curriculum to read the stage from"
        self.explore_below_stage = explore_below_stage
        self._rng = np.random.default_rng(seed)
        if learner_agent is None and hasattr(serving_agent, "clone"):
            self.agent = serving_agent.clone(seed=seed)
        else:
            if learner_agent is None:
                learner_agent = type(serving_agent)(
                    serving_agent.meta, serving_agent.cfg, seed=seed)
            self.agent = learner_agent
            install_agent_state(self.agent, agent_state(serving_agent),
                                copy=True)
        self.stats = LearnStats(final_stage=3 if curriculum is None
                                else curriculum.stage)
        self.update_log: List[Dict] = []
        self._sched = None

    def attach(self, scheduler) -> None:
        self._sched = scheduler
        if self.store is not None and \
                getattr(self.store, "obs", None) is None:
            # wire the store's observability sink to the scheduler's
            # tracer (QueryService attaches obs before hooks, so it is
            # already installed here; None stays None)
            self.store.obs = getattr(scheduler, "obs", None)
        if self.curriculum is not None:
            scheduler.stage = self.curriculum.stage
            self._gate_explore()
        if self.store is not None and not self.store.versions:
            self.store.commit(self.serving_agent, step=0,
                              extra={"initial": True})
        scheduler.on_complete.append(self._on_complete)

    def _gate_explore(self) -> None:
        if self.explore_below_stage is not None:
            self._sched.explore = \
                self.curriculum.stage < self.explore_below_stage

    # -------------------------------------------------------------- loop
    def _on_complete(self, comp) -> None:
        t0 = time.perf_counter()
        if self.curriculum is not None:
            self._sched.stage = self.curriculum.observe(comp)
            self.stats.final_stage = self.curriculum.stage
            self._gate_explore()
        self.stats.completions += 1
        if self.stats.completions % self.update_every == 0 and \
                len(self.replay) >= self.min_buffer:
            self._update_step()
        self.stats.host_seconds += time.perf_counter() - t0

    def _update_step(self) -> None:
        exps = self.replay.sample(self.sample_size, self._rng,
                                  self._sched.db.versions)
        m = self.agent.ppo_update_batch([e.traj for e in exps])
        self.stats.updates += 1
        self.update_log.append({"update": self.stats.updates,
                                "n_traj": len(exps), **m})
        obs = getattr(self._sched, "obs", None)
        if obs is not None:
            obs.event("learner_update",
                      {"update": self.stats.updates, "n_traj": len(exps)})
        if self.store is None or self.stats.updates % self.gate_every:
            return
        self.stats.gates += 1
        rec = self.store.evaluate_and_maybe_swap(
            self.serving_agent, self.agent, db=self._sched.db,
            est=self._sched.est, cluster=self._sched.cluster,
            step=self.stats.updates)
        if rec["swapped"]:
            self.stats.swaps += 1
        elif not rec["accepted"]:
            self.stats.rejects += 1
            if self.reset_on_reject:      # restart from the incumbent
                install_agent_state(self.agent,
                                    agent_state(self.serving_agent),
                                    copy=True)
                log.info("learner reset to incumbent after gate reject "
                         "@update %d", self.stats.updates)


def make_online_loop(serving_agent, *, probe=(), store_dir=None,
                     replay: Optional[ReplayBuffer] = None,
                     curriculum: Optional[AdaptiveCurriculum] = None,
                     store: Optional[PolicyStore] = None,
                     **learner_kw):
    """Convenience factory: (harvester, learner) sharing one replay
    buffer, ready for `QueryService(hooks=[harvester, learner])` (the
    harvester must run first so the completion that triggers an update is
    already buffered)."""
    replay = replay if replay is not None else ReplayBuffer()
    if store is None and store_dir is not None:
        store = PolicyStore(store_dir, probe)
    assert not (probe and store is None), \
        "probe queries given but no store/store_dir: the gate (and any " \
        "hot-swap) would silently never run"
    harvester = TrajectoryHarvester(replay)
    learner = BackgroundLearner(serving_agent, replay, store=store,
                                curriculum=curriculum, **learner_kw)
    return harvester, learner
