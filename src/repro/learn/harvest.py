"""Serve-time trajectory harvesting.

`TrajectoryHarvester` is the opt-in bridge between the scheduler's
completion stream and the replay buffer: attached to a `LaneScheduler`
(directly or via `QueryService(hooks=[...])`), it turns every Completion
into a tagged `replay.Experience` — recording the per-stage
observations/actions/rewards the serving path already computed, plus the
live per-table data versions at finish time. Harvesting is pure
bookkeeping on data the scheduler produced anyway, so it adds no policy
calls and no virtual-clock cost.

Trajectories with zero decision points (queries that ran to completion
before the first stage boundary) carry no gradient and are counted but
not buffered.

Plan-memory interplay: MEMOIZED completions (`comp.memoized`) replayed a
scripted action sequence — no policy evaluation happened, their logps
are 0.0 placeholders, and feeding them to PPO would poison the
importance ratios — so they are counted (`n_memoized`) and skipped. For
NON-memoized completions, when a `plan_memory` is wired in, the observed
latency is folded back into the matching entry's streaming stats
(`PlanMemory.note_latency`): the memory's mean/variance per template
keeps tracking live serving conditions even while the entry itself is
not being replayed.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.learn.replay import Experience, ReplayBuffer


class TrajectoryHarvester:
    def __init__(self, replay: Optional[ReplayBuffer] = None,
                 plan_memory=None):
        self.replay = replay if replay is not None else ReplayBuffer()
        self.plan_memory = plan_memory
        self.n_seen = 0
        self.n_harvested = 0
        self.n_empty = 0
        self.n_retried = 0
        self.n_memoized = 0
        self.n_fed_back = 0            # latencies folded into memory stats
        self._sched = None

    def attach(self, scheduler) -> None:
        self._sched = scheduler
        scheduler.on_complete.append(self._on_complete)

    # ------------------------------------------------------------ harvest
    def _on_complete(self, comp) -> None:
        self.n_seen += 1
        if getattr(comp, "memoized", False):
            # scripted replay: logps are placeholders, not policy samples
            self.n_memoized += 1
            return
        if self.plan_memory is not None and not comp.result.failed:
            if self.plan_memory.note_latency(
                    comp.query, self._sched.db.versions,
                    comp.result.latency):
                self.n_fed_back += 1
        if not comp.traj.actions:
            self.n_empty += 1
            return
        tables = tuple(sorted({r.table for r in comp.query.relations}))
        versions = {t: self._sched.db.table_version(t) for t in tables}
        self.replay.add(Experience(
            seq=comp.seq, query_name=comp.query.name, traj=comp.traj,
            latency=comp.result.latency, failed=comp.result.failed,
            finish_t=comp.finish_t, tables=tables, versions=versions,
            # recovery tags: the scheduler emits one Completion per query
            # (the final attempt), so replay sees retried queries once —
            # tagged, not duplicated; completion-like objects without the
            # recovery fields read as single untried attempts
            attempts=getattr(comp, "attempts", 1),
            recovered=getattr(comp, "recovered", False),
            hedged=getattr(comp, "hedged", False)))
        self.n_harvested += 1
        if getattr(comp, "attempts", 1) > 1:
            self.n_retried += 1

    def stats(self) -> Dict[str, float]:
        return {"seen": self.n_seen, "harvested": self.n_harvested,
                "empty": self.n_empty, "retried": self.n_retried,
                "memoized": self.n_memoized, "fed_back": self.n_fed_back,
                **self.replay.stats()}
