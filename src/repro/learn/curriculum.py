"""Adaptive curriculum: the paper's staged action-space schedule, driven
by live serving statistics instead of an episode counter.

Offline training promotes through stages 1→2→3 at fixed episode
fractions (`core.actions.curriculum_stage`). Online there is no episode
horizon — the loop promotes when the SERVING stream says the policy has
earned the next stage: a rolling window of completions must clear a
success-rate threshold (and optionally a p50-latency ceiling) and the
current stage must have been held for a minimum dwell. Stage 1 restricts
the mask to the safe pre-execution family (cbo/no-op), stage 2 unlocks
runtime plan adjustments, stage 3 lifts every restriction — so a cold or
freshly-swapped policy cannot take destabilizing actions on live traffic
until its own track record licenses them. Optionally the governor also
runs in reverse: a window whose success rate collapses (drift starting
to fail queries) demotes a stage, re-restricting the action space and —
through `BackgroundLearner.explore_below_stage` — re-opening exploration
until the loop has adapted and the track record re-earns stage 3.

`observe` is called once per completion (the `BackgroundLearner` wires it
to the scheduler's completion hook and copies `stage` onto the scheduler
between ticks); everything is computed from virtual-clock quantities, so
promotion points are bit-reproducible.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np


class AdaptiveCurriculum:
    def __init__(self, *, start_stage: int = 1, window: int = 16,
                 promote_success: float = 0.9,
                 promote_p50: Optional[float] = None,
                 min_dwell: int = 16,
                 demote_success: Optional[float] = None,
                 drift_demote_threshold: Optional[float] = None,
                 drift_cooldown: Optional[int] = None):
        """window           rolling completion window the thresholds see
        promote_success  fraction of window completions that must succeed
        promote_p50      optional ceiling on the window's p50 latency (s)
        min_dwell        completions that must pass before each promotion
        demote_success   optional floor: a full window whose success rate
                         falls below it DEMOTES one stage — the governor
                         that re-restricts the action space (and, via the
                         learner's explore gating, re-opens exploration)
                         when drift starts failing queries
        drift_demote_threshold
                         optional `DriftDetector` peak-score trigger for
                         `note_drift`: demote PROACTIVELY on attributed
                         drift (catalog lag, regret, prediction error)
                         rather than waiting for a window of failures —
                         the success-rate governor is reactive; this one
                         re-restricts the action space as soon as the
                         detector says the world moved
        drift_cooldown   completions between drift demotions (default:
                         `window`), so one sustained drift episode costs
                         at most one stage per window
        """
        assert 1 <= start_stage <= 3
        self.stage = start_stage
        self.window_size = window
        self.promote_success = promote_success
        self.promote_p50 = promote_p50
        self.min_dwell = min_dwell
        self.demote_success = demote_success
        self.drift_demote_threshold = drift_demote_threshold
        self.drift_cooldown = window if drift_cooldown is None \
            else drift_cooldown
        self._last_drift_demote = -(1 << 30)
        self._window: Deque[Tuple[bool, float]] = deque(maxlen=window)
        self._dwell = 0
        self.n_observed = 0
        self.promotions: List[int] = []    # completion counts at promotion
        self.demotions: List[int] = []     #   ... and at demotion
        self.drift_demotions: List[int] = []  # subset driven by note_drift

    def observe(self, comp) -> int:
        """Fold one scheduler Completion in; returns the (possibly just
        promoted/demoted) current stage."""
        self.n_observed += 1
        self._dwell += 1
        self._window.append((not comp.result.failed, comp.result.latency))
        if self.stage > 1 and self.demote_success is not None and \
                len(self._window) >= self.window_size and \
                self._success_rate() < self.demote_success:
            self.stage -= 1
            self.demotions.append(self.n_observed)
            self._dwell = 0
            self._window.clear()
        elif self.stage < 3 and self._ready():
            self.stage += 1
            self.promotions.append(self.n_observed)
            self._dwell = 0
            self._window.clear()
        return self.stage

    def note_drift(self, peak_score: float) -> bool:
        """Detector-driven demotion (wired by `drift.DriftController`):
        when the peak per-table drift score crosses the configured
        threshold, drop one stage immediately — stale-stats drift makes
        the aggressive action families the riskiest exactly when the
        track record that earned them stops being evidence. Window and
        dwell reset, so re-promotion must be re-earned on post-drift
        traffic. Returns True when a demotion fired."""
        if self.drift_demote_threshold is None or \
                peak_score < self.drift_demote_threshold:
            return False
        if self.stage <= 1 or \
                self.n_observed - self._last_drift_demote < \
                self.drift_cooldown:
            return False
        self.stage -= 1
        self.demotions.append(self.n_observed)
        self.drift_demotions.append(self.n_observed)
        self._last_drift_demote = self.n_observed
        self._dwell = 0
        self._window.clear()
        return True

    def _success_rate(self) -> float:
        return float(np.mean([s for s, _ in self._window]))

    def _ready(self) -> bool:
        if self._dwell < self.min_dwell or \
                len(self._window) < self.window_size:
            return False
        if self._success_rate() < self.promote_success:
            return False
        if self.promote_p50 is not None:
            lat = np.asarray([l for _, l in self._window])
            if float(np.percentile(lat, 50)) > self.promote_p50:
                return False
        return True

    def stats(self) -> dict:
        return {"stage": self.stage, "observed": self.n_observed,
                "promotions": list(self.promotions),
                "demotions": list(self.demotions),
                "drift_demotions": list(self.drift_demotions)}
