"""Versioned policy store with a shadow-evaluation gate and atomic
hot-swap.

Every accepted policy version is committed through `repro.checkpoint`
(`Checkpointer` + the shared `agent_state` layout, so offline training
checkpoints and online versions are interchangeable). Before a candidate
ever serves traffic it must pass the gate:

  1. finite-params guard — a corrupted candidate (NaN/Inf anywhere in
     actor/critic) is rejected without spending a single probe run;
  2. shadow evaluation — candidate and incumbent are both replayed
     greedy (argmax, explore=False) over a fixed held-out probe set ON
     THE LIVE DATABASE — i.e. against post-delta data, which is the
     point of re-gating after drift. Scores are mean virtual latency
     (failures already carry the timeout), so gate decisions are
     deterministic;
  3. accept iff candidate_score <= incumbent_score * (1+rel_tol)+abs_tol
     ("no worse", with slack for ties).

On accept the candidate's params are deep-copied onto the serving agent
(`install_agent_state(copy=True)` — the learner keeps donating its own
buffers to XLA, so the serving agent must never alias them) between
scheduler ticks, which is what makes the swap atomic: every query decides
all its steps against a consistent params version, and the next tick's
batch sees the new one. On reject the serving agent is untouched and
serving continues on the incumbent. `rollback` reinstalls any committed
version (newest by default) — the recourse when a swap that passed the
gate regresses later.

`mode="shadow"` evaluates and records verdicts but never swaps — a canary
mode, also used by the benchmark to price the full learning overhead
against a bit-identical serving run.
"""
from __future__ import annotations

import logging
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.checkpoint import (Checkpointer, agent_state, install_agent_state,
                              params_finite)
from repro.core.rollout import rollout

log = logging.getLogger("repro.learn")


class PolicyStore:
    def __init__(self, directory, probe: Sequence, *, rel_tol: float = 0.0,
                 abs_tol: float = 1e-6, keep_last: int = 5,
                 mode: str = "gate", probe_reuse_stages: bool = True):
        """probe_reuse_stages=True lets probe runs share the serving stage
        cache: results are bit-identical either way (the cache invariant),
        and repeated gates then cost near-zero host time; set False for
        fully cache-isolated evaluation."""
        assert mode in ("gate", "shadow"), mode
        self.ckpt = Checkpointer(directory, keep_last=keep_last)
        self.probe = list(probe)
        self.rel_tol, self.abs_tol = rel_tol, abs_tol
        self.mode = mode
        self.probe_reuse_stages = probe_reuse_stages
        self.versions: List[Dict] = []      # committed (accepted) versions
        self.gate_log: List[Dict] = []      # every gate verdict
        self.serving_step: Optional[int] = None
        # incumbent probe score, keyed on (serving_step, data versions):
        # it can only change after a swap/rollback or a delta, so gates in
        # between skip re-probing the incumbent
        self._inc_score: Optional[tuple] = None
        self.probe_log: List[Dict] = []     # one record per set_probe
        # optional observability sink (serve.obs.Tracer): wired by
        # whatever owns both the store and a traced scheduler (the
        # learner / breaker attach seams); None = silent
        self.obs = None

    def _emit(self, kind: str, attrs: Dict) -> None:
        if self.obs is not None:
            self.obs.event(kind, attrs)

    # ------------------------------------------------------------ probe set
    def set_probe(self, probe: Sequence, *, reason: str = "") -> None:
        """Swap the held-out probe set (the drift control plane re-samples
        it to cover drifted templates/tables instead of the fixed list).
        Invalidates the cached incumbent score: it was measured on the OLD
        probes and must not gate candidates against the new ones."""
        self.probe = list(probe)
        self._inc_score = None
        self.probe_log.append({"n": len(self.probe), "reason": reason,
                               "names": [getattr(q, "name", str(q))
                                         for q in self.probe]})

    def note_stats_refresh(self) -> None:
        """A catalog re-ANALYZE changed the Estimator the probe rollouts
        plan with (data versions did NOT move, so the version-keyed cache
        would wrongly survive): drop the cached incumbent score."""
        self._inc_score = None

    # ---------------------------------------------------------- evaluation
    def probe_score(self, agent, db, est, cluster) -> float:
        """Mean greedy virtual latency over the probe set on the live
        db (post-delta data — the point of re-gating after drift).

        Probes run at stage 3 (full action space): the gate compares the
        policies' full capability. If the serving scheduler is currently
        curriculum-restricted to a lower stage, both incumbent and
        candidate serve under the same tighter mask — the gate bounds
        capability, not the exact restricted-serving distribution."""
        lats = [rollout(db, q, est, agent, stage=3, explore=False,
                        cluster=cluster,
                        reuse_stages=self.probe_reuse_stages).result.latency
                for q in self.probe]
        return float(np.mean(lats)) if lats else 0.0

    # ------------------------------------------------------------- commits
    def commit(self, agent, step: int, extra: Optional[Dict] = None) -> int:
        """Version `agent`'s params atomically (manifest-fenced). `step`
        is a hint: if it collides with a step already on disk (e.g. a
        reused store directory from a previous run — Checkpointer.save
        silently skips existing steps), the next free step is used, so a
        commit ALWAYS writes the params it claims to. Returns the step
        actually committed."""
        step = max([self.ckpt.next_step(step)] +
                   [v["step"] + 1 for v in self.versions])
        if not self.ckpt.save(step, agent_state(agent),
                              extra=dict(extra or {})):
            raise RuntimeError(f"policy version step {step} was not "
                               f"written (step already on disk?)")
        self.versions.append({"step": step, **(extra or {})})
        self.serving_step = step
        self._emit("policy_commit", {"step": step})
        return step

    def evaluate_and_maybe_swap(self, serving_agent, candidate_agent, *,
                                db, est, cluster, step: int) -> Dict:
        """Run the gate; on accept (and mode="gate"), hot-swap the serving
        agent's params and commit the new version. Returns the verdict."""
        rec = {"step": step, "accepted": False, "swapped": False,
               "reason": "", "candidate_score": None, "incumbent_score": None}
        if not self.probe:
            # fail CLOSED: with nothing to evaluate on, "no worse" cannot
            # be demonstrated, so no candidate ever swaps in
            rec["reason"] = "empty probe set"
            self.gate_log.append(rec)
            log.info("gate@%d: REJECT (%s)", step, rec["reason"])
            self._emit("gate_eval", {"step": step, "accepted": False,
                                     "reason": rec["reason"]})
            return rec
        if not params_finite(candidate_agent):
            rec["reason"] = "non-finite candidate params"
            self.gate_log.append(rec)
            log.info("gate@%d: REJECT (%s)", step, rec["reason"])
            self._emit("gate_eval", {"step": step, "accepted": False,
                                     "reason": rec["reason"]})
            return rec
        cand = self.probe_score(candidate_agent, db, est, cluster)
        inc_key = (self.serving_step,
                   tuple(sorted(getattr(db, "versions", {}).items())))
        if self._inc_score is not None and self._inc_score[0] == inc_key:
            inc = self._inc_score[1]
        else:
            inc = self.probe_score(serving_agent, db, est, cluster)
            self._inc_score = (inc_key, inc)
        rec["candidate_score"], rec["incumbent_score"] = cand, inc
        if cand <= inc * (1.0 + self.rel_tol) + self.abs_tol:
            rec["accepted"] = True
            if self.mode == "gate":
                prior_step = self.serving_step
                install_agent_state(serving_agent,
                                    agent_state(candidate_agent), copy=True)
                rec["step"] = self.commit(serving_agent, step,
                                          extra={"probe_score": cand,
                                                 "incumbent_score": inc})
                rec["swapped"] = True
                # explicit swap marker (commit fires for offline versions
                # too): the monitor's RCA joins anomaly windows against it
                self._emit("policy_swap", {"from_step": prior_step,
                                           "to_step": rec["step"],
                                           "candidate_score": round(cand, 6),
                                           "incumbent_score": round(inc, 6)})
                # the new incumbent IS the candidate just scored
                self._inc_score = ((self.serving_step, inc_key[1]), cand)
        else:
            rec["reason"] = (f"candidate {cand:.3f}s worse than "
                             f"incumbent {inc:.3f}s")
        self.gate_log.append(rec)
        log.info("gate@%d: %s cand=%.3fs inc=%.3fs%s", step,
                 "ACCEPT" if rec["accepted"] else "REJECT", cand, inc,
                 " (shadow)" if self.mode == "shadow" else "")
        self._emit("gate_eval", {
            "step": rec["step"], "accepted": rec["accepted"],
            "swapped": rec["swapped"], "reason": rec["reason"],
            "candidate_score": round(cand, 6),
            "incumbent_score": round(inc, 6)})
        return rec

    # ------------------------------------------------------------ rollback
    def rollback(self, agent, step: Optional[int] = None) -> int:
        """Reinstall a committed version. Default: the newest version
        BEFORE the one currently serving (the newest overall would be the
        just-regressed version itself); falls back to the newest valid
        checkpoint when no prior one survives retention."""
        if step is None and self.serving_step is not None:
            prior = [s for s in self.ckpt.steps() if s < self.serving_step]
            if prior:
                step = max(prior)
        tree, s, _ = self.ckpt.restore(agent_state(agent), step)
        install_agent_state(agent, tree, copy=True)
        prior = self.serving_step
        self.serving_step = s
        log.info("rollback: serving policy restored to step %d", s)
        self._emit("policy_rollback", {"from_step": prior, "to_step": s})
        return s

    def stats(self) -> Dict:
        return {"mode": self.mode, "n_versions": len(self.versions),
                "n_gates": len(self.gate_log),
                "n_accepted": sum(g["accepted"] for g in self.gate_log),
                "serving_step": self.serving_step}
