"""Prioritized experience replay for the lifelong-learning loop.

One `Experience` per served query: the full trajectory the scheduler
already produced (states/actions/logps/masks/rewards plus the terminal
latency baked into `traj.t_execute`), tagged with the per-table data
versions in force when the query finished. Priorities combine three
signals:

  recency         geometric decay in completions since harvest — the
                  serving distribution is the training distribution, and
                  it drifts;
  latency regret  how much worse this execution was than the best
                  completion seen for the same query template — high-
                  regret experience carries the gradient that actually
                  moves tail latency (outright failures get a further
                  `fail_boost`: timeouts/OOMs are the tail);
  freshness       experiences whose table-version tags still match the
                  live database outweigh pre-delta experience by
                  `fresh_boost` — after a delta lands, the old rows'
                  latencies describe a table that no longer exists.

Sampling is weighted-without-replacement from a caller-supplied seeded
`numpy` Generator, so a fixed seed makes the whole online loop
bit-reproducible.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Experience:
    seq: int                          # stream position of the completion
    query_name: str
    traj: object                      # core.rollout.Trajectory
    latency: float                    # virtual seconds (timeout if failed)
    failed: bool
    finish_t: float
    tables: Tuple[str, ...]           # base tables the query touches
    versions: Dict[str, int]          # per-table versions at completion
    harvest_idx: int = -1             # completion count at harvest time
    # failure-recovery tags (serve.recover): exactly ONE Experience is
    # harvested per query — the FINAL attempt's — so replay never
    # double-counts a retried query; these record what it took.
    attempts: int = 1                 # lane admissions the query consumed
    recovered: bool = False           # succeeded after >=1 failed attempt
    hedged: bool = False              # resolved through a hedge race


class ReplayBuffer:
    """Bounded FIFO of Experiences with recency x regret x freshness
    prioritized sampling."""

    def __init__(self, capacity: int = 512, *, recency_decay: float = 0.98,
                 regret_scale: float = 1.0, regret_cap: float = 4.0,
                 fresh_boost: float = 4.0, fail_boost: float = 2.0):
        assert 0.0 < recency_decay <= 1.0
        self.capacity = capacity
        self.recency_decay = recency_decay
        self.regret_scale = regret_scale
        self.regret_cap = regret_cap
        self.fresh_boost = fresh_boost
        self.fail_boost = fail_boost
        self._buf: deque = deque(maxlen=capacity)  # O(1) FIFO eviction
        self._best: Dict[str, float] = {}   # per-template best latency seen
        self.n_added = 0
        self.n_evicted = 0

    def __len__(self) -> int:
        return len(self._buf)

    def add(self, exp: Experience) -> None:
        exp.harvest_idx = self.n_added
        self.n_added += 1
        b = self._best.get(exp.query_name)
        if b is None or exp.latency < b:
            self._best[exp.query_name] = exp.latency
        if len(self._buf) == self.capacity:
            self.n_evicted += 1          # deque(maxlen) drops the oldest
        self._buf.append(exp)

    def regret(self, exp: Experience) -> float:
        """Relative latency regret vs the best seen for this template."""
        return self.regret_for(exp.query_name, exp.latency)

    def regret_for(self, query_name: str, latency: float) -> float:
        """Relative latency regret of one observation vs the best latency
        seen for its template (0.0 for a never-seen template). The drift
        detector reads this per completion: sustained regret on a
        template's tables is execution-level evidence the data moved."""
        best = self._best.get(query_name, latency)
        return (latency - best) / max(best, 1e-9)

    def priorities(self, current_versions: Dict[str, int]) -> np.ndarray:
        now = self.n_added
        out = np.empty(len(self._buf), np.float64)
        for i, e in enumerate(self._buf):
            w = self.recency_decay ** (now - 1 - e.harvest_idx)
            w *= 1.0 + self.regret_scale * min(self.regret(e), self.regret_cap)
            if e.failed:               # timeouts/OOMs carry the strongest
                w *= self.fail_boost   #   unlearning gradient
            fresh = all(current_versions.get(t, 0) == e.versions.get(t, 0)
                        for t in e.tables)
            if fresh:
                w *= self.fresh_boost
            out[i] = w
        return out

    def sample(self, k: int, rng: np.random.Generator,
               current_versions: Optional[Dict[str, int]] = None
               ) -> List[Experience]:
        """k experiences, weighted without replacement (deterministic given
        `rng`'s state). Returns fewer than k only if the buffer is small."""
        if not self._buf:
            return []
        p = self.priorities(current_versions or {})
        p = p / p.sum()
        k = min(k, len(self._buf))
        idx = rng.choice(len(self._buf), size=k, replace=False, p=p)
        return [self._buf[i] for i in idx]

    def all(self) -> List[Experience]:
        """Every buffered experience in stream (seq) order."""
        return sorted(self._buf, key=lambda e: e.seq)

    def stats(self) -> Dict[str, float]:
        return {"size": len(self._buf), "added": self.n_added,
                "evicted": self.n_evicted,
                "templates": len(self._best)}
