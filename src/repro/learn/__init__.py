"""Lifelong learning loop: train DURING serving, behind a safety gate.

The serving subsystem (`repro.serve`) runs a frozen policy; this package
closes the serve→train loop the paper's online re-optimization story
needs. Five cooperating pieces, each in its own module:

  harvest.py       `TrajectoryHarvester` — opt-in hook on the scheduler's
                   completion stream; records the per-stage observations/
                   actions/rewards serving already computed, tagged with
                   per-table data versions at finish time.

  replay.py        `ReplayBuffer` — bounded, prioritized by recency ×
                   latency-regret × version freshness, so post-delta
                   experience outweighs experience from data that no
                   longer exists.

  learner.py       `BackgroundLearner` — deterministic `ppo_update_batch`
                   steps interleaved with scheduler ticks (at most one
                   update per K completions) on a CLONE of the serving
                   agent; never mutates serving params directly.

  curriculum.py    `AdaptiveCurriculum` — the paper's staged action
                   schedule driven by live rolling success-rate/latency
                   stats instead of an episode counter.

  policy_store.py  `PolicyStore` — versions params via repro.checkpoint,
                   shadow-evaluates each candidate on a held-out probe
                   set against the incumbent on the live database, and
                   atomically hot-swaps the serving agent only when the
                   candidate is no worse — with rollback.

Dataflow: scheduler completions → harvester → replay → learner →
policy-store gate → (hot-swap) scheduler's agent. Everything runs on
virtual-clock event order with seeded RNGs, so a served run is
bit-reproducible with learning on. See src/repro/serve/README.md.
"""
from __future__ import annotations

_EXPORTS = {
    "Experience": "repro.learn.replay",
    "ReplayBuffer": "repro.learn.replay",
    "TrajectoryHarvester": "repro.learn.harvest",
    "AdaptiveCurriculum": "repro.learn.curriculum",
    "PolicyStore": "repro.learn.policy_store",
    "BackgroundLearner": "repro.learn.learner",
    "LearnStats": "repro.learn.learner",
    "make_online_loop": "repro.learn.learner",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(target), name)
