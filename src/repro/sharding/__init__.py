from repro.sharding.rules import (batch_specs, cache_specs, param_specs,
                                  MeshAxes)

__all__ = ["batch_specs", "cache_specs", "param_specs", "MeshAxes"]
