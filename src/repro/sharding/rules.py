"""Sharding rule engine: pytree -> PartitionSpec tree.

Megatron/MaxText-style named rules with divisibility fallback so that the
same rules serve every assigned architecture (head counts from 6 to 64,
vocabs from 51865 to 256000) without per-arch hand specs:

  * COL weights (qkv/gate/up/router/in-proj):   in -> FSDP axis, out -> TP axis
  * ROW weights (o/down/out-proj):              in -> TP axis,   out -> FSDP axis
  * expert weights (E, D, F):                   E  -> TP axis (expert parallel),
                                                largest remaining divisible -> FSDP
  * embed (V, D): V -> TP (vocab-parallel logits), D -> FSDP; non-divisible
    vocabs (73448, 51865) fall back to D -> TP.
  * norms / biases / scalars / small state: replicated.

Params are stacked on a leading superblock axis (lax.scan) which is never
sharded. Parameters are *never* sharded across the "pod" axis: pods are pure
data-parallel replicas (a pod loss only costs its data shard — see DESIGN.md
§5), so every rule here names only "data"/"model".

If a dim is not divisible by its mesh axis, that assignment is dropped
(never an error): whisper-tiny ends up mostly replicated, which is correct —
it is 4 layers of d=384.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Axis names + sizes of the active mesh, plus role mapping."""
    data: str = "data"            # FSDP / batch axis
    model: str = "model"          # TP / expert axis
    pod: Optional[str] = None     # pure-DP outer axis (multi-pod)
    sizes: Tuple[Tuple[str, int], ...] = (("data", 16), ("model", 16))

    def size(self, name: Optional[str]) -> int:
        if name is None:
            return 1
        return dict(self.sizes)[name]

    @property
    def dp_axes(self):
        """Axes for sharding the batch dim (pod-major)."""
        return (self.pod, self.data) if self.pod else (self.data,)

    @property
    def dp_size(self) -> int:
        return self.size(self.pod) * self.size(self.data)

    @classmethod
    def from_mesh(cls, mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        sizes = tuple(zip(names, mesh.devices.shape))
        if "pod" in names:
            return cls(pod="pod", sizes=sizes)
        return cls(sizes=sizes)


# --------------------------------------------------------------- param rules
# leaf-name patterns -> kind
_ROW = re.compile(r"^(wo|w_down|mamba_out|mamba_dtproj)$")
_COL = re.compile(r"^(wq|wk|wv|w_gate|w_up|wq_a|wq_b|wkv_a|wkv_b|mamba_in|"
                  r"mamba_xproj|lm_head|router)$")
_EXPERT = re.compile(r"^moe_w[gud]$")
_BIAS = re.compile(r".*_bias$")


def _fit(dim: int, axis: Optional[str], axes: MeshAxes) -> Optional[str]:
    """Return axis if dim divides evenly over it, else None."""
    if axis is None or dim % axes.size(axis) != 0:
        return None
    return axis


def _dense_spec(shape, axes: MeshAxes, *, row: bool, skip_leading: int):
    """2D dense weight (possibly with leading stack axes)."""
    spec = [None] * len(shape)
    i_in, i_out = skip_leading, len(shape) - 1
    in_ax, out_ax = ((axes.model, axes.data) if row else (axes.data, axes.model))
    spec[i_in] = _fit(shape[i_in], in_ax, axes)
    spec[i_out] = _fit(shape[i_out], out_ax, axes)
    if spec[i_in] is not None and spec[i_in] == spec[i_out]:
        spec[i_out] = None
    return P(*spec)


def _expert_spec(shape, axes: MeshAxes, skip_leading: int):
    """(..., E, D, F): experts -> model, largest remaining divisible -> data."""
    spec = [None] * len(shape)
    e = skip_leading
    spec[e] = _fit(shape[e], axes.model, axes)
    rest = list(range(e + 1, len(shape)))
    rest.sort(key=lambda i: -shape[i])
    for i in rest:
        if _fit(shape[i], axes.data, axes):
            spec[i] = axes.data
            break
    return P(*spec)


def _embed_spec(shape, axes: MeshAxes):
    V, D = shape
    v_ax = _fit(V, axes.model, axes)
    d_ax = _fit(D, axes.data, axes)
    if v_ax is None:                       # odd vocab: TP the feature dim
        v_ax, d_ax = _fit(V, axes.data, axes), _fit(D, axes.model, axes)
    return P(v_ax, d_ax)


def _leaf_spec(path, leaf, axes: MeshAxes):
    name = path[-1] if path else ""
    shape = leaf.shape
    # leading lax.scan stack axis on everything under "stack"
    skip = 1 if (len(path) >= 2 and path[0] == "stack") else 0
    if name == "embed":
        return _embed_spec(shape, axes)
    if name == "pos_embed":
        return P(*([None] * len(shape)))
    if _EXPERT.match(name):
        return _expert_spec(shape, axes, skip)
    if _ROW.match(name):
        return _dense_spec(shape, axes, row=True, skip_leading=skip)
    if _COL.match(name):
        return _dense_spec(shape, axes, row=False, skip_leading=skip)
    if _BIAS.match(name) or len(shape) - skip <= 1:
        return P(*([None] * len(shape)))   # norms / biases / scalars: replicate
    if name == "mamba_A_log":              # (di, N): di -> model
        spec = [None] * len(shape)
        spec[skip] = _fit(shape[skip], axes.model, axes)
        return P(*spec)
    if name == "mamba_conv_w":             # (W, di): di -> model
        spec = [None] * len(shape)
        spec[-1] = _fit(shape[-1], axes.model, axes)
        return P(*spec)
    # default: largest axis -> data if divisible
    spec = [None] * len(shape)
    dims = sorted(range(skip, len(shape)), key=lambda i: -shape[i])
    for i in dims:
        if _fit(shape[i], axes.data, axes):
            spec[i] = axes.data
            break
    return P(*spec)


def _path_names(kp):
    out = []
    for k in kp:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def param_specs(params, axes: MeshAxes):
    """PartitionSpec tree matching a params (or opt-state) pytree. Works on
    concrete arrays or ShapeDtypeStructs."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _leaf_spec(_path_names(kp), leaf, axes), params)


# --------------------------------------------------------------- data rules
def batch_specs(batch, axes: MeshAxes):
    """Shard dim 0 (global batch) over (pod, data) when divisible; a batch of
    1 (long_500k) falls back to sequence sharding over data."""
    def leaf(x):
        spec = [None] * len(x.shape)
        if len(x.shape) == 0:
            return P()
        if x.shape[0] % axes.dp_size == 0:
            spec[0] = axes.dp_axes if axes.pod else axes.data
        elif len(x.shape) > 1 and x.shape[1] % axes.dp_size == 0:
            spec[1] = axes.dp_axes if axes.pod else axes.data
        return P(*spec)
    return jax.tree_util.tree_map(leaf, batch)


def cache_specs(cache, axes: MeshAxes, kv_seq: bool = False):
    """kv_seq=True: prefer sharding the KV sequence axis over the model
    axis (flash-decoding style) instead of heads/head_dim — head_dim is a
    CONTRACTING dim in attention scores, so sharding it forces a per-layer
    all-reduce of the (B,H,1,S) score tensor; sequence sharding reduces the
    cross-shard exchange to softmax stats (§Perf decode hillclimb)."""
    return _cache_specs(cache, axes, kv_seq)


def _cache_specs(cache, axes: MeshAxes, kv_seq: bool = False):
    """Decode-cache sharding. Entries are stacked (n_superblocks, B, ...):

      k/v/ck/cv (S, B, T, K, hd): B -> dp if divisible, else T (seq) -> dp
        (flash-decoding-style KV sequence sharding for batch-1 long context);
        K heads -> model if divisible, else head_dim -> model, else T -> model.
      ckv/krope (MLA latents) (S, B, T, r): B -> dp else T -> dp; r -> model.
      conv/ssm (mamba): d_inner -> model, B -> dp.
      pos scalars: replicated.
    """
    def leaf_spec(path, x):
        name = path[-1]
        shape = x.shape
        spec = [None] * len(shape)
        if name == "pos" or len(shape) <= 1:
            return P(*spec)
        dp = axes.dp_axes if axes.pod else axes.data
        B = shape[1]
        used_dp_on_seq = False
        if B % axes.dp_size == 0 and B > 1:
            spec[1] = dp
        elif len(shape) > 2 and shape[2] % axes.dp_size == 0:
            spec[2] = dp                                  # seq-shard the cache
            used_dp_on_seq = True
        if name in ("conv", "ssm"):                       # (S,B,*,di,*) style
            i = 2 if name == "conv" else 2                # conv:(S,B,W-1,di) ssm:(S,B,di,N)
            i = len(shape) - 2 if name == "ssm" else len(shape) - 1
            if spec[i] is None and shape[i] % axes.size(axes.model) == 0:
                spec[i] = axes.model
            return P(*spec)
        if name in ("ckv", "krope"):
            i = len(shape) - 1
            if shape[i] % axes.size(axes.model) == 0:
                spec[i] = axes.model
            return P(*spec)
        # attention k/v/ck/cv: (S, B, T, K, hd). Default preference after
        # the §Perf decode hillclimb: KV heads if divisible, else the
        # SEQUENCE axis (flash-decoding; −59..76% on the bound vs the old
        # head_dim fallback, which all-reduced scores every layer), else
        # head_dim as the last resort.
        K_i, hd_i, T_i = len(shape) - 2, len(shape) - 1, 2
        if (kv_seq and not used_dp_on_seq
                and shape[T_i] % axes.size(axes.model) == 0):
            spec[T_i] = axes.model
        elif not kv_seq and shape[K_i] % axes.size(axes.model) == 0:
            spec[K_i] = axes.model
        elif not used_dp_on_seq and shape[T_i] % axes.size(axes.model) == 0:
            spec[T_i] = axes.model
        elif shape[hd_i] % axes.size(axes.model) == 0:
            spec[hd_i] = axes.model
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda kp, x: leaf_spec(_path_names(kp), x), cache)
