"""Activation-sharding policy: explicit with_sharding_constraint hooks.

GSPMD propagation alone picks bad layouts for some of our graphs (e.g. it
re-sharded 4k x 4k attention scores onto the 6-way head axis of
whisper-tiny, replicating the batch and blowing per-device temp to 210 GB).
The model code therefore calls ``constrain(x, {dim: role})`` at a few key
points; the active :class:`ActivationPolicy` maps roles to mesh axes with
divisibility checks. When no policy is set (CPU smoke tests) every call is
a no-op, keeping model code mesh-free.

Roles:
  "dp"  — batch-like dim  -> (pod, data) axes
  "tp"  — model-parallel dim (sequence, heads, vocab, experts, d_ff) -> model
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class ActivationPolicy:
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    dp_size: int = 1
    tp_size: int = 1
    # ---- layout knobs (Plane B / §Perf hillclimb levers) ----
    attn_mode: str = "seq"        # seq | heads | none: which dim of q gets TP
    ce_chunk: Optional[int] = None   # override lm.CE_CHUNK
    remat: str = "full"           # full (nothing_saveable) | dots | none
    attn_remat: bool = False      # recompute attention probs in backward
                                  # (flash-bwd semantics: save only m/l/out)
    mla_absorb: bool = False      # MLA decode: score against the latent
                                  # (absorbed wkv_b), skip cache re-expansion
    attn_scores_bf16: bool = False  # store score/prob tensors in bf16 at
                                    # HBM fusion boundaries (f32 softmax math)
    moe_dispatch: str = "global"  # global | local | shard_map:
                                  #  local = per-block capacity slices
                                  #  shard_map = explicit per-shard dispatch
                                  #    + combine-psum (see models/moe.py)
    mesh: object = None           # concrete Mesh for shard_map dispatch

    def axes_for(self, role: str):
        if role == "dp":
            return (self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0],
                    self.dp_size)
        return self.tp_axis, self.tp_size


def current() -> Optional[ActivationPolicy]:
    return getattr(_state, "policy", None)


@contextlib.contextmanager
def policy(p: Optional[ActivationPolicy]):
    prev = current()
    _state.policy = p
    try:
        yield
    finally:
        _state.policy = prev


def constrain(x, roles: Dict[int, str]):
    """Apply with_sharding_constraint(P(...)) per the active policy.
    Dims whose size does not divide the target axis are left unsharded.
    No-op without a policy (single-host tests)."""
    pol = current()
    if pol is None:
        return x
    spec = [None] * x.ndim
    for dim, role in roles.items():
        axis, size = pol.axes_for(role)
        if size > 1 and x.shape[dim] % size == 0 and x.shape[dim] > 1:
            spec[dim] = axis
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
