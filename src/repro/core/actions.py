"""Action space (Tab. I), masking (§V-B3) and curriculum schedule.

Layout over a workload with at most n tables (d = 2 + (n-1) + C(n,2) + n + 1):

  [cbo(1), cbo(0)] ++ [lead(2..n)] ++ [swap(i,j) for i<j lexicographic]
                   ++ [broadcast(1..n)] ++ [no-op]

AQORA's *default* action space enables the cbo / lead / no-op families
(§VII-D: swap is subsumed by lead in practice; broadcast destabilizes
training by broadcasting oversized tables) — the other families exist for
the action-space ablation and are masked out by configuration, exactly how
the paper reports it.

Curriculum (§V-B3): stage 1 exposes only cbo(0/1)+no-op; stage 2 lifts the
mask on runtime plan adjustments (lead/swap once true cardinalities exist,
i.e. after the first stage completes); stage 3 removes every restriction
except invalid-action masking. Offline training walks the stages at fixed
episode fractions (`curriculum_stage`); the serving-time loop promotes on
live rolling stats instead (`learn.curriculum.AdaptiveCurriculum`).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

import numpy as np

from repro.sql import cbo as cbo_mod
from repro.sql.executor import RuntimeState, planned_shuffles
from repro.sql.plans import (apply_broadcast, apply_lead, apply_swap,
                             leaves, syntactic_plan)


@dataclasses.dataclass(frozen=True)
class ActionSpace:
    n: int                                  # max tables in the workload
    families: Tuple[str, ...] = ("cbo", "lead", "noop")

    @property
    def pairs(self) -> List[Tuple[int, int]]:
        return list(itertools.combinations(range(1, self.n + 1), 2))

    @property
    def d(self) -> int:
        n = self.n
        return 2 + (n - 1) + n * (n - 1) // 2 + n + 1

    # ---- index blocks
    @property
    def lead_off(self) -> int:
        return 2

    @property
    def swap_off(self) -> int:
        return 2 + (self.n - 1)

    @property
    def bcast_off(self) -> int:
        return self.swap_off + self.n * (self.n - 1) // 2

    @property
    def noop_idx(self) -> int:
        return self.d - 1

    def decode(self, idx: int):
        if idx == 0:
            return ("cbo", 1)
        if idx == 1:
            return ("cbo", 0)
        if idx < self.swap_off:
            return ("lead", idx - self.lead_off + 2)       # lead(2..n)
        if idx < self.bcast_off:
            i, j = self.pairs[idx - self.swap_off]
            return ("swap", i, j)
        if idx < self.noop_idx:
            return ("broadcast", idx - self.bcast_off + 1)
        return ("noop",)


def curriculum_stage(episode: int, total: int,
                     fractions=(0.25, 0.55)) -> int:
    f = episode / max(total, 1)
    if f < fractions[0]:
        return 1
    if f < fractions[1]:
        return 2
    return 3


def action_mask(space: ActionSpace, state: RuntimeState, stage: int = 3,
                query=None) -> np.ndarray:
    """Legality x curriculum x configured-families mask."""
    query = query or state.query
    m = np.zeros(space.d, np.float32)
    m[space.noop_idx] = 1.0
    fams = set(space.families)
    lvs = leaves(state.plan)
    n_l = len(lvs)
    pre_exec = state.stages_done == 0 and state.step == 0
    runtime_ok = stage >= 3 or (stage >= 2 and state.stages_done >= 1)

    if "cbo" in fams and pre_exec and stage >= 1:
        m[0] = 1.0
        m[1] = 1.0
    if "lead" in fams and runtime_ok:
        for i in range(2, min(n_l, space.n) + 1):
            if apply_lead(query, state.plan, i) is not None:
                m[space.lead_off + i - 2] = 1.0
    if "swap" in fams and runtime_ok:
        for k, (i, j) in enumerate(space.pairs):
            if j <= n_l and apply_swap(query, state.plan, i, j) is not None:
                m[space.swap_off + k] = 1.0
    if "broadcast" in fams and runtime_ok:
        for i in range(1, min(n_l, space.n) + 1):
            if not lvs[i - 1].broadcast_hint:
                m[space.bcast_off + i - 1] = 1.0
    return m


def apply_action(space: ActionSpace, state: RuntimeState, idx: int):
    """Returns (new_plan_or_None, shaping_reward, extra_plan_seconds).

    r = -(Δ planned shuffles)/10 (§V-A1c): no-op never adds shuffles, so it
    earns 0; actions that add shuffles are penalized immediately.
    """
    act = space.decode(idx)
    if act[0] == "noop":               # no plan change, no Δshuffles walk
        return None, 0.0, 0.0
    before = planned_shuffles(state.plan, state)
    extra_plan = 0.0
    if act[0] == "cbo":
        if act[1] == 1:
            plan, t = cbo_mod.cbo_plan(state.query, state.est)
            extra_plan = t
        else:
            plan = syntactic_plan(state.query)
    elif act[0] == "lead":
        plan = apply_lead(state.query, state.plan, act[1])
    elif act[0] == "swap":
        plan = apply_swap(state.query, state.plan, act[1], act[2])
    elif act[0] == "broadcast":
        plan = apply_broadcast(state.plan, act[1])
    else:
        raise ValueError(act)
    if plan is None:
        return None, 0.0, extra_plan
    after = planned_shuffles(plan, state)
    return plan, -(after - before) / 10.0, extra_plan
