"""Vectorized rollout engine: B queries executed in lockstep (§IV at
batch granularity — the training hot path of the framework).

Since the online serving subsystem landed, lockstep batching is a
SCHEDULER POLICY, not a separate engine: `rollout_batch` admits its B
queries as one wave into `serve.scheduler.LaneScheduler(policy=
"lockstep")`, which per tick gathers every suspended lane into ONE jitted
`agent.act_batch` call (masked categorical, per-lane PRNG advanced
in-kernel, a single device sync per step), applies Alg. 2 per lane, and
resumes each `sql.executor.AdaptiveRun` to its next stage boundary.

Lanes that finish drop out of the batch (their slots are padded with a
noop-only mask); the wave barriers until every lane has produced a
RunResult. Per-lane PRNG chains are keyed by `seeds` and advance exactly
like `core.rollout.rollout(..., key=seed)` — a seeded serial rollout, one
lane of this lockstep wave, and one async serving lane
(`LaneScheduler(policy="async")`) all take identical actions, so the
paths are interchangeable evidence-wise and differ only in scheduling.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.rollout import Trajectory
from repro.serve.scheduler import Arrival, LaneScheduler
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel


def rollout_batch(db, queries: Sequence, est: Estimator, agent, *,
                  stage: int = 3, explore: bool = True,
                  cluster: Optional[ClusterModel] = None,
                  seeds: Optional[Sequence] = None) -> List[Trajectory]:
    """Run `queries` in lockstep; returns one Trajectory per query.

    `seeds[i]` keys lane i's action sampling (defaults to 0..B-1); a serial
    `rollout(db, queries[i], ..., key=seeds[i])` reproduces lane i exactly.
    """
    B = len(queries)
    if seeds is None:
        seeds = list(range(B))
    assert len(seeds) == B, "one seed per lane"
    sched = LaneScheduler(db, est, agent, n_lanes=B, stage=stage,
                          explore=explore, cluster=cluster,
                          policy="lockstep")
    comps = sched.run([Arrival(0.0, query=q, seed=s)
                       for q, s in zip(queries, seeds)])
    return [c.traj for c in comps]
