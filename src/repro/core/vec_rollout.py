"""Vectorized rollout engine: B queries executed in lockstep (§IV at
batch granularity — the training/inference hot path of the framework).

Each query runs inside a resumable `sql.executor.AdaptiveRun`, suspended at
its stage boundaries. One lockstep step:

  1. encode every suspended lane's RuntimeState (host numpy) and pad the
     batch into one (B, MAX_NODES, F) block;
  2. ONE jitted `agent.act_batch` call — batched encoder forward (optionally
     the fused VMEM-resident TreeCNN kernel), masked categorical sample
     with a per-lane PRNG key advanced in-kernel, and a single device sync
     for the whole batch (no per-lane `policy_probs` / `np.asarray`);
  3. scatter actions back: apply Alg. 2 per lane and resume each run.

Lanes that finish drop out of the batch (their slots are padded with a
noop-only mask); the step repeats until every lane has produced a
RunResult. Per-lane PRNG chains are keyed by `seeds`, and advance exactly
like `core.rollout.rollout(..., key=seed)` — a seeded serial rollout and
the batched engine take identical actions, so the two paths are
interchangeable evidence-wise and differ only in throughput.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from repro.core.actions import action_mask, apply_action
from repro.core.encoding import MAX_NODES, encode_state
from repro.core.rollout import Trajectory, as_key, finalize_trajectory
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.executor import AdaptiveRun, RuntimeState
from repro.sql.plans import syntactic_plan


@dataclasses.dataclass
class _Lane:
    run: AdaptiveRun
    traj: Trajectory
    state: Optional[RuntimeState]     # pending suspension (None = finished)
    key: np.ndarray                   # uint32[2] PRNG chain head
    extra_plan: float = 0.0


def rollout_batch(db, queries: Sequence, est: Estimator, agent, *,
                  stage: int = 3, explore: bool = True,
                  cluster: Optional[ClusterModel] = None,
                  seeds: Optional[Sequence] = None) -> List[Trajectory]:
    """Run `queries` in lockstep; returns one Trajectory per query.

    `seeds[i]` keys lane i's action sampling (defaults to 0..B-1); a serial
    `rollout(db, queries[i], ..., key=seeds[i])` reproduces lane i exactly.
    """
    cluster = cluster if cluster is not None else ClusterModel()
    meta = agent.meta
    B = len(queries)
    if seeds is None:
        seeds = list(range(B))
    assert len(seeds) == B, "one seed per lane"
    batched = hasattr(agent, "act_batch")

    lanes: List[_Lane] = []
    for q, s in zip(queries, seeds):
        run = AdaptiveRun(db, q, syntactic_plan(q), est, cluster,
                          max_hook_steps=agent.cfg.max_steps, plan_time=0.0)
        lane = _Lane(run, Trajectory(), None, as_key(s))
        lane.state = run.start()
        lanes.append(lane)

    F = meta.feat_dim
    d = agent.space.d
    while True:
        active = [i for i, l in enumerate(lanes) if l.state is not None]
        if not active:
            break

        # ---- 1. gather + pad pending states into one batch
        feat = np.zeros((B, MAX_NODES, F), np.float32)
        left = np.zeros((B, MAX_NODES), np.int32)
        right = np.zeros((B, MAX_NODES), np.int32)
        mask = np.zeros((B, MAX_NODES), np.float32)
        amask = np.zeros((B, d), np.float32)
        amask[:, agent.space.noop_idx] = 1.0      # padded lanes sample noop
        keys = np.zeros((B, 2), np.uint32)
        encs = {}
        prep_t = {}
        for bi in active:
            l = lanes[bi]
            t0 = time.perf_counter()
            enc = encode_state(l.state, meta)
            am = action_mask(agent.space, l.state, stage=stage)
            feat[bi], left[bi], right[bi], mask[bi] = enc
            amask[bi] = am
            keys[bi] = l.key
            encs[bi] = (enc, am)
            prep_t[bi] = time.perf_counter() - t0

        # ---- 2. one jitted forward + sample, ONE device sync for all lanes
        t0 = time.perf_counter()
        if batched:
            acts, logps, new_keys = agent.act_batch(
                feat, left, right, mask, amask, keys, explore=explore)
        else:                  # value-based agents (DQN) have no batch path
            acts = np.zeros(B, np.int32)
            logps = np.zeros(B, np.float32)
            new_keys = keys
            for bi in active:
                a, lp = agent.act(encs[bi][0], encs[bi][1], explore=explore)
                acts[bi], logps[bi] = a, lp
        act_share = (time.perf_counter() - t0) / max(len(active), 1)

        # ---- 3. scatter actions back, advance every lane one stage
        for bi in active:
            l = lanes[bi]
            t0 = time.perf_counter()
            enc, am = encs[bi]
            a = int(acts[bi])
            l.key = new_keys[bi]
            new_plan, r, extra = apply_action(agent.space, l.state, a)
            l.traj.states.append(enc)
            l.traj.actions.append(a)
            l.traj.logps.append(float(logps[bi]))
            l.traj.masks.append(am)
            l.traj.rewards.append(r)
            l.traj.decoded.append(agent.space.decode(a))
            l.extra_plan += extra
            l.traj.hook_seconds += (prep_t[bi] + act_share
                                    + time.perf_counter() - t0)
            l.state = l.run.resume(new_plan)

    return [finalize_trajectory(l.traj, l.run.result, q, est, agent, cluster,
                                meta, l.extra_plan)
            for l, q in zip(lanes, queries)]
