"""AQORA training + evaluation loops (§V-A4, §VII-A4c).

train_agent: episodes over the training workload with the curriculum
schedule. Serial (`batch_size=1`): one PPO update per completed query (the
paper replays the k-step trajectory after each query, Alg. 1). Batched
(`batch_size=B`): B queries run in lockstep through the vectorized rollout
engine — one policy forward per stage boundary for the whole batch — and
their trajectories are replayed by ONE jitted PPO update per episode-batch
(Alg. 1 semantics per trajectory are unchanged; only the dispatch is
amortized).

evaluate: run test queries with the trained policy (argmax, no
exploration); returns per-query RunResults for the benchmark tables.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.actions import curriculum_stage
from repro.core.agent import AgentConfig, AqoraAgent
from repro.core.encoding import WorkloadMeta
from repro.core.rollout import rollout
from repro.core.vec_rollout import rollout_batch
from repro.sql.catalog import Database
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.workloads import Workload

# training progress goes through logging, NOT stdout: the background
# learner runs this machinery during serving, and a print would land in
# the middle of the service's output stream. Callers that want the old
# behavior opt in via logging.basicConfig(level=logging.INFO).
log = logging.getLogger("repro.train")


@dataclasses.dataclass
class EpisodeLog:
    episode: int
    query: str
    latency: float
    failed: bool
    actions: List
    rewards: List[float]
    actor_loss: float
    critic_loss: float
    stage: int


def train_agent(db: Database, workload: Workload, *,
                episodes: int = 300, seed: int = 0,
                cfg: Optional[AgentConfig] = None,
                cluster: Optional[ClusterModel] = None,
                est: Optional[Estimator] = None,
                use_curriculum: bool = True,
                agent=None,
                batch_size: int = 1,
                log_every: int = 0) -> Tuple[AqoraAgent, List[EpisodeLog]]:
    cfg = cfg if cfg is not None else AgentConfig()
    cluster = cluster if cluster is not None else ClusterModel()
    meta = WorkloadMeta.from_workload(workload)
    if agent is None:
        agent = AqoraAgent(meta, cfg, seed=seed)
    est = est or Estimator(db, db.stats)
    rng = np.random.default_rng(seed)
    logs: List[EpisodeLog] = []

    def log_progress(ep_start, n_eps, stage, m):
        # fire when this (batch of) episode(s) crosses a log_every boundary,
        # so batched runs keep the serial cadence for any log_every
        if log_every and \
                (ep_start + n_eps) // log_every > ep_start // log_every:
            recent = logs[-log_every:]
            lat = np.mean([l.latency for l in recent])
            fails = sum(l.failed for l in recent)
            log.info("  ep %4d stage=%d mean_lat=%7.2fs fails=%d "
                     "aloss=%+.3f", ep_start + n_eps, stage, lat, fails,
                     m["actor_loss"])

    ep = 0
    while ep < episodes:
        stage = curriculum_stage(ep, episodes, cfg.curriculum) \
            if use_curriculum else 3
        if batch_size <= 1:
            q = workload.train[int(rng.integers(len(workload.train)))]
            traj = rollout(db, q, est, agent, stage=stage, explore=True,
                           cluster=cluster)
            m = agent.ppo_update(traj)
            logs.append(EpisodeLog(ep, q.name, traj.t_execute, traj.failed,
                                   traj.decoded, traj.rewards,
                                   m["actor_loss"], m["critic_loss"], stage))
            log_progress(ep, 1, stage, m)
            ep += 1
            continue
        # ---- lockstep episode-batch: B rollouts, ONE jitted PPO update
        bs = min(batch_size, episodes - ep)
        qs = [workload.train[int(rng.integers(len(workload.train)))]
              for _ in range(bs)]
        seeds = [int(rng.integers(2 ** 31)) for _ in range(bs)]
        trajs = rollout_batch(db, qs, est, agent, stage=stage, explore=True,
                              cluster=cluster, seeds=seeds)
        if hasattr(agent, "ppo_update_batch"):
            m = agent.ppo_update_batch(trajs)
        else:                              # e.g. DQN: per-trajectory replay
            for traj in trajs:
                m = agent.ppo_update(traj)
        for i, (q, traj) in enumerate(zip(qs, trajs)):
            logs.append(EpisodeLog(ep + i, q.name, traj.t_execute,
                                   traj.failed, traj.decoded, traj.rewards,
                                   m["actor_loss"], m["critic_loss"], stage))
        log_progress(ep, bs, stage, m)
        ep += bs
    return agent, logs


def evaluate(db: Database, queries, agent: AqoraAgent, *,
             est: Optional[Estimator] = None,
             cluster: Optional[ClusterModel] = None,
             batch_size: int = 1,
             policy: Optional[str] = None) -> List[Dict]:
    """Run test queries with the trained policy (argmax, no exploration).

    policy=None keeps the legacy paths: serial rollouts (batch_size=1) or
    barriered lockstep chunks (batch_size>1). policy="async"/"lockstep"
    routes the whole set through the online serving scheduler
    (`serve.scheduler.LaneScheduler`) with batch_size lanes — per-query
    plans and latencies are identical across all paths; only scheduling
    (and therefore host batching) differs.
    """
    cluster = cluster if cluster is not None else ClusterModel()
    est = est or Estimator(db, db.stats)
    if policy is not None:
        from repro.serve.scheduler import Arrival, LaneScheduler
        sched = LaneScheduler(db, est, agent, n_lanes=max(batch_size, 1),
                              stage=3, explore=False, cluster=cluster,
                              policy=policy)
        comps = sched.run([Arrival(0.0, query=q, seed=i)
                           for i, q in enumerate(queries)])
        trajs = [c.traj for c in comps]
    elif batch_size > 1:
        trajs = []
        for i in range(0, len(queries), batch_size):
            trajs += rollout_batch(db, queries[i:i + batch_size], est, agent,
                                   stage=3, explore=False, cluster=cluster)
    else:
        trajs = [rollout(db, q, est, agent, stage=3, explore=False,
                         cluster=cluster) for q in queries]
    out = []
    for q, traj in zip(queries, trajs):
        r = traj.result
        out.append({
            "query": q.name, "latency": r.latency, "plan_time": r.plan_time,
            "total": r.total, "failed": r.failed,
            "failure_kind": r.failure_kind, "actions": traj.decoded,
            "shuffles": r.total_shuffles,
            "shuffle_bytes": r.total_shuffle_bytes, "bushy": r.bushy,
        })
    return out
