"""AQORA training + evaluation loops (§V-A4, §VII-A4c).

train_agent: episodes over the training workload with the curriculum
schedule; one PPO update per completed query (the paper replays the k-step
trajectory after each query, Alg. 1).

evaluate: run test queries with the trained policy (argmax, no
exploration); returns per-query RunResults for the benchmark tables.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.actions import curriculum_stage
from repro.core.agent import AgentConfig, AqoraAgent
from repro.core.encoding import WorkloadMeta
from repro.core.rollout import rollout
from repro.sql.catalog import Database
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.workloads import Workload


@dataclasses.dataclass
class EpisodeLog:
    episode: int
    query: str
    latency: float
    failed: bool
    actions: List
    rewards: List[float]
    actor_loss: float
    critic_loss: float
    stage: int


def train_agent(db: Database, workload: Workload, *,
                episodes: int = 300, seed: int = 0,
                cfg: AgentConfig = AgentConfig(),
                cluster: ClusterModel = ClusterModel(),
                est: Optional[Estimator] = None,
                use_curriculum: bool = True,
                agent=None,
                log_every: int = 0) -> Tuple[AqoraAgent, List[EpisodeLog]]:
    meta = WorkloadMeta.from_workload(workload)
    if agent is None:
        agent = AqoraAgent(meta, cfg, seed=seed)
    est = est or Estimator(db, db.stats)
    rng = np.random.default_rng(seed)
    logs: List[EpisodeLog] = []
    for ep in range(episodes):
        q = workload.train[int(rng.integers(len(workload.train)))]
        stage = curriculum_stage(ep, episodes, cfg.curriculum) if use_curriculum else 3
        traj = rollout(db, q, est, agent, stage=stage, explore=True,
                       cluster=cluster)
        m = agent.ppo_update(traj)
        logs.append(EpisodeLog(ep, q.name, traj.t_execute, traj.failed,
                               traj.decoded, traj.rewards,
                               m["actor_loss"], m["critic_loss"], stage))
        if log_every and (ep + 1) % log_every == 0:
            recent = logs[-log_every:]
            lat = np.mean([l.latency for l in recent])
            fails = sum(l.failed for l in recent)
            print(f"  ep {ep+1:4d} stage={stage} mean_lat={lat:7.2f}s "
                  f"fails={fails} aloss={m['actor_loss']:+.3f}")
    return agent, logs


def evaluate(db: Database, queries, agent: AqoraAgent, *,
             est: Optional[Estimator] = None,
             cluster: ClusterModel = ClusterModel()) -> List[Dict]:
    est = est or Estimator(db, db.stats)
    out = []
    for q in queries:
        traj = rollout(db, q, est, agent, stage=3, explore=False,
                       cluster=cluster)
        r = traj.result
        out.append({
            "query": q.name, "latency": r.latency, "plan_time": r.plan_time,
            "total": r.total, "failed": r.failed,
            "failure_kind": r.failure_kind, "actions": traj.decoded,
            "shuffles": r.total_shuffles,
            "shuffle_bytes": r.total_shuffle_bytes, "bushy": r.bushy,
        })
    return out
