"""Decision-model encoders: TreeCNN (default) + LSTM / FCNN / tree-
transformer ("QueryFormer-lite") for the paper's Tab. III / Fig. 11(b)
ablation. All share one interface:

  init_encoder(key, kind, feat_dim, hidden) -> params
  apply_encoder(params, kind, feat, left, right, mask) -> (hidden,) pooled

and are pure-JAX, jit/vmap friendly (fixed MAX_NODES padding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import normal_init, split_keys


# ------------------------------------------------------------------ treecnn
def _init_treeconv(key, d_in, d_out):
    ks = split_keys(key, 4)
    s = 1.0 / (3 * d_in) ** 0.5
    return {"wr": normal_init(ks[0], (d_in, d_out), jnp.float32, s),
            "wl": normal_init(ks[1], (d_in, d_out), jnp.float32, s),
            "wrt": normal_init(ks[2], (d_in, d_out), jnp.float32, s),
            "b": jnp.zeros((d_out,), jnp.float32)}


def _apply_treeconv(p, h, left, right, mask):
    """Neo-style binary tree convolution: combine each node with its
    children (null child = slot 0, kept zero)."""
    hl = h[left]
    hr = h[right]
    out = h @ p["wr"] + hl @ p["wl"] + hr @ p["wrt"] + p["b"]
    out = jax.nn.leaky_relu(out)
    return out * mask[:, None]          # re-zero padding (incl. slot 0)


def _init_treecnn(key, feat_dim, hidden):
    ks = split_keys(key, 3)
    return {"conv1": _init_treeconv(ks[0], feat_dim, hidden),
            "conv2": _init_treeconv(ks[1], hidden, hidden),
            "conv3": _init_treeconv(ks[2], hidden, hidden)}


def _apply_treecnn(p, feat, left, right, mask):
    h = _apply_treeconv(p["conv1"], feat * mask[:, None], left, right, mask)
    h = _apply_treeconv(p["conv2"], h, left, right, mask)
    h = _apply_treeconv(p["conv3"], h, left, right, mask) + h
    # dynamic max-pool over real nodes
    neg = jnp.where(mask[:, None] > 0, h, -jnp.inf)
    pooled = jnp.max(neg, axis=0)
    return jnp.where(jnp.isfinite(pooled), pooled, 0.0)


# ------------------------------------------------------------------ lstm
def _init_lstm(key, feat_dim, hidden):
    ks = split_keys(key, 2)
    s = 1.0 / (feat_dim + hidden) ** 0.5
    return {"wx": normal_init(ks[0], (feat_dim, 4 * hidden), jnp.float32, s),
            "wh": normal_init(ks[1], (hidden, 4 * hidden), jnp.float32, s),
            "b": jnp.zeros((4 * hidden,), jnp.float32)}


def _apply_lstm(p, feat, left, right, mask):
    """Pre-order node sequence (the padded order IS pre-order) -> last state."""
    H = p["wh"].shape[0]

    def step(carry, xm):
        h, c = carry
        x, m = xm
        z = x @ p["wx"] + h @ p["wh"] + p["b"]
        i, f, g, o = jnp.split(z, 4)
        c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
        h = m * h_new + (1 - m) * h
        c = m * c_new + (1 - m) * c
        return (h, c), None

    (h, _), _ = jax.lax.scan(step, (jnp.zeros(H), jnp.zeros(H)),
                             (feat, mask))
    return h


# ------------------------------------------------------------------ fcnn
def _init_fcnn(key, feat_dim, hidden, max_nodes):
    ks = split_keys(key, 2)
    d = feat_dim * max_nodes
    return {"w1": normal_init(ks[0], (d, hidden), jnp.float32, d ** -0.5),
            "b1": jnp.zeros((hidden,), jnp.float32),
            "w2": normal_init(ks[1], (hidden, hidden), jnp.float32, hidden ** -0.5),
            "b2": jnp.zeros((hidden,), jnp.float32)}


def _apply_fcnn(p, feat, left, right, mask):
    x = (feat * mask[:, None]).reshape(-1)
    h = jax.nn.leaky_relu(x @ p["w1"] + p["b1"])
    return jax.nn.leaky_relu(h @ p["w2"] + p["b2"])


# ------------------------------------------------------- queryformer-lite
def _init_qf(key, feat_dim, hidden, n_heads=4, n_layers=2):
    ks = split_keys(key, 2 + 4 * n_layers)
    p = {"inp": normal_init(ks[0], (feat_dim, hidden), jnp.float32, feat_dim ** -0.5),
         "layers": []}
    for i in range(n_layers):
        base = 2 + 4 * i
        p["layers"].append({
            "wq": normal_init(ks[base], (hidden, hidden), jnp.float32, hidden ** -0.5),
            "wk": normal_init(ks[base + 1], (hidden, hidden), jnp.float32, hidden ** -0.5),
            "wv": normal_init(ks[base + 2], (hidden, hidden), jnp.float32, hidden ** -0.5),
            "wo": normal_init(ks[base + 3], (hidden, hidden), jnp.float32, hidden ** -0.5),
        })
    return p


def _apply_qf(p, feat, left, right, mask):
    """Self-attention over node tokens with a tree-structure bias: children
    attend to parents (adjacency bias), QueryFormer-style but miniature."""
    h = (feat * mask[:, None]) @ p["inp"]
    N = h.shape[0]
    adj = jnp.zeros((N, N), jnp.float32)
    idx = jnp.arange(N)
    adj = adj.at[idx, left].set(1.0).at[idx, right].set(1.0)
    adj = adj + adj.T + jnp.eye(N)
    bias = jnp.where(adj > 0, 0.0, -4.0)          # soft structural prior
    key_mask = jnp.where(mask > 0, 0.0, -1e9)
    for lp in p["layers"]:
        q, k, v = h @ lp["wq"], h @ lp["wk"], h @ lp["wv"]
        s = q @ k.T / (h.shape[-1] ** 0.5) + bias + key_mask[None, :]
        a = jax.nn.softmax(s, axis=-1)
        h = h + (a @ v) @ lp["wo"]
        h = h * mask[:, None]
    neg = jnp.where(mask[:, None] > 0, h, -jnp.inf)
    pooled = jnp.max(neg, axis=0)
    return jnp.where(jnp.isfinite(pooled), pooled, 0.0)


# ------------------------------------------------------------------ public
def init_encoder(key, kind, feat_dim, hidden, max_nodes=64):
    if kind == "treecnn":
        return _init_treecnn(key, feat_dim, hidden)
    if kind == "lstm":
        return _init_lstm(key, feat_dim, hidden)
    if kind == "fcnn":
        return _init_fcnn(key, feat_dim, hidden, max_nodes)
    if kind == "queryformer":
        return _init_qf(key, feat_dim, hidden)
    raise ValueError(kind)


def apply_encoder(params, kind, feat, left, right, mask, *, fused=False,
                  interpret=None):
    """Single state (N, F) -> (hidden,), or a batch (B, N, F) -> (B, hidden).

    Batched treecnn may lower to the fused VMEM-resident Pallas kernel
    (`fused=True`) — one kernel for all three conv layers + residual +
    masked max-pool, building child one-hots in-kernel. The fused kernel
    carries a custom VJP (backward rematerializes through the jnp
    reference), so it serves training losses as well as rollout inference.
    """
    fn = {"treecnn": _apply_treecnn, "lstm": _apply_lstm,
          "fcnn": _apply_fcnn, "queryformer": _apply_qf}[kind]
    if getattr(feat, "ndim", 2) == 3:          # batched states
        if fused and kind == "treecnn":
            from repro.kernels.tree_conv import tree_cnn_fused
            return tree_cnn_fused(feat, left, right, mask, params,
                                  interpret=interpret)
        return jax.vmap(fn, in_axes=(None, 0, 0, 0, 0))(
            params, feat, left, right, mask)
    return fn(params, feat, left, right, mask)


def init_mlp_head(key, d_in, d_hidden, d_out):
    ks = split_keys(key, 2)
    return {"w1": normal_init(ks[0], (d_in, d_hidden), jnp.float32, d_in ** -0.5),
            "b1": jnp.zeros((d_hidden,), jnp.float32),
            "w2": normal_init(ks[1], (d_hidden, d_out), jnp.float32, d_hidden ** -0.5),
            "b2": jnp.zeros((d_out,), jnp.float32)}


def apply_mlp_head(p, x):
    h = jax.nn.leaky_relu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]
