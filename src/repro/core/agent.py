"""AQORA agent: TreeCNN actor + critic, masked policy, PPO update (Alg. 1).

Actor and critic are separate encoder+head networks (~150k parameters
combined at the defaults, matching Tab. III). All state tensors are padded
to MAX_NODES, trajectories to (max_steps+1) states, so the PPO update jits
once per workload.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nets
from repro.core.actions import ActionSpace
from repro.core.encoding import MAX_NODES, WorkloadMeta
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class AgentConfig:
    net: str = "treecnn"               # treecnn | lstm | fcnn | queryformer
    hidden: int = 96
    head_hidden: int = 96
    families: Tuple[str, ...] = ("cbo", "lead", "noop")
    max_steps: int = 3                 # hook interventions per query (§VI-A)
    ppo_epochs: int = 6
    clip: float = 0.2
    entropy: float = 0.02              # η
    gamma: float = 1.0                 # Alg. 1 sets γ=1
    lr_actor: float = 3e-4
    lr_critic: float = 1e-3
    curriculum: Tuple[float, float] = (0.25, 0.55)
    failure_penalty: float = 300.0     # R(τ) -= sqrt(300) on failure


class AqoraAgent:
    def __init__(self, meta: WorkloadMeta, cfg: AgentConfig = AgentConfig(),
                 seed: int = 0):
        self.meta = meta
        self.cfg = cfg
        self.space = ActionSpace(meta.n_tables_max, cfg.families)
        k = jax.random.split(jax.random.PRNGKey(seed), 5)
        F, H = meta.feat_dim, cfg.hidden
        self.actor = {
            "enc": nets.init_encoder(k[0], cfg.net, F, H, MAX_NODES),
            "head": nets.init_mlp_head(k[1], H, cfg.head_hidden, self.space.d)}
        self.critic = {
            "enc": nets.init_encoder(k[2], cfg.net, F, H, MAX_NODES),
            "head": nets.init_mlp_head(k[3], H, cfg.head_hidden, 1)}
        self.aopt = adamw_init(self.actor)
        self.copt = adamw_init(self.critic)
        self._acfg = AdamWConfig(lr=cfg.lr_actor, weight_decay=0.0, grad_clip=5.0)
        self._ccfg = AdamWConfig(lr=cfg.lr_critic, weight_decay=0.0, grad_clip=5.0)
        self.rng = jax.random.PRNGKey(seed + 1)
        self._build_jits()

    # ------------------------------------------------------------- nets
    def _build_jits(self):
        net = self.cfg.net

        def logits_fn(actor, feat, left, right, mask):
            h = nets.apply_encoder(actor["enc"], net, feat, left, right, mask)
            return nets.apply_mlp_head(actor["head"], h)

        def value_fn(critic, feat, left, right, mask):
            h = nets.apply_encoder(critic["enc"], net, feat, left, right, mask)
            return nets.apply_mlp_head(critic["head"], h)[0]

        self._logits = jax.jit(logits_fn)
        self._value = jax.jit(value_fn)
        self._logits_b = jax.jit(jax.vmap(logits_fn, in_axes=(None, 0, 0, 0, 0)))
        self._value_b = jax.jit(jax.vmap(value_fn, in_axes=(None, 0, 0, 0, 0)))

        clip, eta = self.cfg.clip, self.cfg.entropy

        def masked_logp(actor, feat, left, right, mask, amask):
            lg = jax.vmap(logits_fn, (None, 0, 0, 0, 0))(actor, feat, left, right, mask)
            lg = jnp.where(amask > 0, lg, -1e9)
            return jax.nn.log_softmax(lg, axis=-1)

        def actor_loss(actor, batch):
            logp_all = masked_logp(actor, batch["feat"], batch["left"],
                                   batch["right"], batch["mask"], batch["amask"])
            logp = jnp.take_along_axis(logp_all, batch["action"][:, None], 1)[:, 0]
            ratio = jnp.exp(logp - batch["old_logp"])
            q = batch["q"]
            un = ratio * q
            cl = jnp.clip(ratio, 1 - clip, 1 + clip) * q
            l_clip = -jnp.sum(jnp.minimum(un, cl) * batch["valid"]) / \
                jnp.maximum(batch["valid"].sum(), 1.0)
            p = jnp.exp(logp_all)
            ent_term = jnp.sum(jnp.where(batch["amask"] > 0, p * logp_all, 0.0), -1)
            l_ent = jnp.sum(ent_term * batch["valid"]) / \
                jnp.maximum(batch["valid"].sum(), 1.0)
            return l_clip + eta * l_ent

        def critic_loss(critic, sbatch):
            v = jax.vmap(value_fn, (None, 0, 0, 0, 0))(
                critic, sbatch["feat"], sbatch["left"], sbatch["right"],
                sbatch["mask"])
            err = (v - sbatch["v_target"]) ** 2
            return jnp.sum(err * sbatch["valid"]) / jnp.maximum(sbatch["valid"].sum(), 1.0)

        def update(actor, critic, aopt, copt, batch, sbatch):
            al, agrad = jax.value_and_grad(actor_loss)(actor, batch)
            cl_, cgrad = jax.value_and_grad(critic_loss)(critic, sbatch)
            actor, aopt, _ = adamw_update(actor, agrad, aopt, self._acfg)
            critic, copt, _ = adamw_update(critic, cgrad, copt, self._ccfg)
            return actor, critic, aopt, copt, al, cl_

        self._update = jax.jit(update)

    # ------------------------------------------------------------- policy
    def policy_probs(self, enc_state, amask: np.ndarray) -> np.ndarray:
        feat, left, right, mask = enc_state
        lg = self._logits(self.actor, feat, left, right, mask)
        lg = jnp.where(jnp.asarray(amask) > 0, lg, -1e9)
        return np.asarray(jax.nn.softmax(lg))

    def act(self, enc_state, amask: np.ndarray, explore: bool = True) -> Tuple[int, float]:
        probs = self.policy_probs(enc_state, amask)
        if explore:
            self.rng, k = jax.random.split(self.rng)
            a = int(jax.random.choice(k, len(probs), p=jnp.asarray(probs)))
        else:
            a = int(np.argmax(probs))
        return a, float(np.log(max(probs[a], 1e-12)))

    def value(self, enc_state) -> float:
        feat, left, right, mask = enc_state
        return float(self._value(self.critic, feat, left, right, mask))

    # ------------------------------------------------------------- update
    def ppo_update(self, traj) -> Dict[str, float]:
        """traj: rollout.Trajectory — implements Alg. 1 exactly: v_pi from
        realized returns, q from the CURRENT critic, then e epochs of
        clipped updates against frozen old probabilities."""
        cfg = self.cfg
        k = len(traj.actions)
        if k == 0:
            return {"actor_loss": 0.0, "critic_loss": 0.0}
        K = cfg.max_steps + 1

        def pad_states(states):
            feat = np.zeros((K, MAX_NODES, self.meta.feat_dim), np.float32)
            left = np.zeros((K, MAX_NODES), np.int32)
            right = np.zeros((K, MAX_NODES), np.int32)
            mask = np.zeros((K, MAX_NODES), np.float32)
            for i, s in enumerate(states[:K]):
                feat[i], left[i], right[i], mask[i] = s
            return feat, left, right, mask

        n_states = min(len(traj.states), K)
        feat, left, right, mask = pad_states(traj.states)
        svalid = np.zeros(K, np.float32)
        svalid[:n_states] = 1.0

        # v_pi(s_i) = sum_{j>i} r_j - sqrt(T_execute)   (Alg. 1 line 2; the
        # paper's +sqrt is a sign typo — R(tau) subtracts it)
        rs = np.asarray(traj.rewards, np.float32)
        term = -np.sqrt(traj.t_execute)
        v_pi = np.zeros(K, np.float32)
        for i in range(n_states):
            v_pi[i] = rs[i:].sum() + term

        # q_t = r_{t+1} + v_phi(s_{t+1}) - v_phi(s_t) for every ACTION
        # (Alg. 1's trailing 0 belongs to the terminal state s_k, which has
        # no action). If the terminal state s_k was not encodable, fall back
        # to its realized value v_pi(s_k) = -sqrt(T).
        v_phi = np.asarray(self._value_b(self.critic, feat, left, right, mask))
        q = np.zeros(K - 1, np.float32)
        for t in range(k):
            v_next = v_phi[t + 1] if t + 1 < n_states else term
            q[t] = rs[t] + v_next - v_phi[t]

        amask = np.zeros((K - 1, self.space.d), np.float32)
        action = np.zeros(K - 1, np.int32)
        old_logp = np.zeros(K - 1, np.float32)
        tvalid = np.zeros(K - 1, np.float32)
        for t in range(k):
            amask[t] = traj.masks[t]
            action[t] = traj.actions[t]
            old_logp[t] = traj.logps[t]
            tvalid[t] = 1.0

        batch = {"feat": feat[:-1], "left": left[:-1], "right": right[:-1],
                 "mask": mask[:-1], "amask": amask, "action": action,
                 "old_logp": old_logp, "q": jnp.asarray(q), "valid": tvalid}
        sbatch = {"feat": feat, "left": left, "right": right, "mask": mask,
                  "v_target": jnp.asarray(v_pi), "valid": svalid}
        al = cl = 0.0
        for _ in range(cfg.ppo_epochs):
            (self.actor, self.critic, self.aopt, self.copt,
             al, cl) = self._update(self.actor, self.critic, self.aopt,
                                    self.copt, batch, sbatch)
        return {"actor_loss": float(al), "critic_loss": float(cl)}

    def param_count(self) -> int:
        return sum(int(np.prod(x.shape)) for x in
                   jax.tree_util.tree_leaves((self.actor, self.critic)))
