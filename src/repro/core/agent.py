"""AQORA agent: TreeCNN actor + critic, masked policy, PPO update (Alg. 1).

Actor and critic are separate encoder+head networks (~150k parameters
combined at the defaults, matching Tab. III). All state tensors are padded
to MAX_NODES, trajectories to (max_steps+1) states, so the PPO update jits
once per workload.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nets
from repro.core.actions import ActionSpace
from repro.core.encoding import MAX_NODES, WorkloadMeta
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class AgentConfig:
    net: str = "treecnn"               # treecnn | lstm | fcnn | queryformer
    hidden: int = 96
    head_hidden: int = 96
    families: Tuple[str, ...] = ("cbo", "lead", "noop")
    max_steps: int = 3                 # hook interventions per query (§VI-A)
    ppo_epochs: int = 6
    clip: float = 0.2
    entropy: float = 0.02              # η
    gamma: float = 1.0                 # Alg. 1 sets γ=1
    lr_actor: float = 3e-4
    lr_critic: float = 1e-3
    curriculum: Tuple[float, float] = (0.25, 0.55)
    failure_penalty: float = 300.0     # R(τ) -= sqrt(300) on failure
    fused_treecnn: bool = False        # VMEM-resident fused kernel on the
                                       #   batched inference AND training
                                       #   paths (custom VJP; TPU)


def _node_bucket(n_used: int) -> int:
    """Smallest multiple of 16 covering the deepest used node slot.

    A plan tree over n relations has at most 2n-1 nodes (+ the null slot),
    and encode_state numbers them contiguously from 1, so every state of a
    workload fits in one trimmed node dimension — ONE compiled shape per
    batch size instead of always paying the full MAX_NODES padding."""
    b = 16
    while b < n_used:
        b += 16
    return min(b, MAX_NODES)


class AqoraAgent:
    def __init__(self, meta: WorkloadMeta, cfg: AgentConfig = AgentConfig(),
                 seed: int = 0):
        self.meta = meta
        self.cfg = cfg
        self.space = ActionSpace(meta.n_tables_max, cfg.families)
        k = jax.random.split(jax.random.PRNGKey(seed), 5)
        F, H = meta.feat_dim, cfg.hidden
        self.actor = {
            "enc": nets.init_encoder(k[0], cfg.net, F, H, MAX_NODES),
            "head": nets.init_mlp_head(k[1], H, cfg.head_hidden, self.space.d)}
        self.critic = {
            "enc": nets.init_encoder(k[2], cfg.net, F, H, MAX_NODES),
            "head": nets.init_mlp_head(k[3], H, cfg.head_hidden, 1)}
        self.aopt = adamw_init(self.actor)
        self.copt = adamw_init(self.critic)
        self._acfg = AdamWConfig(lr=cfg.lr_actor, weight_decay=0.0, grad_clip=5.0)
        self._ccfg = AdamWConfig(lr=cfg.lr_critic, weight_decay=0.0, grad_clip=5.0)
        self.rng = jax.random.PRNGKey(seed + 1)
        # static per-workload trimmed node dim (fcnn flattens MAX_NODES)
        self._nodes = MAX_NODES if cfg.net == "fcnn" \
            else _node_bucket(2 * meta.n_tables_max)
        self._build_jits()

    # ------------------------------------------------------------- nets
    def _build_jits(self):
        net = self.cfg.net
        fused = self.cfg.fused_treecnn

        def logits_fn(actor, feat, left, right, mask):
            h = nets.apply_encoder(actor["enc"], net, feat, left, right, mask)
            return nets.apply_mlp_head(actor["head"], h)

        def value_fn(critic, feat, left, right, mask):
            h = nets.apply_encoder(critic["enc"], net, feat, left, right, mask)
            return nets.apply_mlp_head(critic["head"], h)[0]

        def logits_fn_b(actor, feat, left, right, mask):
            # batched (B, N, F) encoder; may lower to the fused Pallas
            # TreeCNN (differentiable — it carries a custom VJP)
            h = nets.apply_encoder(actor["enc"], net, feat, left, right, mask,
                                   fused=fused)
            return nets.apply_mlp_head(actor["head"], h)

        def value_fn_b(critic, feat, left, right, mask):
            h = nets.apply_encoder(critic["enc"], net, feat, left, right, mask,
                                   fused=fused)
            return nets.apply_mlp_head(critic["head"], h)[:, 0]

        self._logits = jax.jit(logits_fn)
        self._value = jax.jit(value_fn)
        self._logits_b = jax.jit(logits_fn_b)
        self._value_b = jax.jit(value_fn_b)

        def act_batch_fn(actor, feat, left, right, mask, amask, keys, explore):
            """One forward + masked categorical sample for B lanes. Each
            lane's PRNG chain advances in-kernel (split -> sample), so the
            host only carries the returned key bytes — no per-lane device
            round trips."""
            lg = logits_fn_b(actor, feat, left, right, mask)
            lg = jnp.where(amask > 0, lg, -1e9)
            logp_all = jax.nn.log_softmax(lg, axis=-1)
            pairs = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
            new_keys, subs = pairs[:, 0], pairs[:, 1]
            if explore:
                a = jax.vmap(jax.random.categorical)(subs, lg)
            else:
                a = jnp.argmax(lg, axis=-1)
            a = a.astype(jnp.int32)
            logp = jnp.take_along_axis(logp_all, a[:, None], 1)[:, 0]
            return a, logp, new_keys

        self._act_batch_jit = jax.jit(act_batch_fn,
                                      static_argnames=("explore",))

        clip, eta = self.cfg.clip, self.cfg.entropy

        def masked_logp(actor, feat, left, right, mask, amask):
            # fused agents train through the fused kernel's custom VJP;
            # the vmapped path is kept as the (numerically identical)
            # default
            if fused:
                lg = logits_fn_b(actor, feat, left, right, mask)
            else:
                lg = jax.vmap(logits_fn, (None, 0, 0, 0, 0))(
                    actor, feat, left, right, mask)
            lg = jnp.where(amask > 0, lg, -1e9)
            return jax.nn.log_softmax(lg, axis=-1)

        def actor_loss(actor, batch):
            logp_all = masked_logp(actor, batch["feat"], batch["left"],
                                   batch["right"], batch["mask"], batch["amask"])
            logp = jnp.take_along_axis(logp_all, batch["action"][:, None], 1)[:, 0]
            ratio = jnp.exp(logp - batch["old_logp"])
            q = batch["q"]
            un = ratio * q
            cl = jnp.clip(ratio, 1 - clip, 1 + clip) * q
            l_clip = -jnp.sum(jnp.minimum(un, cl) * batch["valid"]) / \
                jnp.maximum(batch["valid"].sum(), 1.0)
            p = jnp.exp(logp_all)
            ent_term = jnp.sum(jnp.where(batch["amask"] > 0, p * logp_all, 0.0), -1)
            l_ent = jnp.sum(ent_term * batch["valid"]) / \
                jnp.maximum(batch["valid"].sum(), 1.0)
            return l_clip + eta * l_ent

        def critic_loss(critic, sbatch):
            if fused:
                v = value_fn_b(critic, sbatch["feat"], sbatch["left"],
                               sbatch["right"], sbatch["mask"])
            else:
                v = jax.vmap(value_fn, (None, 0, 0, 0, 0))(
                    critic, sbatch["feat"], sbatch["left"], sbatch["right"],
                    sbatch["mask"])
            err = (v - sbatch["v_target"]) ** 2
            return jnp.sum(err * sbatch["valid"]) / jnp.maximum(sbatch["valid"].sum(), 1.0)

        def update(actor, critic, aopt, copt, batch, sbatch):
            al, agrad = jax.value_and_grad(actor_loss)(actor, batch)
            cl_, cgrad = jax.value_and_grad(critic_loss)(critic, sbatch)
            actor, aopt, _ = adamw_update(actor, agrad, aopt, self._acfg)
            critic, copt, _ = adamw_update(critic, cgrad, copt, self._ccfg)
            return actor, critic, aopt, copt, al, cl_

        epochs = self.cfg.ppo_epochs

        def update_epochs(actor, critic, aopt, copt, batch, sbatch):
            """All e PPO epochs in ONE jitted call (lax.fori_loop), so an
            episode-batch costs a single dispatch; params + optimizer
            state are donated and rewritten in place."""
            def body(_, carry):
                actor, critic, aopt, copt, _, _ = carry
                return update(actor, critic, aopt, copt, batch, sbatch)
            init = (actor, critic, aopt, copt,
                    jnp.float32(0.0), jnp.float32(0.0))
            return jax.lax.fori_loop(0, epochs, body, init)

        self._update_epochs = jax.jit(update_epochs, donate_argnums=(0, 1, 2, 3))

    # ------------------------------------------------------------- policy
    def policy_probs(self, enc_state, amask: np.ndarray) -> np.ndarray:
        feat, left, right, mask = enc_state
        lg = self._logits(self.actor, feat, left, right, mask)
        lg = jnp.where(jnp.asarray(amask) > 0, lg, -1e9)
        return np.asarray(jax.nn.softmax(lg))

    def act(self, enc_state, amask: np.ndarray, explore: bool = True) -> Tuple[int, float]:
        probs = self.policy_probs(enc_state, amask)
        if explore:
            self.rng, k = jax.random.split(self.rng)
            a = int(jax.random.choice(k, len(probs), p=jnp.asarray(probs)))
        else:
            a = int(np.argmax(probs))
        return a, float(np.log(max(probs[a], 1e-12)))

    def act_batch(self, feat, left, right, mask, amask, keys,
                  explore: bool = True):
        """Act for B lanes in one jitted forward + masked categorical sample.

        feat (B, N, F), left/right (B, N) int32, mask (B, N), amask (B, d),
        keys (B, 2) uint32 per-lane PRNG keys. Returns numpy
        (actions (B,), logps (B,), advanced keys (B, 2)) with exactly ONE
        device sync — the single device_get below.

        The node dimension is trimmed to the workload's static bucket
        before the forward: trailing padding rows never influence real
        nodes, so this is exact, and it cuts the dominant O(N) encoder
        cost without fragmenting the jit cache.
        """
        if self.cfg.net != "fcnn":       # fcnn flattens all MAX_NODES slots
            mask = np.asarray(mask)
            n = min(self._nodes, _node_bucket(int(mask.sum(axis=1).max()) + 1))
            feat, left, right, mask = (np.asarray(feat)[:, :n],
                                       np.asarray(left)[:, :n],
                                       np.asarray(right)[:, :n], mask[:, :n])
        a, logp, new_keys = self._act_batch_jit(
            self.actor, jnp.asarray(feat), jnp.asarray(left),
            jnp.asarray(right), jnp.asarray(mask), jnp.asarray(amask),
            jnp.asarray(keys), explore=explore)
        a, logp, new_keys = jax.device_get((a, logp, new_keys))
        return np.asarray(a), np.asarray(logp), np.asarray(new_keys)

    def act_keyed(self, enc_state, amask: np.ndarray, key,
                  explore: bool = True) -> Tuple[int, float, np.ndarray]:
        """Serial act with an explicit PRNG key chain — one lane of
        act_batch, so seeded serial and batched rollouts sample
        identically. Returns (action, logp, advanced key)."""
        feat, left, right, mask = enc_state
        a, logp, new_keys = self.act_batch(
            feat[None], left[None], right[None], mask[None],
            np.asarray(amask)[None], np.asarray(key, np.uint32)[None],
            explore=explore)
        return int(a[0]), float(logp[0]), new_keys[0]

    def value(self, enc_state) -> float:
        feat, left, right, mask = enc_state
        return float(self._value(self.critic, feat, left, right, mask))

    # ------------------------------------------------------------- update
    def ppo_update(self, traj) -> Dict[str, float]:
        """Single-trajectory PPO update — an episode-batch of one (Alg. 1
        semantics are preserved exactly at batch_size=1)."""
        return self.ppo_update_batch([traj])

    def ppo_update_batch(self, trajs) -> Dict[str, float]:
        """One jitted PPO update over an episode-batch of trajectories.

        Implements Alg. 1 per lane: v_pi from realized returns, q from the
        CURRENT critic (one batched forward over all B*K padded states),
        then e epochs of clipped updates against frozen old probabilities —
        amortizing the jit dispatch and (via donate_argnums) reusing the
        param/optimizer buffers across the whole batch.
        """
        cfg = self.cfg
        trajs = [t for t in trajs if len(t.actions) > 0]
        if not trajs:
            return {"actor_loss": 0.0, "critic_loss": 0.0}
        B = len(trajs)
        K = cfg.max_steps + 1
        F = self.meta.feat_dim

        feat = np.zeros((B, K, MAX_NODES, F), np.float32)
        left = np.zeros((B, K, MAX_NODES), np.int32)
        right = np.zeros((B, K, MAX_NODES), np.int32)
        mask = np.zeros((B, K, MAX_NODES), np.float32)
        svalid = np.zeros((B, K), np.float32)
        v_pi = np.zeros((B, K), np.float32)
        amask = np.zeros((B, K - 1, self.space.d), np.float32)
        action = np.zeros((B, K - 1), np.int32)
        old_logp = np.zeros((B, K - 1), np.float32)
        tvalid = np.zeros((B, K - 1), np.float32)
        ks, n_states_b, rs_b, term_b = [], [], [], []
        for bi, traj in enumerate(trajs):
            k = len(traj.actions)
            n_states = min(len(traj.states), K)
            for i, s in enumerate(traj.states[:K]):
                feat[bi, i], left[bi, i], right[bi, i], mask[bi, i] = s
            svalid[bi, :n_states] = 1.0
            # v_pi(s_i) = sum_{j>i} r_j - sqrt(T_execute)  (Alg. 1 line 2;
            # the paper's +sqrt is a sign typo — R(tau) subtracts it)
            rs = np.asarray(traj.rewards, np.float32)
            term = -np.sqrt(traj.t_execute)
            for i in range(n_states):
                v_pi[bi, i] = rs[i:].sum() + term
            for t in range(k):
                amask[bi, t] = traj.masks[t]
                action[bi, t] = traj.actions[t]
                old_logp[bi, t] = traj.logps[t]
                tvalid[bi, t] = 1.0
            ks.append(k)
            n_states_b.append(n_states)
            rs_b.append(rs)
            term_b.append(term)

        # trim the node dimension to the batch's bucketed max (exact:
        # trailing padding never influences real nodes; fcnn excepted).
        # Buckets are multiples of 16, so the jit cache sees at most
        # MAX_NODES/16 shapes per batch size.
        N = MAX_NODES
        if cfg.net != "fcnn":
            N = min(self._nodes,
                    _node_bucket(int(mask.sum(axis=2).max()) + 1))
            feat, left = feat[:, :, :N], left[:, :, :N]
            right, mask = right[:, :, :N], mask[:, :, :N]

        # q_t = r_{t+1} + v_phi(s_{t+1}) - v_phi(s_t) for every ACTION
        # (Alg. 1's trailing 0 belongs to the terminal state s_k, which has
        # no action). If the terminal state s_k was not encodable, fall back
        # to its realized value v_pi(s_k) = -sqrt(T).
        v_phi = np.asarray(self._value_b(
            self.critic, feat.reshape(B * K, N, F),
            left.reshape(B * K, N), right.reshape(B * K, N),
            mask.reshape(B * K, N))).reshape(B, K)
        q = np.zeros((B, K - 1), np.float32)
        for bi in range(B):
            for t in range(ks[bi]):
                v_next = v_phi[bi, t + 1] if t + 1 < n_states_b[bi] \
                    else term_b[bi]
                q[bi, t] = rs_b[bi][t] + v_next - v_phi[bi, t]

        T = B * (K - 1)
        batch = {"feat": feat[:, :-1].reshape(T, N, F),
                 "left": left[:, :-1].reshape(T, N),
                 "right": right[:, :-1].reshape(T, N),
                 "mask": mask[:, :-1].reshape(T, N),
                 "amask": amask.reshape(T, -1), "action": action.reshape(T),
                 "old_logp": old_logp.reshape(T),
                 "q": jnp.asarray(q.reshape(T)), "valid": tvalid.reshape(T)}
        sbatch = {"feat": feat.reshape(B * K, N, F),
                  "left": left.reshape(B * K, N),
                  "right": right.reshape(B * K, N),
                  "mask": mask.reshape(B * K, N),
                  "v_target": jnp.asarray(v_pi.reshape(B * K)),
                  "valid": svalid.reshape(B * K)}
        (self.actor, self.critic, self.aopt, self.copt,
         al, cl) = self._update_epochs(self.actor, self.critic, self.aopt,
                                       self.copt, batch, sbatch)
        return {"actor_loss": float(al), "critic_loss": float(cl)}

    def param_count(self) -> int:
        return sum(int(np.prod(x.shape)) for x in
                   jax.tree_util.tree_leaves((self.actor, self.critic)))

    def clone(self, seed: int = 0) -> "AqoraAgent":
        """A fresh agent (own jit caches, own PRNG chain) carrying a deep
        COPY of this agent's params + optimizer state. The online
        `learn.BackgroundLearner` trains a clone so its donated update
        buffers can never alias the serving agent's params."""
        from repro.checkpoint import agent_state, install_agent_state
        other = type(self)(self.meta, self.cfg, seed=seed)
        install_agent_state(other, agent_state(self), copy=True)
        return other
