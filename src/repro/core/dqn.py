"""DQN variant of the decision model (Fig. 11(a) ablation).

Same TreeCNN encoder and action space as the PPO agent, but value-based:
epsilon-greedy behaviour policy, experience replay over (s, a, r, s',
mask', done) transitions, and a periodically-synced target network. The
paper finds DQN converges slower and plateaus worse in this large,
non-stationary action space — the benchmark reproduces that comparison.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nets
from repro.core.actions import ActionSpace
from repro.core.encoding import MAX_NODES, WorkloadMeta
from repro.core.agent import AgentConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    hidden: int = 96
    head_hidden: int = 96
    gamma: float = 1.0
    eps_start: float = 0.9
    eps_end: float = 0.05
    eps_decay_episodes: int = 150
    buffer_size: int = 4096
    batch_size: int = 64
    target_sync: int = 20              # episodes
    lr: float = 5e-4


class DQNAgent:
    """Duck-types AqoraAgent's act/update interface for the rollout loop."""

    def __init__(self, meta: WorkloadMeta, cfg: AgentConfig = AgentConfig(),
                 dqn: DQNConfig = DQNConfig(), seed: int = 0):
        self.meta, self.cfg, self.dcfg = meta, cfg, dqn
        self.space = ActionSpace(meta.n_tables_max, cfg.families)
        k = jax.random.split(jax.random.PRNGKey(seed), 2)
        F, H = meta.feat_dim, dqn.hidden
        self.qnet = {"enc": nets.init_encoder(k[0], "treecnn", F, H, MAX_NODES),
                     "head": nets.init_mlp_head(k[1], H, dqn.head_hidden,
                                                self.space.d)}
        self.target = jax.tree_util.tree_map(lambda x: x, self.qnet)
        self.opt = adamw_init(self.qnet)
        self._ocfg = AdamWConfig(lr=dqn.lr, weight_decay=0.0, grad_clip=5.0)
        self.buffer: Deque = deque(maxlen=dqn.buffer_size)
        self.episode = 0
        self.rng = np.random.default_rng(seed + 1)

        def qvals(params, feat, left, right, mask):
            h = nets.apply_encoder(params["enc"], "treecnn", feat, left, right, mask)
            return nets.apply_mlp_head(params["head"], h)

        self._q = jax.jit(qvals)
        self._q_b = jax.jit(jax.vmap(qvals, in_axes=(None, 0, 0, 0, 0)))

        def loss(params, target, batch):
            q = jax.vmap(qvals, (None, 0, 0, 0, 0))(
                params, batch["feat"], batch["left"], batch["right"], batch["mask"])
            qa = jnp.take_along_axis(q, batch["action"][:, None], 1)[:, 0]
            qn = jax.vmap(qvals, (None, 0, 0, 0, 0))(
                target, batch["nfeat"], batch["nleft"], batch["nright"], batch["nmask"])
            qn = jnp.where(batch["namask"] > 0, qn, -1e9)
            tgt = batch["reward"] + dqn.gamma * jnp.max(qn, -1) * (1 - batch["done"])
            return jnp.mean((qa - jax.lax.stop_gradient(tgt)) ** 2)

        def update(params, target, opt, batch):
            l, g = jax.value_and_grad(loss)(params, target, batch)
            params, opt, _ = adamw_update(params, g, opt, self._ocfg)
            return params, opt, l

        self._update = jax.jit(update)

    # ---- rollout interface (duck-typed with AqoraAgent)
    def act(self, enc_state, amask, explore=True) -> Tuple[int, float]:
        d = self.dcfg
        eps = max(d.eps_end, d.eps_start - (d.eps_start - d.eps_end)
                  * self.episode / d.eps_decay_episodes)
        legal = np.flatnonzero(amask > 0)
        if explore and self.rng.random() < eps:
            return int(self.rng.choice(legal)), 0.0
        feat, left, right, mask = enc_state
        q = np.array(self._q(self.qnet, feat, left, right, mask))
        q[amask <= 0] = -1e9
        return int(np.argmax(q)), 0.0

    def value(self, enc_state) -> float:
        feat, left, right, mask = enc_state
        return float(np.max(self._q(self.qnet, feat, left, right, mask)))

    # ---- learning
    def record(self, traj):
        """Push (s, a, r, s', amask', done); the terminal reward folds
        -sqrt(T) into the last transition."""
        k = len(traj.actions)
        term = -float(np.sqrt(traj.t_execute))
        for t in range(k):
            s = traj.states[t]
            done = t == k - 1 or t + 1 >= len(traj.states)
            s2 = traj.states[min(t + 1, len(traj.states) - 1)]
            am2 = traj.masks[min(t + 1, len(traj.masks) - 1)]
            r = traj.rewards[t] + (term if done else 0.0)
            self.buffer.append((s, traj.actions[t], r, s2, am2, float(done)))

    def train_step(self) -> float:
        d = self.dcfg
        if len(self.buffer) < d.batch_size:
            return 0.0
        idx = self.rng.choice(len(self.buffer), size=d.batch_size, replace=False)
        rows = [self.buffer[i] for i in idx]
        F = self.meta.feat_dim

        def stack(sel):
            return (np.stack([r[sel][0] for r in rows]),
                    np.stack([r[sel][1] for r in rows]),
                    np.stack([r[sel][2] for r in rows]),
                    np.stack([r[sel][3] for r in rows]))

        f, l, rr, m = stack(0)
        nf, nl, nr, nm = stack(3)
        batch = {"feat": f, "left": l, "right": rr, "mask": m,
                 "action": np.array([r[1] for r in rows], np.int32),
                 "reward": np.array([r[2] for r in rows], np.float32),
                 "nfeat": nf, "nleft": nl, "nright": nr, "nmask": nm,
                 "namask": np.stack([r[4] for r in rows]).astype(np.float32),
                 "done": np.array([r[5] for r in rows], np.float32)}
        self.qnet, self.opt, l_ = self._update(self.qnet, self.target, self.opt, batch)
        return float(l_)

    def end_episode(self):
        self.episode += 1
        if self.episode % self.dcfg.target_sync == 0:
            self.target = jax.tree_util.tree_map(lambda x: x, self.qnet)

    # PPO-interface shim so train_loop can drive either agent
    def ppo_update(self, traj) -> Dict[str, float]:
        self.record(traj)
        losses = [self.train_step() for _ in range(4)]
        self.end_episode()
        return {"actor_loss": float(np.mean(losses)), "critic_loss": 0.0}

    def param_count(self) -> int:
        return sum(int(np.prod(x.shape)) for x in
                   jax.tree_util.tree_leaves(self.qnet))
