"""Rollouts: run one query under AQE with the agent as the planner
extension (§IV workflow steps 1-4).

The hook fires at stage boundaries (and once pre-execution — AQORA's
two-phase mechanism reuses in-execution strategies at planning time), at
most `max_steps` times. Each firing: encode partial plan + true cards ->
policy -> apply action via Alg. 2 -> shaping reward from Δshuffles.
The hook's real wall time (model inference + plan transformation + any CBO
re-planning) is charged to C_plan, mirroring the paper's ~317 ms/query
optimization overhead accounting.

`rollout` drives ONE query serially. Pass `key` (an int seed or a raw
uint32[2] PRNG key) to sample through the agent's keyed path — the same
split-then-sample chain one lane of `core.vec_rollout.rollout_batch` uses,
so seeded serial and batched rollouts take identical actions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import numpy as np

from repro.core.actions import action_mask, apply_action
from repro.core.encoding import WorkloadMeta, encode_state
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.executor import RunResult, RuntimeState, run_adaptive
from repro.sql.plans import syntactic_plan


@dataclasses.dataclass
class Trajectory:
    states: List = dataclasses.field(default_factory=list)
    actions: List[int] = dataclasses.field(default_factory=list)
    logps: List[float] = dataclasses.field(default_factory=list)
    masks: List[np.ndarray] = dataclasses.field(default_factory=list)
    rewards: List[float] = dataclasses.field(default_factory=list)
    decoded: List = dataclasses.field(default_factory=list)
    t_execute: float = 0.0
    failed: bool = False
    result: Optional[RunResult] = None
    hook_seconds: float = 0.0


def as_key(key) -> np.ndarray:
    """int seed or raw key -> uint32[2] PRNG key bytes (host-side)."""
    if isinstance(key, (int, np.integer)):
        return np.asarray(jax.random.PRNGKey(int(key)), np.uint32)
    return np.asarray(key, np.uint32)


def finalize_trajectory(traj: Trajectory, res: RunResult, query, est,
                        agent, cluster: ClusterModel, meta: WorkloadMeta,
                        extra_plan: float) -> Trajectory:
    """Shared epilogue: terminal critic state s_k, latency, C_plan."""
    final = res.final_plan
    if final is not None:
        s = RuntimeState(query, final, {}, est, agent.cfg.max_steps,
                         res.latency, len(res.stages), cluster)
        try:
            traj.states.append(encode_state(s, meta))
        except (KeyError, IndexError, ValueError):
            pass          # un-encodable terminal plan: critic falls back to
            #               the realized value -sqrt(T) in ppo_update
    # failed runs already carry their failure charge in res.latency (the
    # cluster's failure_charge: full timeout by default, detection time
    # under oom_charge="detect") — the learner's -sqrt(T) target matches
    # whatever the scheduler actually charged the lane
    traj.t_execute = res.latency
    traj.failed = res.failed
    # C_plan = hook wall time (model inference + Alg. 2) + CBO re-planning
    res.plan_time += traj.hook_seconds + extra_plan
    traj.result = res
    return traj


def rollout(db, query, est: Estimator, agent, *, stage: int = 3,
            explore: bool = True,
            cluster: Optional[ClusterModel] = None,
            key=None, reuse_stages: bool = True) -> Trajectory:
    cluster = cluster if cluster is not None else ClusterModel()
    traj = Trajectory()
    meta = agent.meta
    extra_plan = [0.0]
    keybox = [None if key is None else as_key(key)]

    def hook(state):
        t0 = time.perf_counter()
        enc = encode_state(state, meta)
        am = action_mask(agent.space, state, stage=stage)
        if keybox[0] is not None and hasattr(agent, "act_keyed"):
            a, logp, keybox[0] = agent.act_keyed(enc, am, keybox[0],
                                                 explore=explore)
        else:
            a, logp = agent.act(enc, am, explore=explore)
        new_plan, r, extra = apply_action(agent.space, state, a)
        traj.states.append(enc)
        traj.actions.append(a)
        traj.logps.append(logp)
        traj.masks.append(am)
        traj.rewards.append(r)
        traj.decoded.append(agent.space.decode(a))
        extra_plan[0] += extra
        traj.hook_seconds += time.perf_counter() - t0
        return new_plan

    plan0 = syntactic_plan(query)
    res = run_adaptive(db, query, plan0, est, cluster, hook=hook,
                       max_hook_steps=agent.cfg.max_steps,
                       plan_time=0.0, reuse_stages=reuse_stages)
    return finalize_trajectory(traj, res, query, est, agent, cluster, meta,
                               extra_plan[0])
