"""State encoding: compressed plan tree -> padded vector tree (§V-B).

encode(u) = type(u) || table(u) || card(u):
  * type: one-hot {join, base-scan leaf, stage-result leaf} (+broadcast bit)
  * table: 0/1 vector over the workload's TABLE vocabulary — "during AQE
    even leaf nodes may touch multiple tables" (stage results do);
  * card: log1p(observed rows), or -1 when not yet observed; same for
    bytes — runtime statistics only, no histograms/sample bitmaps (S1).

Trees are padded to MAX_NODES with slot 0 reserved as the null child, so a
whole state is (feat [N,F], left [N], right [N], mask [N]) — fixed shapes
for jit. The engine's plans contain ONLY joins and leaves, so the paper's
tree-compression step (dropping sorts/aggregates, Fig. 6(1)) is the
identity here; the table/card encodings are implemented exactly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sql.executor import RuntimeState
from repro.sql.plans import Join, Leaf, Node, leaves

MAX_NODES = 64


@dataclasses.dataclass(frozen=True)
class WorkloadMeta:
    """Fixed encoding context for one benchmark workload."""
    table_index: Dict[str, int]        # table name -> bit position
    n_tables_max: int                  # max relations in any query (action n)

    @property
    def feat_dim(self) -> int:
        return 4 + len(self.table_index) + 2

    @classmethod
    def from_workload(cls, workload) -> "WorkloadMeta":
        tabs = sorted({r.table for q in workload.train + workload.test
                       for r in q.relations})
        return cls({t: i for i, t in enumerate(tabs)}, workload.max_tables)


def encode_state(state: RuntimeState, meta: WorkloadMeta):
    """RuntimeState -> (feat, left, right, mask) numpy arrays."""
    F = meta.feat_dim
    feat = np.zeros((MAX_NODES, F), np.float32)
    left = np.zeros(MAX_NODES, np.int32)
    right = np.zeros(MAX_NODES, np.int32)
    mask = np.zeros(MAX_NODES, np.float32)
    nT = len(meta.table_index)
    counter = [1]                       # slot 0 = null

    def tab_bits(aliases) -> np.ndarray:
        v = np.zeros(nT, np.float32)
        for a in aliases:
            # unseen tables encode as all-zeros: "even when new tables are
            # introduced, the encoding remains valid, with the corresponding
            # positions taking a default value of 0" (§V-B2)
            i = meta.table_index.get(state.query.relation(a).table)
            if i is not None:
                v[i] = 1.0
        return v

    def visit(node: Node) -> int:
        if counter[0] >= MAX_NODES:
            return 0
        idx = counter[0]
        counter[0] += 1
        mask[idx] = 1.0
        if isinstance(node, Leaf):
            m = state.mats.get(node.covered())
            is_stage = node.stage_id is not None or len(node.aliases) > 1
            feat[idx, 1 if not is_stage else 2] = 1.0
            feat[idx, 3] = 1.0 if node.broadcast_hint else 0.0
            feat[idx, 4:4 + nT] = tab_bits(node.aliases)
            if m is not None:
                feat[idx, 4 + nT] = math.log1p(m.nrows)
                feat[idx, 5 + nT] = math.log1p(m.bytes)
            else:
                feat[idx, 4 + nT] = -1.0
                feat[idx, 5 + nT] = -1.0
            return idx
        feat[idx, 0] = 1.0              # join
        feat[idx, 4:4 + nT] = tab_bits(node.covered())
        feat[idx, 4 + nT] = -1.0        # cardinality not yet observed
        feat[idx, 5 + nT] = -1.0
        left[idx] = visit(node.left)
        right[idx] = visit(node.right)
        return idx

    visit(state.plan)
    return feat, left, right, mask
