"""AQORA: the paper's primary contribution.

A learned adaptive query optimizer that refines *running* query plans at
stage boundaries: plan-tree state encoding with true runtime cardinalities
(encoding.py), TreeCNN actor-critic (nets.py), masked + curriculum PPO
(ppo.py, agent.py), the Alg. 2 planner-extension actions (actions.py), and
the rollout/training loop against the staged engine (rollout.py,
train_loop.py). DQN and alternative encoders for the paper's ablations.
"""
