from repro.runtime.elastic import ElasticPlanner, StragglerMonitor
