"""Elastic scaling + straggler mitigation (host-side control plane).

Design for 1000+ nodes (DESIGN.md §5): the `pod` mesh axis is pure data
parallelism — parameters are never sharded across it — so membership
changes are cheap:

  * pod loss: drop its logical data-shard range, rebalance ranges over
    survivors, shrink the mesh to (p-1, data, model), resume from the last
    step-atomic checkpoint (in-flight step is discarded; determinism of the
    data pipeline means no sample is lost or duplicated).
  * pod join: extend the mesh, hand the newcomer a range, restore params
    from any survivor's checkpoint (params are replicated across pods).

Straggler mitigation: per-step host heartbeats feed an EWMA of step time;
hosts slower than `threshold x median` for `patience` consecutive steps
are marked for eviction (the same rebalance path as pod loss) — on real
fleets this is the "kill the sick node, don't wait for it" policy.
This module is deliberately device-free (pure control logic) so it is unit
testable here and drivable by any launcher.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    pod: int
    lo: int
    hi: int


class ElasticPlanner:
    def __init__(self, n_logical_shards: int = 256):
        self.n_logical = n_logical_shards

    def assign(self, pods: Sequence[int]) -> List[ShardAssignment]:
        """Contiguous balanced ranges over live pods (deterministic)."""
        pods = sorted(pods)
        n = len(pods)
        per = self.n_logical // n
        rem = self.n_logical % n
        out, lo = [], 0
        for i, p in enumerate(pods):
            hi = lo + per + (1 if i < rem else 0)
            out.append(ShardAssignment(p, lo, hi))
            lo = hi
        assert lo == self.n_logical
        return out

    def on_membership_change(self, old: Sequence[int], new: Sequence[int]
                             ) -> Dict[str, object]:
        """Plan the transition: which ranges move, what mesh to rebuild."""
        new_assign = self.assign(new)
        return {
            "mesh_pods": len(new),
            "assignments": new_assign,
            "action": "restore_from_checkpoint_and_resume",
            "lost": sorted(set(old) - set(new)),
            "joined": sorted(set(new) - set(old)),
        }


@dataclasses.dataclass
class _HostStat:
    ewma: float = 0.0
    slow_streak: int = 0


class StragglerMonitor:
    def __init__(self, threshold: float = 1.5, patience: int = 5,
                 alpha: float = 0.3):
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.stats: Dict[int, _HostStat] = {}

    def report(self, host: int, step_seconds: float):
        s = self.stats.setdefault(host, _HostStat(step_seconds))
        s.ewma = (1 - self.alpha) * s.ewma + self.alpha * step_seconds

    def evictions(self) -> List[int]:
        if len(self.stats) < 2:
            return []
        med = sorted(s.ewma for s in self.stats.values())[len(self.stats) // 2]
        out = []
        for h, s in self.stats.items():
            if s.ewma > self.threshold * med:
                s.slow_streak += 1
            else:
                s.slow_streak = 0
            if s.slow_streak >= self.patience:
                out.append(h)
        return sorted(out)
