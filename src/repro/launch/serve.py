"""Batched serving driver: continuous-batching decode loop with prefill
admission, KV/SSM caches from lm.init_cache, and per-request streams.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --smoke --requests 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm


class BatchedServer:
    """Static-batch decode server (the dry-run's serve_step semantics):
    admits up to `max_batch` requests, prefills them together, then decodes
    lockstep with per-request stop handling."""

    def __init__(self, cfg, *, max_batch: int = 8, max_len: int = 512,
                 seed: int = 0):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = lm.init_params(jax.random.PRNGKey(seed), cfg)
        self._decode = jax.jit(
            lambda p, tok, cache, pos: lm.decode_step(p, tok, cache, cfg, pos))

    def generate(self, prompts: np.ndarray, gen_tokens: int,
                 greedy: bool = True, seed: int = 0):
        """prompts: (B, P) int32. Returns (B, gen_tokens) int32."""
        cfg = self.cfg
        B, P = prompts.shape
        memory = None
        if cfg.family == "vlm":
            memory = jnp.zeros((B, cfg.vision_tokens, cfg.d_model), cfg.cdtype)
        if cfg.encoder is not None:
            frames = jnp.zeros((B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
            memory = lm.encode(self.params, frames, cfg)
        t0 = time.time()
        logits, cache = lm.prefill(self.params, jnp.asarray(prompts), cfg,
                                   max_len=P + gen_tokens, memory=memory)
        prefill_s = time.time() - t0
        out = np.zeros((B, gen_tokens), np.int32)
        key = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        t0 = time.time()
        for t in range(gen_tokens):
            out[:, t] = np.asarray(tok[:, 0])
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(P + t))
            if greedy:
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            else:
                key, k = jax.random.split(key)
                tok = jax.random.categorical(k, logits)[:, None].astype(jnp.int32)
        decode_s = time.time() - t0
        return out, {"prefill_s": prefill_s, "decode_s": decode_s,
                     "tok_per_s": B * gen_tokens / max(decode_s, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    cfg = registry.get_config(args.arch)
    if args.smoke:
        cfg = registry.reduced(cfg)
    server = BatchedServer(cfg, max_batch=args.requests)
    rng = np.random.default_rng(0)
    prompts = rng.integers(2, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    out, stats = server.generate(prompts, args.gen)
    print(f"prefill {stats['prefill_s']:.2f}s decode {stats['decode_s']:.2f}s "
          f"({stats['tok_per_s']:.0f} tok/s) sample: {out[0, :10].tolist()}")


if __name__ == "__main__":
    main()
