"""HLO-text cost analyzer with while-loop trip-count accounting.

``compiled.cost_analysis()`` visits each computation ONCE, so a
``lax.scan`` over 20 superblocks reports 1/20th of the real FLOPs
(verified in tests/test_roofline.py). This analyzer re-derives

    flops            — dot ops exact (2 * out_elems * contracted_elems),
                       elementwise/reduce ops at 1 flop/output element
    memory bytes     — operands + outputs at fusion boundaries
                       (same convention as XLA's bytes_accessed)
    collective bytes — per-device ICI traffic with ring multipliers
                       (see launch/roofline.py)

from the optimized per-device HLO text, multiplying every computation by
its call multiplicity: fusions x1, while bodies x known_trip_count
(present as backend_config on scheduled while ops).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize `compiled.cost_analysis()` across jax versions: older
    releases returned a one-element list of per-program dicts, newer ones
    return the dict directly (and may return None for trivial programs).
    Every caller goes through this seam instead of calling `.get` on
    whatever shape the installed jax produces."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "%name = <shape-or-tuple> opcode(" ; shape may be a flat tuple
# "(f32[..], /*index=5*/ bf16[..], ...)" — comments contain '=' but no parens.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:\w+\[[\d,]*\]\S*))\s*"
    r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}|known_trip_count=\{n=(\d+)\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DIMS_NUM_RE = re.compile(r"\d+")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "tanh", "log", "log-plus-one",
    "rsqrt", "sqrt", "negate", "abs", "floor", "ceil", "cosine", "sine",
    "logistic", "select", "compare", "and", "or", "xor", "not", "remainder",
    "clamp", "sign", "atan2", "cbrt", "round-nearest-afz",
    "round-nearest-even", "erf",
}
_REDUCE_LIKE = {"reduce", "reduce-window"}
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "iota", "partition-id",
    "replica-id", "bitcast-convert",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all",
                "all-gather-start", "all-reduce-start",
                "collective-permute-start", "all-to-all-start"}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    line: str
    is_root: bool = False


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    symbols: Dict[str, str]           # %name -> shape str
    param_order: List[str] = dataclasses.field(default_factory=list)

    def root(self) -> Optional[_Op]:
        for op in self.ops:
            if op.is_root:
                return op
        return self.ops[-1] if self.ops else None

    def effective_param_bytes(self, idx: int) -> Optional[int]:
        """Bytes actually read from parameter #idx, or None for 'all of it'.
        A parameter consumed only through dynamic-slice/gather reads just the
        sliced region — crucial for scan-stacked weights and decode caches."""
        if idx >= len(self.param_order):
            return None
        pname = self.param_order[idx]
        pat = re.compile(r"%" + re.escape(pname) + r"\b")
        total = 0
        for op in self.ops:
            if not pat.search(op.line.split(" = ", 1)[-1]):
                continue
            if op.opcode in ("dynamic-slice", "gather"):
                total += _shape_elems_bytes(op.shape)[1]
            elif op.opcode == "dynamic-update-slice":
                # reads only the region it overwrites
                total += _second_operand_bytes(op, self.symbols)
            elif op.opcode in ("bitcast", "get-tuple-element"):
                return None           # aliases the param: be conservative
            else:
                return None
        return total


def _parse(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m and (line.endswith("{") or "->" in line):
            cur = _Computation(m.group(1), [], {})
            comps[cur.name] = cur
            for pname, pshape in _PARAM_RE.findall(m.group(2)):
                cur.symbols[pname] = pshape
                cur.param_order.append(pname)
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, shape, opcode = mi.groups()
            cur.symbols[name] = shape
            cur.ops.append(_Op(name, shape, opcode, line,
                               is_root=line.lstrip().startswith("ROOT")))
    return comps


def _dot_flops(op: _Op, sym: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(op.shape)
    # contracted size from lhs operand shape + lhs_contracting_dims
    paren = op.line.split("(", 1)[1]
    operands = _OPERAND_RE.findall(paren.split(")", 1)[0])
    c = 1
    m = _CDIMS_RE.search(op.line)
    if m and operands:
        lhs_shape = sym.get(operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            for idx in _DIMS_NUM_RE.findall(m.group(1)):
                i = int(idx)
                if i < len(dims):
                    c *= dims[i]
    return 2.0 * out_elems * c


def _operand_bytes(op: _Op, sym: Dict[str, str]) -> int:
    paren = op.line.split("(", 1)[1]
    # operands before any named attribute section
    arglist = paren.split("), ")[0]
    total = 0
    for name in _OPERAND_RE.findall(arglist):
        if name in sym:
            total += _shape_elems_bytes(sym[name])[1]
    return total


def _second_operand_bytes(op: _Op, sym: Dict[str, str]) -> int:
    paren = op.line.split("(", 1)[1]
    arglist = paren.split("), ")[0]
    names = _OPERAND_RE.findall(arglist)
    if len(names) > 1 and names[1] in sym:
        return _shape_elems_bytes(sym[names[1]])[1]
    return 0


def _fusion_bytes(op: _Op, sym: Dict[str, str], called) -> float:
    """Boundary bytes of a fusion: output + effective per-operand reads."""
    paren = op.line.split("(", 1)[1]
    arglist = paren.split("), ")[0]
    names = _OPERAND_RE.findall(arglist)
    _, out_b = _shape_elems_bytes(op.shape)
    # in-place DUS fusions: output aliases the buffer; traffic ~ update only
    if called is not None:
        r = called.root()
        if r is not None and r.opcode == "dynamic-update-slice":
            out_b = _second_operand_bytes(r, called.symbols) * 2
    total = float(out_b)
    for i, nm in enumerate(names):
        full = _shape_elems_bytes(sym.get(nm, ""))[1]
        eff = called.effective_param_bytes(i) if called is not None else None
        total += full if eff is None else min(eff, full)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS2_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def _collective_traffic(op: _Op, sym: Dict[str, str]) -> Tuple[str, float]:
    kind = op.opcode.replace("-start", "")
    g = _group_size(op.line)
    if op.opcode.endswith("-start"):
        # start ops return (in, out [, scratch]) tuples; take the LAST array
        shapes = _SHAPE_RE.findall(op.shape)
        arrays = [f"{dt}[{dims}]" for dt, dims in shapes if dt in _DTYPE_BYTES]
        b = _shape_elems_bytes(arrays[-1])[1] if arrays else 0
    else:
        b = _shape_elems_bytes(op.shape)[1]
    if kind == "all-gather":
        return kind, b * (g - 1) / g
    if kind == "all-reduce":
        return kind, 2 * b * (g - 1) / g
    if kind == "reduce-scatter":
        return kind, b * (g - 1)
    if kind == "all-to-all":
        return kind, b * (g - 1) / g
    return "collective-permute", float(b)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def coll_total(self) -> float:
        return sum(self.collectives.values())


def analyze(text: str, entry: Optional[str] = None) -> HloCost:
    comps = _parse(text)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        entry = m.group(1) if m else next(iter(comps))

    cache: Dict[str, HloCost] = {}

    def comp_cost(name: str) -> HloCost:
        if name in cache:
            return cache[name]
        cost = HloCost(collectives={})
        cache[name] = cost                      # cycle guard
        comp = comps.get(name)
        if comp is None:
            return cost
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                trip = 1
                mt = _TRIP_RE.search(op.line)
                if mt:
                    trip = int(mt.group(1) or mt.group(2))
                mb, mc = _BODY_RE.search(op.line), _COND_RE.search(op.line)
                for sub, mult in ((mb, trip), (mc, trip)):
                    if sub:
                        c = comp_cost(sub.group(1))
                        cost.flops += c.flops * mult
                        cost.bytes += c.bytes * mult
                        for k, v in c.collectives.items():
                            cost.collectives[k] = cost.collectives.get(k, 0) + v * mult
                continue
            if oc in ("fusion", "call", "conditional"):
                called = None
                for mcall in _CALLS_RE.finditer(op.line):
                    called = comps.get(mcall.group(1))
                    c = comp_cost(mcall.group(1))
                    cost.flops += c.flops
                    # bytes inside fusions are NOT HBM traffic; boundary only
                    for k, v in c.collectives.items():
                        cost.collectives[k] = cost.collectives.get(k, 0) + v
                if oc == "fusion":
                    cost.bytes += _fusion_bytes(op, comp.symbols, called)
                continue
            if oc in _COLLECTIVES:
                kind, traffic = _collective_traffic(op, comp.symbols)
                cost.collectives[kind] = cost.collectives.get(kind, 0) + traffic
                _, ob = _shape_elems_bytes(op.shape)
                cost.bytes += ob + _operand_bytes(op, comp.symbols)
                continue
            if oc in _NO_BYTES:
                continue
            elems, ob = _shape_elems_bytes(op.shape)
            if oc == "dot":
                cost.flops += _dot_flops(op, comp.symbols)
            elif oc in _ELEMENTWISE:
                cost.flops += elems
            elif oc in _REDUCE_LIKE:
                cost.flops += _operand_bytes(op, comp.symbols) / 4.0
            if oc in ("dynamic-slice", "gather"):
                # reads only the sliced region, not the whole operand
                cost.bytes += 2 * ob
            elif oc == "dynamic-update-slice":
                # read-modify-write of the update region only
                upd = _second_operand_bytes(op, comp.symbols)
                cost.bytes += 3 * upd
            elif oc == "scatter":
                cost.bytes += 3 * _second_operand_bytes(op, comp.symbols) + ob
            else:
                cost.bytes += ob + _operand_bytes(op, comp.symbols)
        # inline-fused computations called only via calls= already handled;
        return cost

    return comp_cost(entry)
