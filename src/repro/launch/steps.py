"""Step functions + ShapeDtypeStruct input specs for every (arch x shape).

These are the exact functions the dry-run lowers and the real launchers run:
  * train_step  — fwd + bwd + AdamW          (train_4k)
  * prefill     — prompt -> logits + cache   (prefill_32k)
  * serve_step  — one decode token against a seq_len KV cache
                                              (decode_32k / long_500k)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


# ------------------------------------------------------------------ steps
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    total_steps: int = 10_000, grad_compress: bool = False):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, batch, cfg)
        if grad_compress:
            from repro.optim.compress import compress_grads
            grads, _ = compress_grads(grads)
        lr_scale = cosine_schedule(opt_state["step"],
                                   warmup=total_steps // 50, total=total_steps)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, lr_scale)
        return params, opt_state, {"loss": loss, **metrics, **om}
    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        memory = batch.get("memory")
        if cfg.encoder is not None:
            memory = lm.encode(params, batch["frames"], cfg)
        return lm.prefill(params, batch["tokens"], cfg, max_len, memory=memory)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        return lm.decode_step(params, batch["token"], cache, cfg, batch["pos"])
    return serve_step


# ------------------------------------------------------------------ specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the data inputs of one cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.family == "vlm":
            batch["memory"] = _sds((B, cfg.vision_tokens, cfg.d_model), cfg.cdtype)
        if cfg.encoder is not None:
            batch["frames"] = _sds((B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
        return batch
    # decode: one new token against a seq_len cache
    return {"token": _sds((B, 1), jnp.int32), "pos": _sds((), jnp.int32)}


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.key(0))


def opt_struct(cfg: ModelConfig):
    p = params_struct(cfg)
    return jax.eval_shape(
        functools.partial(adamw_init, moment_dtype=jnp.dtype(cfg.opt_moment_dtype)), p)


def cache_struct(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple:
    """Full positional ShapeDtypeStruct tuple for the cell's step function.

    train:   (params, opt_state, batch)
    prefill: (params, batch)
    decode:  (params, cache, batch)
    """
    if shape.kind == "train":
        return (params_struct(cfg), opt_struct(cfg), batch_struct(cfg, shape))
    if shape.kind == "prefill":
        return (params_struct(cfg), batch_struct(cfg, shape))
    return (params_struct(cfg), cache_struct(cfg, shape), batch_struct(cfg, shape))


def step_fn(cfg: ModelConfig, shape: ShapeConfig):
    if shape.kind == "train":
        return make_train_step(cfg)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, max_len=shape.seq_len)
    return make_serve_step(cfg)
