"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def _axis_types_kw(n: int) -> dict:
    """`jax.sharding.AxisType` was removed from newer jax releases; when
    absent, `jax.make_mesh` defaults every axis to Auto anyway, so the
    explicit kwarg is only passed where the enum still exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: data (FSDP/batch), model (TP/expert). The multi-pod mesh adds a
    leading pure-DP "pod" axis — parameters are never sharded across it, so
    pods can join/leave elastically (see runtime/elastic.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_host_mesh():
    """Whatever devices exist (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"), **_axis_types_kw(2))
