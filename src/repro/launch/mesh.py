"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: data (FSDP/batch), model (TP/expert). The multi-pod mesh adds a
    leading pure-DP "pod" axis — parameters are never sharded across it, so
    pods can join/leave elastically (see runtime/elastic.py)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
