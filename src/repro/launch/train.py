"""End-to-end LM training driver.

Composes the whole substrate: config registry -> sharded params + AdamW ->
deterministic data pipeline (prefetching) -> jitted train_step under the
active mesh -> step-atomic async checkpoints -> straggler telemetry. On
the CPU container it runs smoke-scale models end-to-end (examples/
train_lm.py trains a ~25M-param model for a few hundred steps); on a real
pod the same driver takes the production mesh (launch/mesh.py) and the
full configs — nothing here is CPU-specific.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import registry
from repro.data import SyntheticLMPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init, cosine_schedule
from repro.optim.compress import compress_grads
from repro.optim.adamw import adamw_update
from repro.runtime import StragglerMonitor
from repro.sharding import MeshAxes, batch_specs, param_specs


def make_train_step(cfg, opt_cfg, total_steps, grad_compress=False):
    def train_step(params, opt_state, err_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(params, batch, cfg)
        if grad_compress:
            grads, err_state = compress_grads(grads, err_state)
        lr_scale = cosine_schedule(opt_state["step"],
                                   warmup=max(total_steps // 50, 1),
                                   total=total_steps)
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, lr_scale)
        return params, opt_state, err_state, {"loss": loss, **metrics, **om}
    return train_step


def train(arch: str, *, smoke: bool = True, steps: int = 100,
          global_batch: int = 8, seq_len: int = 256,
          ckpt_dir=None, ckpt_every: int = 50, restore: bool = False,
          grad_compress: bool = False, lr: float = 3e-4,
          log_every: int = 10, seed: int = 0, mesh=None):
    cfg = registry.get_config(arch)
    if smoke:
        cfg = registry.reduced(cfg)
    mesh = mesh or make_host_mesh()
    axes = MeshAxes.from_mesh(mesh)
    opt_cfg = AdamWConfig(lr=lr)

    key = jax.random.PRNGKey(seed)
    params = lm.init_params(key, cfg)
    opt_state = adamw_init(params, jnp.dtype(cfg.opt_moment_dtype))
    err_state = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params) if grad_compress else 0

    pipe = SyntheticLMPipeline(vocab_size=cfg.vocab_size, seq_len=seq_len,
                               global_batch=global_batch, seed=seed,
                               n_logical_shards=global_batch)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if ckpt and restore:
        try:
            (params, opt_state), start_step, extra = ckpt.restore(
                (params, opt_state))
            pipe.state.step = int(extra.get("data_step", start_step))
            print(f"restored checkpoint at step {start_step}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")
    pipe.state.step = max(pipe.state.step, start_step)
    pipe.start_prefetch()

    step_fn = make_train_step(cfg, opt_cfg, steps, grad_compress)
    with mesh:
        pspec = param_specs(params, axes)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1, 2))
        monitor = StragglerMonitor()
        losses = []
        t_start = time.time()
        for step in range(start_step, steps):
            batch_np = next(pipe)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if cfg.family == "vlm":
                batch["memory"] = jnp.zeros(
                    (global_batch, cfg.vision_tokens, cfg.d_model), cfg.cdtype)
            if cfg.encoder is not None:
                batch["frames"] = jnp.zeros(
                    (global_batch, cfg.encoder.n_frames, cfg.d_model),
                    jnp.float32)
            t0 = time.time()
            params, opt_state, err_state, metrics = jitted(
                params, opt_state, err_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            monitor.report(0, time.time() - t0)
            if log_every and (step + 1) % log_every == 0:
                tok_s = global_batch * seq_len * log_every / max(
                    time.time() - t_start, 1e-9)
                t_start = time.time()
                print(f"step {step+1:5d} loss {loss:7.4f} "
                      f"gnorm {float(metrics['grad_norm']):6.2f} "
                      f"tok/s {tok_s:9.0f}")
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          extra={"data_step": pipe.state.step},
                          blocking=False)
        if ckpt:
            ckpt.save(steps, (params, opt_state),
                      extra={"data_step": pipe.state.step}, blocking=True)
    pipe.stop_prefetch()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    _, losses = train(args.arch, smoke=args.smoke, steps=args.steps,
                      global_batch=args.batch, seq_len=args.seq,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      restore=args.restore, grad_compress=args.grad_compress,
                      lr=args.lr)
    print(f"final loss: {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
