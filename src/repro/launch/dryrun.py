import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax-importing import: jax locks the device count on
# first backend init. 512 placeholder host devices let jax.make_mesh build
# the production meshes. Set here ONLY — tests/benches must see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding rules produce a consistent SPMD program (compile succeeds,
    no sharding mismatch / unsupported collective),
  * it fits per-device HBM (memory_analysis),
  * and it yields the roofline terms (cost_analysis + HLO collective parse).

Results are written incrementally to results/dryrun/<mesh>/<arch>__<shape>.json
so a long sweep can be resumed / monitored.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch import hloanalysis
from repro.launch import roofline as rl
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.sharding import MeshAxes, batch_specs, cache_specs, param_specs
from repro.sharding import act as act_sharding

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _useful_params(cfg) -> int:
    """Active params for the 6ND/2ND model; untied embed tables do no matmul."""
    n = cfg.active_param_count()
    if not cfg.tie_embeddings and cfg.family != "audio":
        n -= cfg.vocab_size * cfg.d_model
    return n


def shardings_for(cfg, shape, mesh, layout=None):
    kv_seq = bool(layout and layout.kv_seq_shard)
    axes = MeshAxes.from_mesh(mesh)
    pspec = param_specs(steps_mod.params_struct(cfg), axes)
    bspec = batch_specs(steps_mod.batch_struct(cfg, shape), axes)
    if shape.kind == "train":
        ospec = param_specs(steps_mod.opt_struct(cfg), axes)
        in_specs = (pspec, ospec, bspec)
        out_specs = (pspec, ospec,
                     jax.tree_util.tree_map(lambda _: P(), {
                         "loss": 0, "ce": 0, "aux": 0, "grad_norm": 0, "lr": 0}))
        donate = (0, 1)
    elif shape.kind == "prefill":
        cspec = cache_specs(steps_mod.cache_struct(cfg, shape), axes, kv_seq=kv_seq)
        dp = axes.dp_axes if axes.pod else axes.data
        logit_spec = P(dp, axes.model if cfg.vocab_size % axes.size(axes.model) == 0 else None) \
            if shape.global_batch % axes.dp_size == 0 else P(None, None)
        in_specs = (pspec, bspec)
        out_specs = (logit_spec, cspec)
        donate = ()
    else:
        cspec = cache_specs(steps_mod.cache_struct(cfg, shape), axes, kv_seq=kv_seq)
        dp = axes.dp_axes if axes.pod else axes.data
        logit_spec = P(dp, axes.model if cfg.vocab_size % axes.size(axes.model) == 0 else None) \
            if shape.global_batch % axes.dp_size == 0 else P(None, None)
        in_specs = (pspec, cspec, bspec)
        out_specs = (logit_spec, cspec)
        donate = (1,)
    return _named(mesh, in_specs), _named(mesh, out_specs), donate


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose=True,
             layout=None) -> dict:
    """layout: optional repro.adapt.knobs.LayoutPlan overriding the default
    activation layout (the §Perf hillclimb re-lowers cells through here)."""
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    if shape.kind == "train" and layout is not None and layout.grad_compress:
        fn = steps_mod.make_train_step(cfg, grad_compress=True)
    else:
        fn = steps_mod.step_fn(cfg, shape)
    in_sds = steps_mod.input_specs(cfg, shape)
    in_sh, out_sh, donate = shardings_for(cfg, shape, mesh, layout)

    axes = MeshAxes.from_mesh(mesh)
    pol = act_sharding.ActivationPolicy(
        dp_axes=axes.dp_axes, tp_axis=axes.model,
        dp_size=axes.dp_size, tp_size=axes.size(axes.model),
        attn_mode=layout.attn_mode if layout else "seq",
        ce_chunk=layout.ce_chunk if layout else None,
        remat=layout.remat if layout else "full",
        attn_remat=layout.attn_remat if layout else False,
        mla_absorb=layout.mla_absorb if layout else False,
        attn_scores_bf16=layout.attn_scores_bf16 if layout else False,
        moe_dispatch=layout.moe_dispatch if layout else "global",
        mesh=mesh)
    t0 = time.time()
    with mesh, act_sharding.policy(pol):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*in_sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = hloanalysis.cost_analysis_dict(compiled)
    hlo = hloanalysis.analyze(compiled.as_text())
    coll = dict(hlo.collectives)
    coll["total"] = hlo.coll_total

    # cost_analysis visits scan bodies once; the HLO analyzer multiplies by
    # trip count (tests/test_roofline.py) — use the analyzer for the roofline.
    flops = hlo.flops
    byt = hlo.bytes
    n_use = _useful_params(cfg)
    roof = rl.Roofline(
        flops_per_device=flops, bytes_per_device=byt,
        coll_bytes_per_device=hlo.coll_total, chips=chips,
        model_flops=rl.model_flops(cfg, shape, n_use))

    mem_d = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_d[k] = int(getattr(mem, k, 0))
    # live bytes per device ~ args + temps (outputs alias donated args)
    mem_d["live_bytes_per_device"] = (
        mem_d["argument_size_in_bytes"] + mem_d["temp_size_in_bytes"]
        + mem_d["output_size_in_bytes"] - mem_d["alias_size_in_bytes"])

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": mem_d,
        "cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                          "bytes": float(cost.get("bytes accessed", 0.0)),
                          "note": "scan bodies counted once; see hlo_analysis"},
        "hlo_analysis": {"flops": flops, "bytes": byt},
        "collectives": coll, "roofline": roof.to_dict(),
        "ok": True,
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {rec['mesh']} ==")
        print(f"memory_analysis: {mem}")
        print(f"cost_analysis: flops={flops:.3e} bytes={byt:.3e}")
        print(f"collectives: { {k: f'{v:.3e}' for k, v in coll.items()} }")
        print(f"roofline: compute={roof.t_compute:.4f}s memory={roof.t_memory:.4f}s "
              f"collective={roof.t_collective:.4f}s -> {roof.bottleneck}-bound, "
              f"useful={roof.useful_flops_ratio:.3f} mfu_bound={roof.mfu_bound:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute existing cells")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, shape, ok, why in registry.assigned_cells():
            cells.append((arch, shape, ok, why))
    else:
        cells.append((args.arch, args.shape, True, ""))

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for multi in meshes:
        mdir = RESULTS / ("multi" if multi else "single")
        mdir.mkdir(parents=True, exist_ok=True)
        for arch, shape, ok, why in cells:
            out = mdir / f"{arch}__{shape}.json"
            if out.exists() and not args.force:
                prev = json.loads(out.read_text())
                if prev.get("ok"):
                    print(f"skip (cached): {out.name}")
                    continue
            if not ok:
                out.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "ok": True,
                     "skipped": True, "reason": why}, indent=1))
                print(f"skip (n/a): {arch} x {shape}: {why}")
                continue
            try:
                rec = run_cell(arch, shape, multi)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "ok": False,
                       "mesh": "2x16x16" if multi else "16x16",
                       "error": f"{type(e).__name__}: {e}"}
                n_fail += 1
            out.write_text(json.dumps(rec, indent=1))
    print(f"done; failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
