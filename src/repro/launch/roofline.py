"""Roofline model for the dry-run: three terms from the compiled artifact.

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bw_per_chip
  collective = ICI_traffic_per_device / ICI_link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the post-SPMD module is
the per-device program, verified by ``tests/test_roofline.py::test_cost_
analysis_is_per_device``). Collective traffic is NOT in cost_analysis, so we
parse the optimized HLO text and sum per-op traffic with ring-algorithm
multipliers derived from each op's replica_groups size g:

  all-gather          out * (g-1)/g
  all-reduce          2 * out * (g-1)/g        (reduce-scatter + all-gather)
  reduce-scatter      out * (g-1)              (operand bytes ~ out*g)
  all-to-all          out * (g-1)/g
  collective-permute  out

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\w+\[[\d,]*\][^\s]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of 'f32[4,8]' or a tuple '(f32[4], bf16[2,2])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS2_RE.search(line)          # iota v2 format [n_groups,group_size]
    if m:
        return int(m.group(2))
    return 2


def collective_traffic_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device ICI traffic (bytes), per collective kind + total."""
    out: Dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, op, _ = m.groups()
        b = _shape_bytes(shape_str)
        g = _group_size(line)
        if op == "all-gather":
            # async start ops return (input, output) tuples: use the larger
            t = b * (g - 1) / g
        elif op == "all-reduce":
            t = 2 * b * (g - 1) / g
        elif op == "reduce-scatter":
            t = b * (g - 1)
        elif op == "all-to-all":
            t = b * (g - 1) / g
        else:
            t = b
        out[op] += t
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    chips: int
    model_flops: float = 0.0          # 6*N*D (train) / 2*N*D (serve), global

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): compiled-compute usefulness."""
        tot = self.flops_per_device * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        if self.t_bound == 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * self.t_bound)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_bound_s": self.t_bound,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops(cfg, shape, n_params_active: int) -> float:
    """6ND for train, 2ND for inference-forward. For decode, D = one token
    per sequence (the step processes global_batch tokens)."""
    if shape.kind == "train":
        toks = shape.seq_len * shape.global_batch
        return 6.0 * n_params_active * toks
    if shape.kind == "prefill":
        toks = shape.seq_len * shape.global_batch
        return 2.0 * n_params_active * toks
    return 2.0 * n_params_active * shape.global_batch
