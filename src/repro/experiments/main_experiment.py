"""Main experiment runner: AQORA + 3 baselines on 3 benchmarks (§VII).

Writes results/aqora/<bench>.json incrementally (resumable); benchmarks/*
read these files to print the paper's tables/figures. Run:

  PYTHONPATH=src python -m repro.experiments.main_experiment --bench job
  PYTHONPATH=src python -m repro.experiments.main_experiment --all
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.baselines import AutoSteerOptimizer, LeroOptimizer, run_spark_default
from repro.core.agent import AgentConfig
from repro.core.train_loop import evaluate, train_agent
from repro.sql import datagen, workloads
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "aqora"

SCALE = 0.4
EPISODES = {"job": 700, "extjob": 400, "stack": 450}
BASELINE_EPISODES = 60
N_TRAIN = {"job": 160, "extjob": 120, "stack": 120}
N_TEST_PER_TEMPLATE = {"job": 3, "extjob": 2, "stack": 4}


def make_db(bench: str, seed: int = 0, year_max=None):
    if bench in ("job", "extjob"):
        return datagen.make_job_like(scale=SCALE, seed=seed, year_max=year_max)
    return datagen.make_stack_like(scale=SCALE, seed=seed)


def run_bench(bench: str, seed: int = 0, episodes=None, out_name=None,
              train_db=None, test_db=None, quiet=False,
              batch_size: int = 1) -> dict:
    t_start = time.time()
    db = train_db if train_db is not None else make_db(bench, seed)
    tdb = test_db if test_db is not None else db
    wl = workloads.make_workload(bench, n_train=N_TRAIN[bench],
                                 n_test_per_template=N_TEST_PER_TEMPLATE[bench],
                                 seed=7 + seed)
    est = Estimator(db, db.stats)
    test_est = Estimator(tdb, db.stats)   # stats from TRAIN-era snapshot
    cluster = ClusterModel()
    episodes = episodes or EPISODES[bench]
    rng = np.random.default_rng(seed)

    out = {"bench": bench, "scale": SCALE, "episodes": episodes}

    # ---------------- Spark default
    sp = []
    for q in wl.test:
        r = run_spark_default(tdb, q, test_est, cluster)
        sp.append({"query": q.name, "latency": r.latency, "plan_time": 0.0,
                   "total": r.latency, "failed": r.failed,
                   "shuffles": r.total_shuffles, "bushy": r.bushy})
    out["spark"] = sp
    if not quiet:
        print(f"[{bench}] spark done ({time.time()-t_start:.0f}s)")

    # ---------------- Lero
    lero = LeroOptimizer(db, est, seed=seed, cluster=cluster)
    for i in range(BASELINE_EPISODES):
        lero.train_episode(wl.train[int(rng.integers(len(wl.train)))])
    lr = []
    lero.est = test_est
    lero.db = tdb
    for q in wl.test:
        r = lero.run(q)
        lr.append({"query": q.name, "latency": r.latency,
                   "plan_time": r.plan_time, "total": r.total,
                   "failed": r.failed, "shuffles": r.total_shuffles,
                   "bushy": r.bushy})
    out["lero"] = lr
    if not quiet:
        print(f"[{bench}] lero done ({time.time()-t_start:.0f}s)")

    # ---------------- AutoSteer
    ast = AutoSteerOptimizer(db, est, seed=seed, cluster=cluster)
    for i in range(BASELINE_EPISODES):
        ast.train_episode(wl.train[int(rng.integers(len(wl.train)))], rng)
    ar = []
    ast.est = test_est
    ast.db = tdb
    for q in wl.test:
        r = ast.run(q)
        ar.append({"query": q.name, "latency": r.latency,
                   "plan_time": r.plan_time, "total": r.total,
                   "failed": r.failed, "shuffles": r.total_shuffles,
                   "bushy": r.bushy})
    out["autosteer"] = ar
    if not quiet:
        print(f"[{bench}] autosteer done ({time.time()-t_start:.0f}s)")

    # ---------------- AQORA
    agent, logs = train_agent(db, wl, episodes=episodes, seed=seed,
                              cfg=AgentConfig(), cluster=cluster, est=est,
                              batch_size=batch_size,
                              log_every=0 if quiet else 60)
    aq = evaluate(tdb, wl.test, agent, est=test_est, cluster=cluster)
    out["aqora"] = aq
    out["aqora_training"] = [
        {"episode": l.episode, "latency": l.latency, "failed": l.failed,
         "stage": l.stage} for l in logs]
    out["agent_params"] = agent.param_count()
    out["wall_seconds"] = time.time() - t_start
    if not quiet:
        print(f"[{bench}] aqora done ({time.time()-t_start:.0f}s)")

    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{out_name or bench}.json").write_text(json.dumps(out))
    return out


def main():
    # train_loop progress goes through logging ("repro.train"); opt in so
    # hour-long runs keep printing per-episode progress
    import logging
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--batch-size", type=int, default=1,
                    help="lockstep rollout lanes for AQORA training "
                         "(1 = the paper's per-query replay; >1 pools "
                         "updates per episode-batch)")
    args = ap.parse_args()
    benches = ["job", "extjob", "stack"] if args.all else [args.bench]
    for b in benches:
        out = RESULTS / f"{b}.json"
        if out.exists() and not args.force:
            print(f"skip cached {b}")
            continue
        run_bench(b, batch_size=args.batch_size)


if __name__ == "__main__":
    main()
