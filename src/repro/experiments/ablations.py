"""Ablation + dynamic-evaluation experiments (paper Fig. 9, Fig. 11,
Tab. III). Each variant trains on ExtJOB (as in §VII-D) and evaluates on
its test set; dynamic eval trains on IMDb-1950/-1980 snapshots of the JOB
workload and tests on the full database (§VII-B5), plus the cross-workload
transfers. Results land in results/aqora/ablations.json (resumable).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import numpy as np

from repro.baselines import LeroOptimizer, run_spark_default
from repro.core.agent import AgentConfig, AqoraAgent
from repro.core.dqn import DQNAgent
from repro.core.encoding import WorkloadMeta
from repro.core.train_loop import evaluate, train_agent
from repro.experiments.main_experiment import SCALE, make_db
from repro.sql import datagen, workloads
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "aqora"
EPISODES = 300


def _summ(rows):
    return {"total": sum(r["total"] for r in rows),
            "exec": sum(r["latency"] for r in rows),
            "plan": sum(r["plan_time"] for r in rows),
            "fails": sum(r["failed"] for r in rows),
            "per_query": rows}


def _train_eval(db, wl, cfg: AgentConfig, *, episodes=EPISODES, seed=0,
                agent=None, use_curriculum=True, test_db=None, test_est=None,
                track_curve=True):
    est = Estimator(db, db.stats)
    agent, logs = train_agent(db, wl, episodes=episodes, seed=seed, cfg=cfg,
                              est=est, agent=agent,
                              use_curriculum=use_curriculum)
    rows = evaluate(test_db if test_db is not None else db, wl.test,
                    agent, est=test_est or est)
    out = _summ(rows)
    if track_curve:
        lat = [l.latency for l in logs]
        out["curve"] = [float(np.mean(lat[i:i + 30]))
                        for i in range(0, len(lat), 30)]
        out["train_fail_curve"] = [int(np.sum([l > 299 for l in lat[i:i + 30]]))
                                   for i in range(0, len(lat), 30)]
    return out


def run_all(force=False):
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / "ablations.json"
    out = json.loads(path.read_text()) if path.exists() and not force else {}

    db = make_db("extjob", 0)
    wl = workloads.make_workload("extjob", n_train=120,
                                 n_test_per_template=2, seed=7)

    def save():
        path.write_text(json.dumps(out))

    def todo(k):
        return k not in out

    t0 = time.time()
    # ---------------- Fig 11(a): PPO vs DQN
    if todo("rl_ppo"):
        out["rl_ppo"] = _train_eval(db, wl, AgentConfig())
        save(); print("rl_ppo done", int(time.time() - t0))
    if todo("rl_dqn"):
        meta = WorkloadMeta.from_workload(wl)
        dqn = DQNAgent(meta, AgentConfig(), seed=0)
        out["rl_dqn"] = _train_eval(db, wl, AgentConfig(), agent=dqn)
        save(); print("rl_dqn done", int(time.time() - t0))

    # ---------------- Fig 11(b)/Tab III: encoder ablation
    for net in ("lstm", "fcnn", "queryformer"):
        k = f"net_{net}"
        if todo(k):
            r = _train_eval(db, wl, AgentConfig(net=net))
            # optimization overhead: mean hook seconds per eval query
            out[k] = r
            save(); print(k, "done", int(time.time() - t0))

    # ---------------- Fig 11(c): strategy ablation
    if todo("strat_no_step_limit"):
        out["strat_no_step_limit"] = _train_eval(
            db, wl, AgentConfig(max_steps=8))
        save(); print("strat_no_step_limit done", int(time.time() - t0))
    if todo("strat_no_curriculum"):
        out["strat_no_curriculum"] = _train_eval(
            db, wl, AgentConfig(), use_curriculum=False)
        save(); print("strat_no_curriculum done", int(time.time() - t0))

    # ---------------- §VII-D4: action-space ablation
    for name, fams in (("act_plus_broadcast", ("cbo", "lead", "broadcast", "noop")),
                       ("act_no_lead", ("cbo", "noop")),
                       ("act_no_cbo", ("lead", "noop")),
                       ("act_plus_swap", ("cbo", "lead", "swap", "noop"))):
        if todo(name):
            out[name] = _train_eval(db, wl, AgentConfig(families=fams))
            save(); print(name, "done", int(time.time() - t0))

    # ---------------- Fig 9 row 1: data-evolution (train old, test full)
    full_db = make_db("job", 0)
    wl_job = workloads.make_workload("job", n_train=120,
                                     n_test_per_template=2, seed=7)
    for year in (1950, 1980):
        k = f"dyn_imdb{year}"
        if todo(k):
            old_db = datagen.make_job_like(scale=SCALE, seed=0, year_max=year)
            test_est = Estimator(full_db, old_db.stats)   # STALE stats
            out[k] = {
                "aqora": _train_eval(old_db, wl_job, AgentConfig(),
                                     test_db=full_db, test_est=test_est,
                                     track_curve=False),
            }
            lero = LeroOptimizer(old_db, Estimator(old_db, old_db.stats))
            rng = np.random.default_rng(0)
            for _ in range(50):
                lero.train_episode(wl_job.train[int(rng.integers(len(wl_job.train)))])
            lero.db, lero.est = full_db, test_est
            out[k]["lero"] = _summ([
                {"query": q.name, "latency": (r := lero.run(q)).latency,
                 "plan_time": r.plan_time, "total": r.total,
                 "failed": r.failed} for q in wl_job.test])
            out[k]["spark"] = _summ([
                {"query": q.name, "latency": (r := run_spark_default(
                    full_db, q, test_est)).latency, "plan_time": 0.0,
                 "total": r.latency, "failed": r.failed}
                for q in wl_job.test])
            save(); print(k, "done", int(time.time() - t0))

    # ---------------- Fig 9 row 2: cross-workload transfer
    if todo("dyn_job_to_extjob"):
        est = Estimator(full_db, full_db.stats)
        agent, _ = train_agent(full_db, wl_job, episodes=EPISODES, seed=0,
                               cfg=AgentConfig(), est=est)
        out["dyn_job_to_extjob"] = _summ(
            evaluate(full_db, wl.test, agent, est=est))
        save(); print("dyn_job_to_extjob done", int(time.time() - t0))
    if todo("dyn_extjob_to_job"):
        est = Estimator(full_db, full_db.stats)
        agent, _ = train_agent(full_db, wl, episodes=EPISODES, seed=0,
                               cfg=AgentConfig(), est=est)
        out["dyn_extjob_to_job"] = _summ(
            evaluate(full_db, wl_job.test, agent, est=est))
        save(); print("dyn_extjob_to_job done", int(time.time() - t0))

    # ---------------- Fig 3: CBO planning-cost blowup
    if todo("cbo_cost"):
        from repro.sql.cbo import dp_join_order
        rows = []
        for q in sorted(wl_job.test, key=lambda q: q.n_relations):
            est = Estimator(full_db, full_db.stats)
            t_dp = dp_join_order(q, est)[1] if q.n_relations <= 12 else None
            from repro.sql.plans import syntactic_plan
            from repro.sql.executor import run_adaptive
            from repro.sql.cbo import cbo_plan
            r0 = run_adaptive(full_db, q, syntactic_plan(q), est)
            p1, t1 = cbo_plan(q, est)
            r1 = run_adaptive(full_db, q, p1, est)
            rows.append({"query": q.name, "n": q.n_relations,
                         "plan_time": t1, "exec_no_cbo": r0.latency,
                         "exec_cbo": r1.latency})
        out["cbo_cost"] = rows
        save(); print("cbo_cost done", int(time.time() - t0))
    print("ablations complete")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    run_all(force=ap.parse_args().force)
