import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ dry-run lowering needs the production mesh (same rule as dryrun.py).

"""§Perf hillclimbs: drive the three selected cells with the Plane-B
re-optimizer. Each iteration re-lowers the cell and logs
hypothesis -> predicted -> measured -> verdict into results/perf/.

Cells (chosen per the assignment's criteria from the baseline table):
  qwen3-8b     x train_4k   — representative cell, Plane-B loop end-to-end
  dbrx-132b    x train_4k   — most collective-bound (139.8 s baseline)
  minicpm3-4b  x decode_32k — worst useful-FLOPs ratio (0.002: MLA latent
                              cache re-expanded every token)
"""
import json
import time

from repro.adapt.knobs import BASELINE, LayoutPlan
from repro.adapt.search import LayoutReoptimizer

CELLS = [
    ("qwen3-8b", "train_4k", "train"),
    ("dbrx-132b", "train_4k", "train"),
    ("minicpm3-4b", "decode_32k", "decode"),
]


def main():
    for arch, shape, kind in CELLS:
        t0 = time.time()
        print(f"=== hillclimb {arch} x {shape} ===", flush=True)
        opt = LayoutReoptimizer(arch, shape)
        best, logs = opt.climb(max_iters=8, kind=kind)
        print(f"--- {arch} x {shape}: best layout {best.name()} "
              f"({len(logs)} iterations, {time.time()-t0:.0f}s)", flush=True)
        for l in logs:
            print(f"  it{l.iteration}: {l.layout} -> {l.verdict}")


if __name__ == "__main__":
    main()

def bonus_decode_cell():
    """4th cell: qwen1.5-4b decode_32k (most collective-bound decode)."""
    opt = LayoutReoptimizer("qwen1.5-4b", "decode_32k")
    best, logs = opt.climb(max_iters=5, kind="decode")
    print(f"--- qwen1.5-4b x decode_32k: best {best.name()}")
    for l in logs:
        print(f"  it{l.iteration}: {l.layout} -> {l.verdict}")
