"""Flash attention as a Pallas TPU kernel.

TPU adaptation (vs. the CUDA original): the online-softmax recurrence is
blocked for the MXU — (block_q x hd) @ (hd x block_k) score tiles with
fp32 accumulators held in VMEM scratch that persist across the sequential
innermost grid dimension (TPU grids execute in order, so the k-block loop
is a grid axis, not an in-kernel loop). Causal/sliding-window masks are
computed from broadcasted iotas; fully-masked tiles skip their MXU work
with pl.when, so sliding-window attention costs O(Sq * window).

Layout: q (BH, Sq, hd), k/v (BKV, Sk, hd) with GQA group size G = BH//BKV
resolved in the k/v BlockSpec index maps (no materialized head broadcast).
VMEM working set per grid cell: q/k/v/o tiles + (block_q x hd) fp32 acc ~=
(3*block_k + 2*block_q) * hd * 2B + block_q*hd*4B ~= 0.43 MB at the
128/128/hd=128 defaults — far under the ~16 MB/core budget, leaving room
for the pipeline's double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qref, kref, vref, oref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, block_q, block_k, n_k, seq_off,
            sk_real):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = (qi * block_q + seq_off
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0))
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < sk_real                      # key padding
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window

    @pl.when(jnp.any(mask))
    def _compute():
        q = qref[0].astype(jnp.float32)                  # (bq, hd)
        k = kref[0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)      # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = vref[0].astype(jnp.float32)                   # (bk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        oref[0] = (acc_scr[...] / l).astype(oref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, block_q=128, block_k=128, interpret=False):
    """q: (BH, Sq, hd); k/v: (BKV, Sk, hd) with BH % BKV == 0 (GQA).
    Queries are right-aligned against keys: qpos = arange(Sq) + (Sk - Sq),
    so prefill (Sq == Sk) and decode-suffix calls share one kernel."""
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    assert BH % BKV == 0
    G = BH // BKV
    scale = (hd ** -0.5) if scale is None else scale
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    seq_off = Sk - Sq
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    n_q = q.shape[1] // block_q
    n_k = k.shape[1] // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, n_k=n_k, seq_off=seq_off,
        sk_real=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, q.shape[1], hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pq:
        out = out[:, :Sq]
    return out
