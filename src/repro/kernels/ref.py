"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                        scale=None):
    """q: (BH, Sq, hd), k/v: (BH, Sk, hd). fp32 softmax, full scores."""
    hd = q.shape[-1]
    scale = (hd ** -0.5) if scale is None else scale
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)     # right-aligned positions
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)            # fully-masked rows -> 0
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def mamba_scan_ref(x, dt, A, Bs, Cs, h0=None):
    """Sequential selective-scan oracle.
    x/dt: (B, S, di); Bs/Cs: (B, S, N); A: (di, N); h0: (B, di, N).
    Returns (y (B,S,di), h_last (B,di,N)), fp32."""
    B, S, di = x.shape
    A = jnp.asarray(A)
    N = A.shape[1]
    xf = jnp.asarray(x, jnp.float32)
    dtf = jnp.asarray(dt, jnp.float32)
    Bf = jnp.asarray(Bs, jnp.float32)
    Cf = jnp.asarray(Cs, jnp.float32)
    h = jnp.zeros((B, di, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t, :, None] * A)                 # (B, di, N)
        b = (dtf[:, t] * xf[:, t])[..., None] * Bf[:, t, None, :]
        h = a * h + b
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return ys.transpose(1, 0, 2), h


def tree_conv_ref(feat, left, right, mask, wr, wl, wrt, b):
    """Neo-style tree convolution oracle.
    feat: (N, F); left/right: (N,) child indices (0 = null, row 0 zeroed);
    returns (N, H) leaky-relu activations, padding re-zeroed."""
    h = feat * mask[:, None]
    out = h @ wr + h[left] @ wl + h[right] @ wrt + b
    return jax.nn.leaky_relu(out) * mask[:, None]
