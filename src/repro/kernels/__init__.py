"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle in ref.py and a jit'd model-layout wrapper in ops.py:

  flash_attention — online-softmax attention, VMEM accumulators, GQA index
                    maps, causal/sliding-window/softcap
  mamba_scan      — chunked selective scan, VMEM-resident state
  tree_conv       — one AQORA TreeCNN layer; child gathers as one-hot MXU
                    matmuls (one-hots built on the host, shipped via HBM)
  tree_cnn_fused  — the whole TreeCNN encoder (3 conv layers + residual +
                    masked max-pool) in ONE VMEM-resident kernel over
                    multi-tree tiles; child one-hots are rebuilt in-kernel
                    from iota==idx compares, so no (B, N, N) matrices and
                    no intermediate activations ever touch HBM

Validated in interpret=True mode on CPU (tests/test_kernels.py,
tests/test_vec_rollout.py); on real TPUs they swap in behind the model's
pure-jnp paths (tree_cnn_fused via AgentConfig.fused_treecnn).
"""
