"""Pallas TPU kernels for the perf-critical compute layers, each with a
pure-jnp oracle in ref.py and a jit'd model-layout wrapper in ops.py:

  flash_attention — online-softmax attention, VMEM accumulators, GQA index
                    maps, causal/sliding-window/softcap
  mamba_scan      — chunked selective scan, VMEM-resident state
  tree_conv       — AQORA TreeCNN layer; child gathers as one-hot MXU matmuls

Validated in interpret=True mode on CPU (tests/test_kernels.py); on real
TPUs they swap in behind the model's pure-jnp paths.
"""
