"""Jit'd public wrappers bridging model-layer shapes to the kernels.

`use_pallas` flips the model between the pure-jnp paths (CPU/dry-run; the
collectives and cost structure XLA sees) and the Pallas kernels (real TPU).
On this CPU container the kernels run only under interpret=True, which is
what the per-kernel allclose tests exercise.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.tree_conv import tree_conv


def mha_flash(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
              interpret=False):
    """Model-layout wrapper: q (B, Sq, H, hd), k/v (B, Sk, K, hd) GQA.
    Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, v.shape[1], hd)
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          softcap=softcap, scale=scale, interpret=interpret)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def selective_scan_fused(x, dt, A, Bs, Cs, D_skip, *, chunk=128,
                         interpret=False):
    """Mamba block core matching models.mamba.selective_scan's contract:
    returns (y + x * D_skip, h_last is NOT returned — training path only)."""
    y = mamba_scan(x, dt, A, Bs, Cs, chunk=chunk, interpret=interpret)
    return y + x.astype(jnp.float32) * D_skip


def tree_conv_batch(feat, left, right, mask, params, *, interpret=False):
    """AQORA TreeCNN layer: params {wr, wl, wrt, b} as in core.nets.
    The whole fused encoder (tree_cnn_fused) is dispatched directly by
    core.nets.apply_encoder rather than wrapped here."""
    return tree_conv(feat, left, right, mask, params["wr"], params["wl"],
                     params["wrt"], params["b"], interpret=interpret)
