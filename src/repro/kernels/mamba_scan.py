"""Chunked Mamba-1 selective scan as a Pallas TPU kernel.

TPU adaptation: the recurrence h_t = a_t * h_{t-1} + b_t is blocked as
(time chunks) x (d_inner tiles). The grid is (B, d_inner/block_d, S/chunk)
with the innermost (time) axis sequential on TPU, so the (block_d, N) state
lives in VMEM scratch and crosses chunk boundaries without HBM round-trips.
Inside a chunk the scan runs as a fori_loop of VPU FMAs over VREG-resident
tiles — the state never leaves vector registers within a chunk; the
numerically-explosive cumprod-division trick used by some GPU ports is
deliberately avoided (A < 0 makes exp-cumprods underflow).

VMEM per cell: (chunk x block_d) x/dt tiles + (chunk x N) B/C tiles +
(block_d x N) state ~= 0.3 MB at chunk=128, block_d=256, N=16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, h_scr, *, chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)        # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)      # (chunk, bd)
    A = A_ref[...].astype(jnp.float32)      # (bd, N)
    Bc = B_ref[0].astype(jnp.float32)       # (chunk, N)
    Cc = C_ref[0].astype(jnp.float32)       # (chunk, N)

    def step(t, carry):
        h, ys = carry
        a = jnp.exp(dt[t][:, None] * A)                   # (bd, N)
        b = (dt[t] * x[t])[:, None] * Bc[t][None, :]      # (bd, N)
        h = a * h + b
        y = jnp.sum(h * Cc[t][None, :], axis=1)           # (bd,)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y[None], t, axis=0)
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros_like(x)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def mamba_scan(x, dt, A, Bs, Cs, *, chunk=128, block_d=256, interpret=False):
    """x/dt: (B, S, di); A: (di, N); Bs/Cs: (B, S, N).
    Returns y (B, S, di) = sum_n C[t,n] * h[t,d,n] (no D-skip/gating — the
    wrapper applies those). S padded to chunk multiples; di to block_d."""
    B, S, di = x.shape
    N = A.shape[1]
    block_d = min(block_d, di)
    ps = (-S) % chunk
    pd = (-di) % block_d
    if ps or pd:
        x = jnp.pad(x, ((0, 0), (0, ps), (0, pd)))
        dt = jnp.pad(dt, ((0, 0), (0, ps), (0, pd)))
        Bs = jnp.pad(Bs, ((0, 0), (0, ps), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, ps), (0, 0)))
        A = jnp.pad(A, ((0, pd), (0, 0)))
    n_d = x.shape[2] // block_d
    n_c = x.shape[1] // chunk

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(B, n_d, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bs, Cs)
    return out[:, :S, :di]
