"""Neo-style tree convolution as a Pallas TPU kernel (AQORA's decision-
model hot spot: called at every stage boundary of every running query).

TPU adaptation: child gathers (h[left], h[right]) are data-dependent loads
— poison for the TPU's vector memory. We re-express them as one-hot
matmuls: gather(h, idx) == onehot(idx) @ h, turning the whole layer into
three MXU matmuls fused in one VMEM-resident kernel:

    out = leaky_relu(h @ Wr + (L @ h) @ Wl + (R @ h) @ Wrt + b) * mask

Trees are padded to MAX_NODES=64, so a whole batch tile (trees x nodes x
feat) fits VMEM comfortably; grid is over tree batches.

Two entry points:

  tree_conv      — ONE conv layer; builds the (B, N, N) one-hots on the
                   host with jax.nn.one_hot and ships them through HBM
                   (legacy; kept as the per-layer building block).
  tree_cnn_fused — the WHOLE encoder: all three conv layers + residual +
                   masked max-pool in one VMEM-resident kernel over
                   multi-tree tiles. Child one-hot matrices are built
                   in-kernel from `iota == idx` comparisons, so no
                   O(B*N^2) one-hot traffic ever touches HBM and no
                   intermediate (B, N, H) activations round-trip either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(h_ref, lo_ref, ro_ref, m_ref, wr_ref, wl_ref, wrt_ref, b_ref,
            o_ref):
    h = h_ref[0].astype(jnp.float32)          # (N, F)
    m = m_ref[0].astype(jnp.float32)          # (N, 1)
    h = h * m
    lo = lo_ref[0].astype(jnp.float32)        # (N, N) one-hot(left)
    ro = ro_ref[0].astype(jnp.float32)
    hl = jax.lax.dot_general(lo, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    hr = jax.lax.dot_general(ro, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    out = (h @ wr_ref[...].astype(jnp.float32)
           + hl @ wl_ref[...].astype(jnp.float32)
           + hr @ wrt_ref[...].astype(jnp.float32)
           + b_ref[...].astype(jnp.float32)[None, :])
    out = jnp.where(out > 0, out, 0.01 * out)           # leaky_relu
    o_ref[0] = (out * m).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_conv(feat, left, right, mask, wr, wl, wrt, b, *, interpret=False):
    """feat: (B, N, F); left/right: (B, N) int32 child indices (0 = null,
    row 0 must be a zero row); mask: (B, N); weights (F, H), b (H,).
    Returns (B, N, H)."""
    Bt, N, F = feat.shape
    H = wr.shape[1]
    onehot_l = jax.nn.one_hot(left, N, dtype=feat.dtype)     # (B, N, N)
    onehot_r = jax.nn.one_hot(right, N, dtype=feat.dtype)
    m = mask[..., None].astype(feat.dtype)

    return pl.pallas_call(
        _kernel,
        grid=(Bt,),
        in_specs=[
            pl.BlockSpec((1, N, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, N, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, N, H), feat.dtype),
        interpret=interpret,
    )(feat, onehot_l, onehot_r, m, wr, wl, wrt, b)


# ------------------------------------------------------------- fused encoder
def _fused_kernel(h_ref, li_ref, ri_ref, m_ref,
                  w1r, w1l, w1t, b1, w2r, w2l, w2t, b2, w3r, w3l, w3t, b3,
                  o_ref):
    """One multi-tree tile: (TB, N, F) feats -> (TB, H) pooled encodings.

    The child one-hots are rebuilt in VMEM from index comparisons — row n
    of L is one-hot at column left[n], so L @ h == h[left] — and every
    intermediate activation lives and dies in VMEM.
    """
    TB = h_ref.shape[0]
    N = h_ref.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (N, N), 1)

    def layer(h, m, lo, ro, wr, wl, wt, b):
        hl = jax.lax.dot_general(lo, h, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        hr = jax.lax.dot_general(ro, h, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        out = (h @ wr[...].astype(jnp.float32)
               + hl @ wl[...].astype(jnp.float32)
               + hr @ wt[...].astype(jnp.float32)
               + b[...].astype(jnp.float32)[None, :])
        out = jnp.where(out > 0, out, 0.01 * out)           # leaky_relu
        return out * m

    def one_tree(t, carry):
        m = m_ref[t].astype(jnp.float32)                    # (N, 1)
        lo = (iota == li_ref[t]).astype(jnp.float32)        # (N, N) in VMEM
        ro = (iota == ri_ref[t]).astype(jnp.float32)
        h = h_ref[t].astype(jnp.float32) * m                # (N, F)
        h1 = layer(h, m, lo, ro, w1r, w1l, w1t, b1)
        h2 = layer(h1, m, lo, ro, w2r, w2l, w2t, b2)
        h3 = layer(h2, m, lo, ro, w3r, w3l, w3t, b3) + h2   # residual
        neg = jnp.where(m > 0, h3, -jnp.inf)                # masked max-pool
        pooled = jnp.max(neg, axis=0)
        pooled = jnp.where(jnp.isfinite(pooled), pooled, 0.0)
        o_ref[t] = pooled.astype(o_ref.dtype)
        return carry

    jax.lax.fori_loop(0, TB, one_tree, 0)


def _fused_forward(feat, left, right, mask, params, tile, interpret):
    """Forward pallas_call for the fused encoder (no autodiff rules)."""
    B, N, F = feat.shape
    H = params["conv1"]["wr"].shape[1]
    TB = min(tile, B)
    Bp = ((B + TB - 1) // TB) * TB
    if Bp != B:                       # pad to a whole number of tiles; the
        pad = ((0, Bp - B), (0, 0))   # all-zero mask rows pool to 0
        feat = jnp.pad(feat, pad + ((0, 0),))
        left = jnp.pad(left, pad)
        right = jnp.pad(right, pad)
        mask = jnp.pad(mask, pad)
    li = left.astype(jnp.int32)[..., None]                  # (Bp, N, 1)
    ri = right.astype(jnp.int32)[..., None]
    m = mask[..., None].astype(feat.dtype)                  # (Bp, N, 1)

    wspec = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    w = []
    specs = []
    for lname in ("conv1", "conv2", "conv3"):
        p = params[lname]
        w += [p["wr"], p["wl"], p["wrt"], p["b"]]
        d_in = p["wr"].shape[0]
        specs += [wspec((d_in, H)), wspec((d_in, H)), wspec((d_in, H)),
                  wspec((H,))]

    out = pl.pallas_call(
        _fused_kernel,
        grid=(Bp // TB,),
        in_specs=[
            pl.BlockSpec((TB, N, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((TB, N, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((TB, N, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((TB, N, 1), lambda i: (i, 0, 0)),
        ] + specs,
        out_specs=pl.BlockSpec((TB, H), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, H), feat.dtype),
        interpret=interpret,
    )(feat, li, ri, m, *w)
    return out[:B]


# ------------------------------------------------- custom VJP for training
def _ref_tree_cnn(feat, left, right, mask, params):
    """jnp reference of the fused kernel for ONE tree — the SAME math
    (one-hot gather == h[idx] for in-range indices, leaky_relu slope 0.01,
    residual, masked max-pool), used to build the backward pass."""
    m = mask[:, None]
    h = feat * m

    def layer(h, p):
        out = (h @ p["wr"] + h[left] @ p["wl"] + h[right] @ p["wrt"]
               + p["b"])
        out = jnp.where(out > 0, out, 0.01 * out)
        return out * m

    h1 = layer(h, params["conv1"])
    h2 = layer(h1, params["conv2"])
    h3 = layer(h2, params["conv3"]) + h2
    neg = jnp.where(m > 0, h3, -jnp.inf)
    pooled = jnp.max(neg, axis=0)
    return jnp.where(jnp.isfinite(pooled), pooled, 0.0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _fused_with_vjp(feat, left, right, mask, params, tile, interpret):
    return _fused_forward(feat, left, right, mask, params, tile, interpret)


def _fused_fwd(feat, left, right, mask, params, tile, interpret):
    out = _fused_forward(feat, left, right, mask, params, tile, interpret)
    return out, (feat, left, right, mask, params)


def _fused_bwd(tile, interpret, residuals, g):
    """Backward by rematerialization: re-run the (cheap, (B,N,H)-sized)
    jnp reference forward and pull the cotangent through it. The fused
    kernel keeps its VMEM-resident forward on the hot path; the backward
    trades one extra reference forward for not spilling any intermediate
    activations to HBM during inference."""
    feat, left, right, mask, params = residuals

    def ref(f, m, p):
        return jax.vmap(_ref_tree_cnn, in_axes=(0, 0, 0, 0, None))(
            f, left, right, m, p)

    _, pullback = jax.vjp(ref, feat, mask, params)
    gf, gm, gp = pullback(g)
    zero_int = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return gf, zero_int(left), zero_int(right), gm, gp


_fused_with_vjp.defvjp(_fused_fwd, _fused_bwd)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def tree_cnn_fused(feat, left, right, mask, params, *, tile=8,
                   interpret=None):
    """Fused TreeCNN encoder: conv1..conv3 + residual + masked max-pool.

    feat: (B, N, F); left/right: (B, N) int32 child indices (0 = null,
    row 0 must be a zero row); mask: (B, N); params: the core.nets treecnn
    dict {"conv1"|"conv2"|"conv3": {"wr","wl","wrt","b"}}. Returns (B, H)
    pooled encodings. Only (B, N) index vectors cross HBM — the one-hot
    matrices and all intermediate activations exist in VMEM only.
    `interpret=None` auto-selects interpreter mode off-TPU.

    Differentiable w.r.t. feat, mask and params via a custom VJP (backward
    rematerializes through the jnp reference), so PPO training can run
    the fused kernel — not just rollout inference.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _fused_with_vjp(feat, left, right, mask, params, tile, interpret)
