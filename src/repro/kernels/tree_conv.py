"""Neo-style tree convolution as a Pallas TPU kernel (AQORA's decision-
model hot spot: called at every stage boundary of every running query).

TPU adaptation: child gathers (h[left], h[right]) are data-dependent loads
— poison for the TPU's vector memory. We re-express them as one-hot
matmuls: gather(h, idx) == onehot(idx) @ h, turning the whole layer into
three MXU matmuls fused in one VMEM-resident kernel:

    out = leaky_relu(h @ Wr + (L @ h) @ Wl + (R @ h) @ Wrt + b) * mask

Trees are padded to MAX_NODES=64, so a whole batch tile (trees x nodes x
feat) fits VMEM comfortably; grid is over tree batches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, lo_ref, ro_ref, m_ref, wr_ref, wl_ref, wrt_ref, b_ref,
            o_ref):
    h = h_ref[0].astype(jnp.float32)          # (N, F)
    m = m_ref[0].astype(jnp.float32)          # (N, 1)
    h = h * m
    lo = lo_ref[0].astype(jnp.float32)        # (N, N) one-hot(left)
    ro = ro_ref[0].astype(jnp.float32)
    hl = jax.lax.dot_general(lo, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    hr = jax.lax.dot_general(ro, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    out = (h @ wr_ref[...].astype(jnp.float32)
           + hl @ wl_ref[...].astype(jnp.float32)
           + hr @ wrt_ref[...].astype(jnp.float32)
           + b_ref[...].astype(jnp.float32)[None, :])
    out = jnp.where(out > 0, out, 0.01 * out)           # leaky_relu
    o_ref[0] = (out * m).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tree_conv(feat, left, right, mask, wr, wl, wrt, b, *, interpret=False):
    """feat: (B, N, F); left/right: (B, N) int32 child indices (0 = null,
    row 0 must be a zero row); mask: (B, N); weights (F, H), b (H,).
    Returns (B, N, H)."""
    Bt, N, F = feat.shape
    H = wr.shape[1]
    onehot_l = jax.nn.one_hot(left, N, dtype=feat.dtype)     # (B, N, N)
    onehot_r = jax.nn.one_hot(right, N, dtype=feat.dtype)
    m = mask[..., None].astype(feat.dtype)

    return pl.pallas_call(
        _kernel,
        grid=(Bt,),
        in_specs=[
            pl.BlockSpec((1, N, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, N, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((F, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, N, H), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, N, H), feat.dtype),
        interpret=interpret,
    )(feat, onehot_l, onehot_r, m, wr, wl, wrt, b)
