"""The paper's three comparison systems, re-implemented against the same
staged engine: Spark SQL default (+AQE), Lero-style learning-to-rank over
cardinality-perturbed candidate plans, and AutoSteer-style greedy
rule-toggle search — plus the serving-shaped CBO re-plan policy
(`CboReplanAgent`) the drift benchmark probes statistics quality with."""
from repro.baselines.spark_default import run_spark_default
from repro.baselines.lero import LeroOptimizer
from repro.baselines.autosteer import AutoSteerOptimizer
from repro.baselines.cbo_serve import CboReplanAgent
