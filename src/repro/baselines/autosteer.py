"""AutoSteer-style rule-toggle optimizer (§II-b, §VII-A3c).

AutoSteer systematically disables optimizer rules to generate plan
variants, then greedily composes the rule-disable set predicted fastest by
a learned model. Our engine's toggleable "rules":

  cbo        — cost-based join reordering (off -> syntactic order)
  aqe_switch — runtime SMJ->BHJ operator switching
  coalesce   — AQE shuffle-partition coalescing
  bjt_boost  — 4x broadcast threshold (aggressive broadcasting)

The learned predictor is an MLP over (query descriptor ++ toggle bitmask)
trained on observed latencies. The paper's characteristic failure mode —
favouring disabled high-overhead rules that backfire on complex queries
(Tab. II failures) — emerges naturally: disabling aqe_switch/cbo is often
fastest on small queries but catastrophic on join-heavy ones.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nets
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sql.cbo import Estimator, cbo_plan
from repro.sql.cluster import ClusterModel
from repro.sql.executor import RunResult, annotate_methods, run_adaptive
from repro.sql.plans import syntactic_plan

RULES = ("cbo", "aqe_switch", "coalesce", "bjt_boost")
EXPLAIN_OVERHEAD = 0.4       # s per EXPLAIN; cheaper than Lero's (§VII-B2)
QFEAT = 12


def query_features(query, est: Estimator) -> np.ndarray:
    f = np.zeros(QFEAT, np.float32)
    f[0] = query.n_relations
    f[1] = len(query.conds)
    rows = sorted((est.base_rows(query, r.alias) for r in query.relations),
                  reverse=True)
    prof = np.log1p(np.asarray(rows[:QFEAT - 2]))
    f[2:2 + len(prof)] = prof
    return f


class AutoSteerOptimizer:
    def __init__(self, db, est: Estimator, seed: int = 0,
                 cluster: ClusterModel = ClusterModel()):
        self.db, self.est, self.cluster = db, est, cluster
        k = jax.random.split(jax.random.PRNGKey(seed), 1)[0]
        self.net = nets.init_mlp_head(k, QFEAT + len(RULES), 64, 1)
        self.opt = adamw_init(self.net)
        self._ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
        self._xs: List[np.ndarray] = []
        self._ys: List[float] = []

        def update(params, opt, x, y):
            def loss(p):
                pred = jax.vmap(lambda xi: nets.apply_mlp_head(p, xi)[0])(x)
                return jnp.mean((pred - y) ** 2)
            l, g = jax.value_and_grad(loss)(params)
            params, opt, _ = adamw_update(params, g, opt, self._ocfg)
            return params, opt, l

        self._update = jax.jit(update)
        self._score = jax.jit(lambda p, x: nets.apply_mlp_head(p, x)[0])

    # ------------------------------------------------------------- exec
    def run_with_toggles(self, query, disabled: Tuple[str, ...]) -> RunResult:
        cluster = self.cluster
        if "coalesce" in disabled:
            cluster = dataclasses.replace(cluster, aqe_coalesce=False)
        if "bjt_boost" not in disabled:      # boost is itself a toggle-ON rule
            pass
        if "bjt_boost" in disabled:
            cluster = dataclasses.replace(cluster, bjt=cluster.bjt * 4)
        if "cbo" in disabled:
            plan, t_plan = syntactic_plan(query), 0.0
        else:
            plan, t_plan = cbo_plan(query, self.est)
        plan = annotate_methods(plan, query, self.est, cluster)
        return run_adaptive(self.db, query, plan, self.est, cluster,
                            aqe_switching="aqe_switch" not in disabled,
                            plan_time=t_plan)

    # ------------------------------------------------------------- choose
    def _predict(self, query, disabled) -> float:
        x = np.concatenate([query_features(query, self.est),
                            np.array([1.0 if r in disabled else 0.0
                                      for r in RULES], np.float32)])
        return float(self._score(self.net, jnp.asarray(x)))

    def choose(self, query) -> Tuple[Tuple[str, ...], float]:
        """Greedy hint-set construction (AutoSteer §4): start empty, add the
        single rule-disable predicted to help, repeat while improving.
        Charges one EXPLAIN per candidate evaluated."""
        n_explains = 1
        best: Tuple[str, ...] = ()
        best_pred = self._predict(query, best)
        improved = True
        while improved:
            improved = False
            for r in RULES:
                if r in best:
                    continue
                cand = best + (r,)
                n_explains += 1
                p = self._predict(query, cand)
                if p < best_pred:
                    best, best_pred, improved = cand, p, True
        return best, n_explains * EXPLAIN_OVERHEAD

    def run(self, query) -> RunResult:
        disabled, t_plan = self.choose(query)
        r = self.run_with_toggles(query, disabled)
        r.plan_time += t_plan
        return r

    # ------------------------------------------------------------- train
    def train_episode(self, query, rng: np.random.Generator):
        """Explore a random toggle set + the greedy set; fit the predictor."""
        cands = [(), tuple(rng.choice(RULES,
                                      size=rng.integers(1, 3), replace=False))]
        for disabled in cands:
            res = self.run_with_toggles(query, disabled)
            x = np.concatenate([query_features(query, self.est),
                                np.array([1.0 if r in disabled else 0.0
                                          for r in RULES], np.float32)])
            self._xs.append(x)
            self._ys.append(np.sqrt(res.latency))
        self._fit()

    def _fit(self, batch: int = 64):
        if len(self._xs) < 8:
            return
        rng = np.random.default_rng(len(self._xs))
        idx = rng.choice(len(self._xs), size=min(batch, len(self._xs)),
                         replace=False)
        x = jnp.asarray(np.stack([self._xs[i] for i in idx]))
        y = jnp.asarray(np.asarray([self._ys[i] for i in idx], np.float32))
        for _ in range(8):
            self.net, self.opt, _ = self._update(self.net, self.opt, x, y)
