"""Spark SQL default configuration (+AQE): the most common industrial
practice (§VII-A3a). CBO is off by default in Spark, so the join order is
the SQL text's syntactic order; AQE performs runtime SMJ->BHJ switching and
partition coalescing. No optimization-time overhead is charged (§VII-B2).
"""
from __future__ import annotations

from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.executor import RunResult, annotate_methods, run_adaptive
from repro.sql.plans import syntactic_plan


def run_spark_default(db, query, est: Estimator,
                      cluster: ClusterModel = ClusterModel()) -> RunResult:
    plan = annotate_methods(syntactic_plan(query), query, est, cluster)
    return run_adaptive(db, query, plan, est, cluster, hook=None)
