"""Lero-style learning-to-rank optimizer (§II-b, §VII-A3b).

Candidate generation follows Lero's mechanism: perturb the native
optimizer's cardinality estimates by scale factors and re-run join
enumeration — different factors surface genuinely different plans. A
pairwise comparator (MLP over plan feature vectors, trained with logistic
pairwise loss on observed latencies) picks the predicted-fastest candidate.

Cost accounting mirrors the paper: every candidate costs one EXPLAIN
(planning + plan serialization overhead), which is why Lero's optimization
time dominates its wins on short queries (Fig. 7).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nets
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sql.cbo import Estimator, cbo_plan
from repro.sql.cluster import ClusterModel
from repro.sql.executor import RunResult, annotate_methods, run_adaptive
from repro.sql.plans import Join, Leaf, Node, joins, leaves, syntactic_plan

EXPLAIN_OVERHEAD = 0.8       # s per EXPLAIN (modeled engine round-trip)
SCALE_FACTORS = (0.01, 0.1, 1.0, 10.0, 100.0)
FEAT_DIM = 24


@dataclasses.dataclass
class _ScaledEstimator(Estimator):
    factor: float = 1.0

    def join_rows(self, query, l_set, l_rows, r_set, r_rows):
        return super().join_rows(query, l_set, l_rows, r_set, r_rows) * self.factor


def plan_features(plan: Node, query, est: Estimator) -> np.ndarray:
    """Fixed-size plan descriptor: depth stats + estimated cardinality
    profile along the join sequence (log-space), padded."""
    f = np.zeros(FEAT_DIM, np.float32)
    js = joins(plan)
    f[0] = len(js)
    f[1] = float(max((_depth(plan), 1)))
    rows = []

    def est_rows(node) -> float:
        if isinstance(node, Leaf):
            return est.base_rows(query, node.alias)
        l = est_rows(node.left)
        r = est_rows(node.right)
        out = est.join_rows(query, frozenset(node.left.covered()), l,
                            frozenset(node.right.covered()), r)
        rows.append(out)
        return out

    est_rows(plan)
    prof = np.log1p(np.asarray(sorted(rows, reverse=True)[:FEAT_DIM - 4]))
    f[2] = float(np.log1p(sum(rows)))
    f[3] = float(np.log1p(max(rows) if rows else 0))
    f[4:4 + len(prof)] = prof
    return f


def _depth(node, d=1):
    if isinstance(node, Leaf):
        return d
    return max(_depth(node.left, d + 1), _depth(node.right, d + 1))


class LeroOptimizer:
    def __init__(self, db, est: Estimator, seed: int = 0,
                 cluster: ClusterModel = ClusterModel()):
        self.db, self.est, self.cluster = db, est, cluster
        k = jax.random.split(jax.random.PRNGKey(seed), 1)[0]
        self.net = nets.init_mlp_head(k, FEAT_DIM, 64, 1)
        self.opt = adamw_init(self.net)
        self._ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
        self._pairs: List[Tuple[np.ndarray, np.ndarray]] = []

        def score(params, x):
            return nets.apply_mlp_head(params, x)[0]

        self._score = jax.jit(score)

        def pair_loss(params, xa, xb):
            # xa observed faster than xb -> want score(xa) < score(xb)
            sa = jax.vmap(lambda x: nets.apply_mlp_head(params, x)[0])(xa)
            sb = jax.vmap(lambda x: nets.apply_mlp_head(params, x)[0])(xb)
            return jnp.mean(jax.nn.softplus(sa - sb))

        def update(params, opt, xa, xb):
            l, g = jax.value_and_grad(pair_loss)(params, xa, xb)
            params, opt, _ = adamw_update(params, g, opt, self._ocfg)
            return params, opt, l

        self._update = jax.jit(update)

    # ------------------------------------------------------------ candidates
    def candidates(self, query) -> Tuple[List[Node], float]:
        plans, sigs = [], set()
        t_plan = 0.0
        for fac in SCALE_FACTORS:
            est = _ScaledEstimator(self.est.db, self.est.stats, factor=fac)
            p, t = cbo_plan(query, est)
            t_plan += t + EXPLAIN_OVERHEAD
            sig = _order_sig(p)
            if sig not in sigs:
                sigs.add(sig)
                plans.append(annotate_methods(p, query, self.est, self.cluster))
        p0 = annotate_methods(syntactic_plan(query), query, self.est, self.cluster)
        if _order_sig(p0) not in sigs:
            plans.append(p0)
            t_plan += EXPLAIN_OVERHEAD
        return plans, t_plan

    # ------------------------------------------------------------ serving
    def choose(self, query) -> Tuple[Node, float, List[Node]]:
        plans, t_plan = self.candidates(query)
        feats = [plan_features(p, query, self.est) for p in plans]
        scores = [float(self._score(self.net, jnp.asarray(f))) for f in feats]
        best = int(np.argmin(scores))
        return plans[best], t_plan, plans

    def run(self, query) -> RunResult:
        plan, t_plan, _ = self.choose(query)
        return run_adaptive(self.db, query, plan, self.est, self.cluster,
                            plan_time=t_plan)

    # ------------------------------------------------------------ training
    def train_episode(self, query, explore_all: bool = True):
        """Execute candidates, record pairwise labels (Lero explores its
        candidate set during training — 'even an unchosen plan at training
        at least belongs to its explored set', §VII-B5)."""
        plans, _ = self.candidates(query)
        results = []
        for p in plans[:4]:              # bound exploration cost
            r = run_adaptive(self.db, query, p, self.est, self.cluster)
            results.append((plan_features(p, query, self.est), r.latency))
        for i in range(len(results)):
            for j in range(len(results)):
                if results[i][1] < results[j][1]:
                    self._pairs.append((results[i][0], results[j][0]))
        self._fit()
        return results

    def _fit(self, batch: int = 64):
        if len(self._pairs) < 8:
            return
        idx = np.random.default_rng(len(self._pairs)).choice(
            len(self._pairs), size=min(batch, len(self._pairs)), replace=False)
        xa = jnp.asarray(np.stack([self._pairs[i][0] for i in idx]))
        xb = jnp.asarray(np.stack([self._pairs[i][1] for i in idx]))
        for _ in range(4):
            self.net, self.opt, _ = self._update(self.net, self.opt, xa, xb)


def _order_sig(plan: Node) -> Tuple:
    return tuple(tuple(sorted(l.aliases)) for l in leaves(plan))
