"""CBO re-plan serving policy: the classical re-optimizing baseline.

`CboReplanAgent` is a scripted, parameter-free policy with the agent
interface the `LaneScheduler` drives (`meta`/`cfg`/`space`/`act_batch`):
at the pre-execution boundary it picks `cbo(1)` — re-plan the query with
the cost-based optimizer against the CURRENT catalog statistics — and
no-ops at every later boundary. It is what a system that "just re-runs
the optimizer at admission" would do, which makes it the natural probe
for statistics quality: its plans are a deterministic function of
`db.stats`, so serving metrics under this policy isolate the stale-stats
premise from learned-policy effects. `benchmarks/bench_drift.py` uses it
to price re-ANALYZE policies (the drift control plane) against the
paper's never-refresh baseline without an RL confound.

Deterministic and host-cheap by construction: no parameters, no RNG
consumption (keys pass through untouched), one numpy argmax-free branch
per lane.
"""
from __future__ import annotations

import numpy as np

from repro.core.actions import ActionSpace
from repro.core.agent import AgentConfig
from repro.core.encoding import WorkloadMeta

__all__ = ["CboReplanAgent"]


class CboReplanAgent:
    def __init__(self, meta: WorkloadMeta,
                 families=("cbo", "lead", "noop"), max_steps: int = 1):
        self.meta = meta
        # Default ONE hook step: the policy only ever acts pre-execution,
        # so a larger budget would just spend scheduler ticks on no-ops.
        # A larger `max_steps` buys mid-run stage boundaries (the extra
        # steps are no-ops), which is what the hedging control plane
        # needs to OBSERVE an overrunning lane before it finishes.
        self.cfg = AgentConfig(max_steps=max_steps, families=tuple(families))
        self.space = ActionSpace(meta.n_tables_max, self.cfg.families)
        self.cbo_idx = 0                      # action 0 == ("cbo", 1)

    def act_batch(self, feat, left, right, mask, amask, keys, *,
                  explore: bool = False):
        """cbo(1) wherever it is legal (the pre-exec boundary), noop
        everywhere else. `explore` is ignored — the baseline is greedy by
        definition — and the PRNG chain passes through untouched."""
        B = amask.shape[0]
        acts = np.where(amask[:, self.cbo_idx] > 0.0, self.cbo_idx,
                        self.space.noop_idx).astype(np.int32)
        return acts, np.zeros(B, np.float32), keys

    def act(self, enc, am, *, explore: bool = False):
        a, lp, _ = self.act_batch(None, None, None, None, am[None],
                                  np.zeros((1, 2), np.uint32))
        return int(a[0]), float(lp[0])
