"""int8 gradient compression with error feedback (distributed-optimization
trick; optional transform around the data-parallel gradient reduction).

Under GSPMD the gradient all-reduce is implicit, so compression is applied
as quantize -> dequantize around the point where XLA inserts the reduce:
wrapping the per-shard gradients in shard_map with an explicit psum over
the int8 payload (int32 accumulator) makes the wire format real — the
dry-run's collective-bytes term drops ~4x on the gradient reduction,
which is how EXPERIMENTS.md §Perf measures the win.

Error feedback (Seide et al.): the quantization residual is carried to the
next step so compression noise is a moving average, not a bias.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error_state=None):
    """Quantize every gradient leaf with error feedback.
    Returns (dequantized_grads, new_error_state)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if error_state is None:
        errs = [jnp.zeros_like(l, jnp.float32) for l in leaves]
    else:
        errs = treedef.flatten_up_to(error_state)
    outs, new_errs = [], []
    for g, e in zip(leaves, errs):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        dq = dequantize_int8(q, s)
        outs.append(dq.astype(g.dtype))
        new_errs.append(g32 - dq)
    return treedef.unflatten(outs), treedef.unflatten(new_errs)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Explicit compressed all-reduce for shard_map code paths: int8 wire
    payload, int32 accumulation, fp32 result. The scale is itself psum'd
    (max) so dequantization is consistent across shards."""
    q, s = quantize_int8(x.astype(jnp.float32))
    s_max = jax.lax.pmax(s, axis_name)
    # requantize against the GLOBAL scale so the sum is exact in int32
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s_max), -127, 127
                 ).astype(jnp.int8)
    tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return tot.astype(jnp.float32) * s_max
