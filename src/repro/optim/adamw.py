"""AdamW from scratch (no optax in this container), ZeRO-friendly.

Moments mirror the param pytree, so the sharding rules that shard params
shard the optimizer state identically (ZeRO-3 equivalent under GSPMD: the
per-param update is elementwise, so each device updates only its shard).

Supports global-norm clipping and decoupled weight decay. Moments are kept
in fp32 regardless of param dtype (bf16-param archs still get fp32 Adam),
matching the DESIGN.md numerics note.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params, moment_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
