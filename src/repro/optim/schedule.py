"""LR schedules (pure functions of the step scalar)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, warmup: int, total: int, floor: float = 0.1):
    """Linear warmup then cosine to floor*peak. Returns multiplier in [0,1]."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
