"""Top-level language models: embedding -> superblock stack -> head.

Covers every assigned family behind one functional API:

  init_params(key, cfg)                  -> params pytree
  forward(params, tokens, cfg, ...)      -> (logits, new_cache, aux)
  loss_fn(params, batch, cfg)            -> (scalar, metrics)
  init_cache(cfg, batch, max_len)        -> decode cache pytree (stacked per
                                            superblock, scanned by the stack)
  prefill(params, tokens, cfg, max_len)  -> (logits_last, cache)
  decode_step(params, token, cache, cfg) -> (logits, new_cache)

Enc-dec (whisper): `encode(params, frames, cfg)` produces the encoder memory;
decoder cross-attn layers consume it (the mel/conv frontend is a stub —
`frames` are precomputed frame embeddings per the assignment).
VLM (llama-3.2-vision): cross-attn layers consume precomputed patch
embeddings passed as `memory` (vision tower stubbed the same way).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.common import (apply_norm, init_norm, normal_init,
                                 softcap, split_keys)
from repro.sharding import act as act_sharding


# ------------------------------------------------------------------ init
def init_params(key, cfg):
    ks = split_keys(key, 6)
    p = {
        "embed": normal_init(ks[0], (cfg.vocab_size, cfg.d_model), cfg.pdtype),
        "stack": blocks.init_stack(ks[1], cfg),
        "final_norm": init_norm((cfg.d_model,), cfg.norm, cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = normal_init(ks[2], (cfg.d_model, cfg.vocab_size), cfg.pdtype)
    if cfg.learned_pos_emb:
        p["pos_embed"] = normal_init(ks[3], (cfg.max_decoder_len, cfg.d_model), cfg.pdtype)
    if cfg.encoder is not None:
        enc_cfg = cfg.encoder_cfg()
        p["encoder"] = {
            "stack": blocks.init_stack(ks[4], enc_cfg),
            "final_norm": init_norm((cfg.d_model,), cfg.norm, cfg.pdtype),
            "pos_embed": normal_init(ks[5], (cfg.encoder.n_frames, cfg.d_model), cfg.pdtype),
        }
    return p


# ------------------------------------------------------------------ encoder
def encode(params, frames, cfg):
    """frames: (B, n_frames, d_model) precomputed frame/patch embeddings (stub
    frontend). Returns encoder memory (B, n_frames, d_model)."""
    enc_cfg = cfg.encoder_cfg()
    ep = params["encoder"]
    x = frames.astype(cfg.cdtype) + ep["pos_embed"].astype(cfg.cdtype)[None]
    pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
    x, _, _ = blocks.apply_stack(ep["stack"], x, enc_cfg, positions=pos)
    return apply_norm(ep["final_norm"], x, cfg.norm)


# ------------------------------------------------------------------ forward
def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    if cfg.scale_emb != 1.0:
        x = x * jnp.asarray(cfg.scale_emb, cfg.cdtype)
    return act_sharding.constrain(x, {0: "dp"})


def _head(params, x, cfg):
    xn = apply_norm(params["final_norm"], x, cfg.norm,
                    unit_offset=cfg.name.startswith("gemma"))
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(cfg.cdtype)
    logits = xn.astype(cfg.cdtype) @ w
    logits = act_sharding.constrain(logits, {0: "dp", 2: "tp"})
    return softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


def forward(params, tokens, cfg, *, positions=None, cache=None, memory=None,
            collect_cache=False, remat=True, head="full"):
    """tokens: (B, S) int32. memory: (B, M, D) for cross-attn archs.
    head: "full" -> logits (B,S,V); "last" -> (B,1,V); "none" -> hidden.
    Returns (logits_or_hidden fp32, new_cache_or_None, aux scalar)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    x = _embed(params, tokens, cfg)
    if cfg.learned_pos_emb:
        x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(cfg.cdtype)
    x, new_cache, aux = blocks.apply_stack(
        params["stack"], x, cfg, positions=positions, cache=cache,
        memory=memory, remat=remat, collect_cache=collect_cache)
    if head == "none":
        return x, new_cache, aux
    if head == "last":
        x = x[:, -1:]
    return _head(params, x, cfg), new_cache, aux


# ------------------------------------------------------------------ loss
CE_CHUNK = 65536    # tokens per CE chunk: logits are never materialized for
                    # more than this many rows (chunked cross-entropy)


def _ce_chunked(params, x, targets, mask, cfg):
    """x: (B,S,D) hidden; targets/mask: (B,S). Computes sum-NLL/sum-mask with
    a remat'd lax.scan over token chunks so the (T, V) logits never exist."""
    B, S, D = x.shape
    xn = apply_norm(params["final_norm"], x, cfg.norm,
                    unit_offset=cfg.name.startswith("gemma"))
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(cfg.cdtype)
    T = B * S
    xt = xn.reshape(T, D).astype(cfg.cdtype)
    tt = targets.reshape(T)
    mt = mask.reshape(T).astype(jnp.float32)
    pol = act_sharding.current()
    chunk = (pol.ce_chunk if pol is not None and pol.ce_chunk else CE_CHUNK)
    C = min(chunk, T)
    pad = (-T) % C
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
        tt = jnp.pad(tt, (0, pad))
        mt = jnp.pad(mt, (0, pad))
    n = (T + pad) // C
    xc = xt.reshape(n, C, D)
    tc = tt.reshape(n, C)
    mc = mt.reshape(n, C)

    def body(carry, blk):
        xb, tb, mb = blk
        xb = act_sharding.constrain(xb, {0: "dp"})
        lg = xb @ w
        lg = act_sharding.constrain(lg, {0: "dp", 1: "tp"})
        lg = softcap(lg.astype(jnp.float32), cfg.final_logit_softcap)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tb[:, None], axis=-1)[:, 0]
        s, m = carry
        return (s + jnp.sum((lse - gold) * mb), m + jnp.sum(mb)), None

    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.float32)), (xc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg, *, remat=True):
    """batch: {"tokens": (B,S), "loss_mask": (B,S) optional, "memory": opt}.
    Next-token CE in fp32; chunked so full logits are never materialized."""
    tokens = batch["tokens"]
    memory = batch.get("memory")
    if cfg.encoder is not None:
        memory = encode(params, batch["frames"], cfg)
    x, _, aux = forward(params, tokens, cfg, memory=memory, remat=remat,
                        head="none")
    mask = batch.get("loss_mask")
    mask = (jnp.ones_like(tokens) if mask is None else mask)[:, 1:]
    loss = _ce_chunked(params, x[:, :-1], tokens[:, 1:], mask, cfg)
    return loss + aux, {"ce": loss, "aux": aux}


# ------------------------------------------------------------------ caches
def _layer_cache(cfg, spec, B, max_len, dtype):
    K, hd = cfg.n_kv_heads, cfg.hd
    if spec.mixer == "mamba":
        s = cfg.ssm
        return {"conv": jnp.zeros((B, s.d_conv - 1, cfg.d_inner), dtype),
                "ssm": jnp.zeros((B, cfg.d_inner, s.d_state), jnp.float32)}
    if spec.mixer == "cross_attn":
        M = cfg.memory_len()
        return {"ck": jnp.zeros((B, M, K, hd), dtype),
                "cv": jnp.zeros((B, M, K, hd), dtype)}
    if cfg.mla is not None:
        m = cfg.mla
        return {"ckv": jnp.zeros((B, max_len, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((B, max_len, m.qk_rope_head_dim), dtype),
                "pos": jnp.zeros((), jnp.int32)}
    # sliding-window layers only ever read the trailing `window` positions but
    # we keep the full ring for simplicity of positions bookkeeping.
    return {"k": jnp.zeros((B, max_len, K, hd), dtype),
            "v": jnp.zeros((B, max_len, K, hd), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def init_cache(cfg, B, max_len, dtype=None):
    """Decode cache pytree stacked on a leading superblock axis (scanned)."""
    dtype = dtype or cfg.cdtype
    one = {f"layer{i}": _layer_cache(cfg, spec, B, max_len, dtype)
           for i, spec in enumerate(cfg.block_pattern)}
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_superblocks,) + x.shape), one)


def prefill(params, tokens, cfg, max_len, *, memory=None):
    """Run the full prompt, materializing a decode-ready cache of size
    max_len. Returns (logits_last (B,V), cache)."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    # seed 'pos'=0 cache entries; forward in cached mode appends at pos.
    logits, new_cache, _ = forward(params, tokens, cfg, cache=cache,
                                   memory=memory, collect_cache=True,
                                   remat=False, head="last")
    return logits[:, -1], new_cache


def decode_step(params, token, cache, cfg, pos, *, memory=None):
    """token: (B, 1) int32; pos: scalar int32 (current write index).
    Returns (logits (B, V), new_cache)."""
    positions = jnp.asarray([pos], jnp.int32)
    logits, new_cache, _ = forward(params, token, cfg, positions=positions,
                                   cache=cache, memory=memory,
                                   collect_cache=True, remat=False)
    return logits[:, 0], new_cache
