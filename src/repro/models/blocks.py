"""Layer / superblock composition.

A *superblock* is one repetition of ``cfg.block_pattern``; the whole stack is
``lax.scan`` over ``n_superblocks`` stacked superblock params, so HLO size is
O(|pattern|) regardless of depth. Heterogeneous stacks (gemma2 local/global,
llama4 3-local+1-global, jamba 7-mamba+1-attn, vision cross-attn every 5th,
MoE every other layer) are all just patterns.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models.common import apply_norm, init_norm, split_keys

ATTN_MIXERS = ("attn", "attn_local", "attn_chunked", "attn_nope", "attn_bidir")


def _residual_scale(cfg):
    if cfg.scale_depth:
        return cfg.scale_depth / (cfg.n_layers ** 0.5)
    return 1.0


# ------------------------------------------------------------------ one layer
def init_layer(key, cfg, spec):
    ks = split_keys(key, 4)
    p = {"norm1": init_norm((cfg.d_model,), cfg.norm, cfg.pdtype)}
    if spec.ffn != "none":
        p["norm2"] = init_norm((cfg.d_model,), cfg.norm, cfg.pdtype)
    if cfg.name.startswith("gemma"):   # sandwich norms (pre+post)
        p["postnorm1"] = init_norm((cfg.d_model,), cfg.norm, cfg.pdtype)
        p["postnorm2"] = init_norm((cfg.d_model,), cfg.norm, cfg.pdtype)
    if spec.mixer == "mamba":
        p["mixer"] = mamba_mod.init_mamba(ks[0], cfg)
    elif cfg.mla is not None and spec.mixer != "cross_attn":
        p["mixer"] = attn_mod.init_mla(ks[0], cfg)
    else:
        p["mixer"] = attn_mod.init_attention(ks[0], cfg, spec)
    if spec.ffn == "mlp":
        p["ffn"] = moe_mod.init_mlp(ks[1], cfg)
    elif spec.ffn == "moe":
        p["ffn"] = moe_mod.init_moe(ks[1], cfg)
    return p


def apply_layer(p, x, cfg, spec, *, positions, cache=None, memory=None):
    """Returns (x, new_cache_entry, aux)."""
    rs = _residual_scale(cfg)
    unit = cfg.name.startswith("gemma")
    h = apply_norm(p["norm1"], x, cfg.norm, unit_offset=unit)

    if spec.mixer == "mamba":
        mix, new_entry = mamba_mod.apply_mamba(p["mixer"], h, cfg, cache=cache)
    elif cfg.mla is not None and spec.mixer != "cross_attn":
        mix, new_entry = attn_mod.apply_mla(p["mixer"], h, cfg, positions=positions, cache=cache)
    else:
        # attn_nope: RoPE suppression handled inside apply_attention via spec
        mix, new_entry = attn_mod.apply_attention(
            p["mixer"], h, cfg, spec, positions=positions, cache=cache,
            memory=memory)
    if "postnorm1" in p:
        mix = apply_norm(p["postnorm1"], mix, cfg.norm, unit_offset=unit)
    x = x + rs * mix

    aux = {}
    if spec.ffn != "none":
        h2 = apply_norm(p["norm2"], x, cfg.norm, unit_offset=unit)
        if spec.ffn == "moe":
            f, aux = moe_mod.apply_moe(p["ffn"], h2, cfg)
        else:
            f = moe_mod.apply_mlp(p["ffn"], h2, cfg)
        if "postnorm2" in p:
            f = apply_norm(p["postnorm2"], f, cfg.norm, unit_offset=unit)
        x = x + rs * f
    return x, new_entry, aux


# ------------------------------------------------------------------ superblock
def init_superblock(key, cfg):
    ks = split_keys(key, len(cfg.block_pattern))
    return {f"layer{i}": init_layer(ks[i], cfg, spec)
            for i, spec in enumerate(cfg.block_pattern)}


def apply_superblock(p, x, cfg, *, positions, cache=None, memory=None):
    """cache: None or dict {"layer{i}": entry}. Returns (x, new_cache, aux_sum)."""
    new_cache = {}
    aux_total = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(cfg.block_pattern):
        entry = cache[f"layer{i}"] if cache is not None else None
        x, new_entry, aux = apply_layer(
            p[f"layer{i}"], x, cfg, spec, positions=positions,
            cache=entry, memory=memory)
        new_cache[f"layer{i}"] = new_entry
        for v in aux.values():
            aux_total = aux_total + v
    return x, new_cache, aux_total


# ------------------------------------------------------------------ stack scan
def init_stack(key, cfg):
    ks = jax.random.split(key, cfg.n_superblocks)
    return jax.vmap(lambda k: init_superblock(k, cfg))(ks)


def apply_stack(params, x, cfg, *, positions, cache=None, memory=None,
                remat: bool = True, collect_cache: bool = False,
                remat_policy=None):
    """Scan over stacked superblocks. cache is a pytree stacked on axis 0.
    collect_cache=False drops per-layer KV outputs (train fwd must not
    materialize a cache). Returns (x, new_cache_or_None, aux_sum)."""

    def body(carry, scanned):
        h, aux = carry
        sb_params, sb_cache = scanned
        h, new_cache, a = apply_superblock(
            sb_params, h, cfg, positions=positions, cache=sb_cache,
            memory=memory)
        return (h, aux + a), (new_cache if collect_cache else None)

    from repro.sharding import act as act_sharding
    pol = act_sharding.current()
    mode = pol.remat if pol is not None else ("full" if remat else "none")
    if not remat:
        mode = "none"
    if mode == "none":
        fn = body
    else:
        policy = remat_policy or (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if mode == "dots" else jax.checkpoint_policies.nothing_saveable)
        fn = jax.checkpoint(body, policy=policy)
    (x, aux), new_cache = jax.lax.scan(
        fn, (x, jnp.zeros((), jnp.float32)), (params, cache))
    return x, new_cache, aux
