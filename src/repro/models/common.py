"""Shared model primitives: norms, RoPE, activations, initializers.

Pure-functional: every module is an ``init_*(key, ...) -> params`` plus an
``apply`` that takes the params dict. Norm math runs in fp32 regardless of
compute dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, dtype, stddev=0.02):
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------- norms
def init_norm(shape, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones(shape, dtype)}
    elif kind == "layernorm":
        return {"scale": jnp.ones(shape, dtype), "bias": jnp.zeros(shape, dtype)}
    raise ValueError(kind)


def apply_norm(params, x, kind: str, eps: float = 1e-6, unit_offset: bool = False):
    """unit_offset: gemma-style (1 + scale) parameterization."""
    xf = x.astype(jnp.float32)
    scale = params["scale"].astype(jnp.float32)
    if unit_offset:
        scale = scale + 1.0
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * scale
    elif kind == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + eps) * scale + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- rope
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) rotated pairwise-half style; positions: (S,) or (B,S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    # broadcast over head axis: (..., S, 1, hd/2)
    angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- activations
def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping; cap <= 0 disables."""
    if cap and cap > 0:
        return cap * jnp.tanh(x / cap)
    return x


# ---------------------------------------------------------------- dense
def init_dense(key, d_in, d_out, dtype, bias=False, stddev=0.02, name="w"):
    p = {name: normal_init(key, (d_in, d_out), dtype, stddev)}
    if bias:
        p[name + "_bias"] = jnp.zeros((d_out,), dtype)
    return p


def apply_dense(p, x, name="w", cdtype=None):
    w = p[name]
    if cdtype is not None:
        w = w.astype(cdtype)
        x = x.astype(cdtype)
    y = x @ w
    if name + "_bias" in p:
        y = y + p[name + "_bias"].astype(y.dtype)
    return y
