"""Mamba-1 selective-SSM block (falcon-mamba / jamba mixer).

Train/prefill path uses a *chunked associative scan*: the sequence is cut
into chunks of ``SCAN_CHUNK``; inside a chunk the linear recurrence
``h_t = a_t * h_{t-1} + b_t`` runs as ``lax.associative_scan`` (log-depth,
VPU-parallel on TPU), and the carry crosses chunk boundaries through a
``lax.scan``. This bounds live memory to O(B * chunk * d_inner * d_state)
instead of O(B * S * d_inner * d_state). The Pallas kernel in
``repro.kernels.mamba_scan`` implements the same chunking with explicit VMEM
tiles and is validated against ``selective_scan_ref``.

Decode path is the O(1) recurrent update on (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_dense, init_dense, normal_init, split_keys

SCAN_CHUNK = 256


def init_mamba(key, cfg):
    s = cfg.ssm
    ks = split_keys(key, 8)
    D, di, N, R = cfg.d_model, cfg.d_inner, s.d_state, cfg.dt_rank
    p = {}
    p.update(init_dense(ks[0], D, 2 * di, cfg.pdtype, name="mamba_in"))
    p["mamba_conv_w"] = normal_init(ks[1], (s.d_conv, di), cfg.pdtype, stddev=0.1)
    p["mamba_conv_b"] = jnp.zeros((di,), cfg.pdtype)
    p.update(init_dense(ks[2], di, R + 2 * N, cfg.pdtype, name="mamba_xproj"))
    p.update(init_dense(ks[3], R, di, cfg.pdtype, bias=True, name="mamba_dtproj"))
    # S4D-real init for A: A_log = log(1..N) rows broadcast over d_inner
    p["mamba_A_log"] = jnp.broadcast_to(
        jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)), (di, N)).astype(jnp.float32)
    p["mamba_D"] = jnp.ones((di,), jnp.float32)
    p.update(init_dense(ks[4], di, D, cfg.pdtype, name="mamba_out"))
    return p


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,di), w: (W,di). state: (B,W-1,di) or None.
    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else None
    return y + b, new_state


def _ssm_params(p, x_act, cfg):
    """x_act: (B,S,di) -> dt (B,S,di), B_ssm/C_ssm (B,S,N), A (di,N) fp32."""
    s = cfg.ssm
    N, R = s.d_state, cfg.dt_rank
    proj = apply_dense(p, x_act, "mamba_xproj", cfg.cdtype)
    dt_in, Bs, Cs = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        apply_dense(p, dt_in, "mamba_dtproj", cfg.cdtype).astype(jnp.float32))
    A = -jnp.exp(p["mamba_A_log"])
    return dt, Bs.astype(jnp.float32), Cs.astype(jnp.float32), A


def selective_scan(x, dt, A, Bs, Cs, D_skip, h0=None, chunk=SCAN_CHUNK):
    """The selective-scan core. x/dt: (B,S,di), Bs/Cs: (B,S,N), A: (di,N).
    Returns (y (B,S,di), h_last (B,di,N)). All fp32 math."""
    B, S, di = x.shape
    N = A.shape[1]
    x = x.astype(jnp.float32)
    nch = -(-S // chunk)
    pad = nch * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(B, nch, chunk, di).transpose(1, 0, 2, 3)
    dtc = dt.reshape(B, nch, chunk, di).transpose(1, 0, 2, 3)
    Bc = Bs.reshape(B, nch, chunk, N).transpose(1, 0, 2, 3)
    Cc = Cs.reshape(B, nch, chunk, N).transpose(1, 0, 2, 3)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_step(h, blk):
        xb, dtb, Bb, Cb = blk                               # (B,c,di) / (B,c,N)
        a = jnp.exp(dtb[..., None] * A)                     # (B,c,di,N)
        b = (dtb * xb)[..., None] * Bb[:, :, None, :]       # (B,c,di,N)
        a_cum, h_all = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_all = h_all + a_cum * h[:, None]                  # inject carry
        y = jnp.einsum("bcdn,bcn->bcd", h_all, Cb)
        return h_all[:, -1], y

    h = jnp.zeros((B, di, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h, yc = jax.lax.scan(chunk_step, h, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3).reshape(B, nch * chunk, di)[:, :S]
    return y + x[:, :S] * D_skip, h


def apply_mamba(p, x, cfg, *, cache=None):
    """x: (B,S,D). cache: None or {"conv": (B,W-1,di), "ssm": (B,di,N)}.
    Returns (out, new_cache_entry)."""
    s = cfg.ssm
    B, S, D = x.shape
    xz = apply_dense(p, x, "mamba_in", cfg.cdtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xconv, new_conv = _causal_conv(xin, p["mamba_conv_w"].astype(cfg.cdtype),
                                   p["mamba_conv_b"].astype(cfg.cdtype), conv_state)
    xact = jax.nn.silu(xconv)
    dt, Bs, Cs, A = _ssm_params(p, xact, cfg)

    if cache is not None and S == 1:
        # O(1) recurrent decode step
        h = cache["ssm"].astype(jnp.float32)                  # (B,di,N)
        a = jnp.exp(dt[:, 0, :, None] * A)                    # (B,di,N)
        b = (dt[:, 0] * xact[:, 0].astype(jnp.float32))[..., None] * Bs[:, 0, None, :]
        h = a * h + b
        y = jnp.einsum("bdn,bn->bd", h, Cs[:, 0])[:, None, :]
        y = y + xact.astype(jnp.float32) * p["mamba_D"]
        new_entry = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h}
    else:
        h0 = cache["ssm"] if cache is not None else None
        y, h = selective_scan(xact, dt, A, Bs, Cs, p["mamba_D"], h0=h0)
        if cache is not None:
            new_entry = {"conv": new_conv.astype(cache["conv"].dtype), "ssm": h}
        else:
            new_entry = {"conv": new_conv, "ssm": h}

    y = (y.astype(cfg.cdtype) * jax.nn.silu(z))
    return apply_dense(p, y, "mamba_out", cfg.cdtype), new_entry
