"""Attention: GQA with every assigned variant, plus MLA and cross-attention.

Two execution paths for the core softmax(QK^T)V:
  * dense  — full score matrix; used when the KV length is short or Sq == 1
             (decode: one query row against the cache is linear, not quadratic).
  * blockwise — ``lax.scan`` over KV chunks with an online-softmax carry
             (flash-attention recurrence in pure jnp); memory O(Sq * chunk)
             instead of O(Sq * Skv). Used for 32k prefill. The Pallas kernel in
             ``repro.kernels.flash_attention`` is the TPU-optimized twin of
             this path and is validated against it.

Mask variants: causal, sliding-window (gemma2 local), chunked-local (llama4),
bidirectional (whisper encoder / cross-attn).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import (act_fn, apply_dense, apply_norm, apply_rope,
                                 init_dense, init_norm, normal_init, softcap,
                                 split_keys)
from repro.sharding import act as act_sharding

DENSE_KV_THRESHOLD = 2048   # Skv above this and Sq > 1 -> blockwise path
KV_BLOCK = 1024


# ------------------------------------------------------------------ masks
def _mask_block(qpos, kpos, kind: str, window: int, chunk: int):
    """qpos: (Sq,), kpos: (Bk,) -> bool (Sq, Bk), True = attend."""
    q = qpos[:, None]
    k = kpos[None, :]
    if kind == "bidir":
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m = k <= q  # causal
    if kind == "window":
        m = m & (k > q - window)
    elif kind == "chunked":
        m = m & (q // chunk == k // chunk)
    return m


def _gqa_scores(q, k, scale, cap):
    """q: (B,Sq,K,G,hd) k: (B,Sk,K,hd) -> (B,K,G,Sq,Sk) fp32 math; with the
    attn_scores_bf16 knob, the MXU emits bf16 (halving the score tensor's
    HBM traffic — the dominant term of 4k training) and the softmax chain
    upcasts inside its fusion."""
    pol = act_sharding.current()
    bf16_scores = pol is not None and pol.attn_scores_bf16
    pet = jnp.bfloat16 if bf16_scores else jnp.float32
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=pet)
    return softcap(s.astype(jnp.float32) * scale, cap)


def _attn_dense(q, k, v, qpos, kpos, kind, window, chunk, cap, scale):
    s = _gqa_scores(q, k, scale, cap)
    mask = _mask_block(qpos, kpos, kind, window, chunk)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)


def _attn_blockwise(q, k, v, qpos, kpos, kind, window, chunk, cap, scale):
    """Online-softmax scan over KV blocks."""
    B, Sq, K, G, hd = q.shape
    Sk = k.shape[1]
    hdv = v.shape[-1]                       # may differ from q/k head dim (MLA)
    nb = -(-Sk // KV_BLOCK)
    pad = nb * KV_BLOCK - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(B, nb, KV_BLOCK, K, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, KV_BLOCK, K, hdv).transpose(1, 0, 2, 3, 4)
    kpb = kpos.reshape(nb, KV_BLOCK)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, kp = blk
        s = _gqa_scores(q, kblk, scale, cap)                 # (B,K,G,Sq,Bk)
        mask = _mask_block(qpos, kp, kind, window, chunk)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))          # may stay -inf
        m_safe = jnp.maximum(m_new, -1e30)                   # finite shift
        alpha = jnp.exp(m - m_safe)                          # -inf-case -> 0
        p = jnp.exp(s - m_safe[..., None])                   # masked -> 0
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vblk.dtype), vblk)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)      # (B,Sq,K,G,hd)


def mha(q, k, v, *, qpos, kpos, kind="causal", window=4096, chunk=8192,
        cap=0.0, scale=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,K,hd) with H % K == 0. Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = (hd ** -0.5) if scale is None else scale
    qg = q.reshape(B, Sq, K, G, hd)
    # Attention activation layout (policy knob `attn_mode`):
    #   seq   — queries shard (batch->dp, Sq->model); k/v dp-sharded,
    #           model-replicated (all-gather per layer, no head padding)
    #   heads — classic Megatron: KV-head axis -> model (pads when K<tp)
    #   none  — dp only (GSPMD free to choose the rest)
    # Decode (Sq==1) inherits the cache sharding instead (K/hd/Skv -> model).
    if Sq > 1:
        pol = act_sharding.current()
        mode = pol.attn_mode if pol is not None else "seq"
        if mode == "heads":
            qg = act_sharding.constrain(qg, {0: "dp", 2: "tp"})
            k = act_sharding.constrain(k, {0: "dp", 2: "tp"})
            v = act_sharding.constrain(v, {0: "dp", 2: "tp"})
        elif mode == "seq":
            qg = act_sharding.constrain(qg, {0: "dp", 1: "tp"})
            k = act_sharding.constrain(k, {0: "dp"})
            v = act_sharding.constrain(v, {0: "dp"})
        else:
            qg = act_sharding.constrain(qg, {0: "dp"})
    if Sq == 1 or k.shape[1] <= DENSE_KV_THRESHOLD:
        out = _attn_dense(qg, k, v, qpos, kpos, kind, window, chunk, cap, scale)
    else:
        blockwise = _attn_blockwise
        pol = act_sharding.current()
        if pol is not None and pol.attn_remat:
            # flash-backward semantics: recompute probabilities in the
            # backward pass instead of materializing per-block p/alpha
            blockwise = jax.checkpoint(
                _attn_blockwise, static_argnums=(5, 6, 7, 8, 9))
        out = blockwise(qg, k, v, qpos, kpos, kind, window, chunk, cap, scale)
    return out.reshape(B, Sq, H, v.shape[-1])   # v head dim may differ (MLA)


# ------------------------------------------------------------------ GQA module
def init_attention(key, cfg, spec):
    ks = split_keys(key, 8)
    H, K, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    p = {}
    p.update(init_dense(ks[0], D, H * hd, cfg.pdtype, bias=cfg.qkv_bias, name="wq"))
    kv_dim = D if spec.mixer != "cross_attn" else D
    p.update(init_dense(ks[1], kv_dim, K * hd, cfg.pdtype, bias=cfg.qkv_bias, name="wk"))
    p.update(init_dense(ks[2], kv_dim, K * hd, cfg.pdtype, bias=cfg.qkv_bias, name="wv"))
    p.update(init_dense(ks[3], H * hd, D, cfg.pdtype, name="wo"))
    if cfg.qk_norm:
        p["qnorm"] = init_norm((hd,), "rmsnorm", cfg.pdtype)
        p["knorm"] = init_norm((hd,), "rmsnorm", cfg.pdtype)
    if spec.mixer == "cross_attn" and cfg.family == "vlm":
        p["xgate"] = jnp.zeros((), cfg.pdtype)   # tanh-gated cross-attn (llama-vision)
    return p


def _project_kv(p, src, cfg):
    B, S = src.shape[:2]
    K, hd = cfg.n_kv_heads, cfg.hd
    k = apply_dense(p, src, "wk", cfg.cdtype).reshape(B, S, K, hd)
    v = apply_dense(p, src, "wv", cfg.cdtype).reshape(B, S, K, hd)
    if cfg.qk_norm:
        k = apply_norm(p["knorm"], k, "rmsnorm")
    return k, v


def apply_attention(p, x, cfg, spec, *, positions, cache=None, memory=None):
    """Self/cross attention.

    cache: None (train/prefill, returns new kv for caching) or dict with
      {"k": (B,Smax,K,hd), "v": ..., "pos": scalar index} for decode.
    memory: (B,M,D) for cross_attn.
    Returns (out, new_cache_entry).
    """
    B, Sq, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = apply_dense(p, x, "wq", cfg.cdtype).reshape(B, Sq, H, hd)
    if cfg.qk_norm:
        q = apply_norm(p["qnorm"], q, "rmsnorm")

    kind = {"attn": "causal", "attn_local": "window", "attn_chunked": "chunked",
            "attn_nope": "causal", "cross_attn": "bidir", "attn_bidir": "bidir"}[spec.mixer]
    use_rope = cfg.use_rope and spec.mixer in ("attn", "attn_local", "attn_chunked")

    if spec.mixer == "cross_attn":
        if memory is not None:                        # prefill/train: project now
            k, v = _project_kv(p, memory, cfg)
        else:                                         # decode: pre-projected in cache
            k, v = cache["ck"].astype(q.dtype), cache["cv"].astype(q.dtype)
        kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
        new_entry = ({"ck": k, "cv": v} if cache is not None else {})
        out = mha(q, k, v, qpos=positions, kpos=kpos, kind="bidir",
                  cap=cfg.attn_logit_softcap)
        if "xgate" in p:
            out = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(out.dtype) * out
    else:
        k, v = _project_kv(p, x, cfg)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        if cache is not None:                          # decode: append to cache
            idx = cache["pos"]
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
            new_entry = {"k": ck, "v": cv, "pos": idx + Sq}
            kpos = jnp.arange(ck.shape[1], dtype=jnp.int32)
            # positions beyond the write head must be masked out
            kpos = jnp.where(kpos < idx + Sq, kpos, jnp.iinfo(jnp.int32).max - 1)
            k, v = ck.astype(q.dtype), cv.astype(q.dtype)
        else:
            new_entry = {"k": k, "v": v}
            kpos = positions
        out = mha(q, k, v, qpos=positions, kpos=kpos, kind=kind,
                  window=cfg.window, chunk=cfg.chunk, cap=cfg.attn_logit_softcap)

    out = out.reshape(B, Sq, H * hd)
    return apply_dense(p, out, "wo", cfg.cdtype), new_entry


# ------------------------------------------------------------------ MLA
def init_mla(key, cfg):
    m = cfg.mla
    ks = split_keys(key, 8)
    D, H = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {}
    p.update(init_dense(ks[0], D, m.q_lora_rank, cfg.pdtype, name="wq_a"))
    p["q_a_norm"] = init_norm((m.q_lora_rank,), "rmsnorm", cfg.pdtype)
    p.update(init_dense(ks[1], m.q_lora_rank, H * qk_dim, cfg.pdtype, name="wq_b"))
    p.update(init_dense(ks[2], D, m.kv_lora_rank + m.qk_rope_head_dim, cfg.pdtype, name="wkv_a"))
    p["kv_a_norm"] = init_norm((m.kv_lora_rank,), "rmsnorm", cfg.pdtype)
    p.update(init_dense(ks[3], m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim),
                        cfg.pdtype, name="wkv_b"))
    p.update(init_dense(ks[4], H * m.v_head_dim, D, cfg.pdtype, name="wo"))
    return p


def apply_mla(p, x, cfg, *, positions, cache=None):
    """Multi-head latent attention. The *latent* (kv_lora + rope-k) is what we
    cache at decode — the paper-accurate memory saving of MLA."""
    m = cfg.mla
    B, Sq, D = x.shape
    H = cfg.n_heads
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    qa = apply_dense(p, x, "wq_a", cfg.cdtype)
    qa = apply_norm(p["q_a_norm"], qa, "rmsnorm")
    q = apply_dense(p, qa, "wq_b", cfg.cdtype).reshape(B, Sq, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = apply_dense(p, x, "wkv_a", cfg.cdtype)
    c_kv, k_rope = kv_a[..., :m.kv_lora_rank], kv_a[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    pol_ = act_sharding.current()
    if cache is not None:
        idx = cache["pos"]
        c_all = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], c_kv.astype(cache["ckv"].dtype), idx, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope.astype(cache["krope"].dtype), idx, axis=1)
        new_entry = {"ckv": c_all, "krope": kr_all, "pos": idx + Sq}
        if Sq == 1 and pol_ is not None and pol_.mla_absorb:
            return _mla_absorbed_decode(p, m, q_nope, q_rope, c_all, kr_all,
                                        idx, cfg), new_entry
        kpos = jnp.arange(c_all.shape[1], dtype=jnp.int32)
        kpos = jnp.where(kpos < idx + Sq, kpos, jnp.iinfo(jnp.int32).max - 1)
        c_kv, k_rope = c_all.astype(x.dtype), kr_all.astype(x.dtype)
    else:
        new_entry = {"ckv": c_kv, "krope": k_rope}
        kpos = positions

    c_kv = apply_norm(p["kv_a_norm"], c_kv, "rmsnorm")
    kv = apply_dense(p, c_kv, "wkv_b", cfg.cdtype)
    Sk = kv.shape[1]
    kv = kv.reshape(B, Sk, H, nope + vd)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, Sk, H, rope_d))], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)

    out = mha(qfull, k, v, qpos=positions, kpos=kpos, kind="causal",
              scale=(nope + rope_d) ** -0.5)
    out = out.reshape(B, Sq, H * vd)
    return apply_dense(p, out, "wo", cfg.cdtype), new_entry


def _mla_absorbed_decode(p, m, q_nope, q_rope, c_all, kr_all, idx, cfg):
    """MLA decode with absorbed projections (beyond-paper §Perf lever).

    The naive decode path re-expands the whole latent cache through wkv_b
    every step — O(S * r * H * (nope+v)) FLOPs per token per layer. Scoring
    against the LATENT instead (fold wkv_b's key half into the query, its
    value half into the output) costs O(S * H * r): ~30x fewer FLOPs at
    minicpm3 dims, and the (B,S,H,nope+v) expanded cache never exists.
    """
    mm = cfg.mla
    B, _, H, nope = q_nope.shape
    r = mm.kv_lora_rank
    vd = mm.v_head_dim
    wkv_b = p["wkv_b"].astype(cfg.cdtype).reshape(r, H, nope + vd)
    wk = wkv_b[..., :nope]                              # (r, H, nope)
    wv = wkv_b[..., nope:]                              # (r, H, vd)
    c_n = apply_norm(p["kv_a_norm"], c_all.astype(cfg.cdtype), "rmsnorm")
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))          # absorb k-half
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_n.astype(jnp.float32))
         + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                      kr_all.astype(jnp.float32)))
    s = s * ((nope + mm.qk_rope_head_dim) ** -0.5)
    S = c_all.shape[1]
    valid = jnp.arange(S, dtype=jnp.int32) < (idx + 1)
    s = jnp.where(valid[None, None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", pr, c_n.astype(jnp.float32))
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, wv.astype(jnp.float32))
    out = out.reshape(B, 1, H * vd).astype(cfg.cdtype)
    return apply_dense(p, out, "wo", cfg.cdtype)
