"""FFN layers: gated-MLP and GShard-style capacity-factor MoE.

The MoE dispatch avoids the classic (tokens, E, C) one-hot dispatch einsum
(memory hog at 1M tokens); instead tokens are *scattered* into an
(E, C, d_model) buffer using cumsum-derived positions-in-expert, expert
matmuls run as a single batched einsum (MXU-friendly), and results are
gathered back and combined with router weights. With experts sharded over the
'model' mesh axis this lowers to the standard expert-parallel all-to-all
pattern under GSPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import act_fn, apply_dense, init_dense, normal_init, split_keys
from repro.sharding import act as act_sharding


# ------------------------------------------------------------------ dense MLP
def init_mlp(key, cfg, d_ff=None):
    ks = split_keys(key, 3)
    D, F = cfg.d_model, (d_ff or cfg.d_ff)
    p = {}
    p.update(init_dense(ks[0], D, F, cfg.pdtype, name="w_gate"))
    p.update(init_dense(ks[1], D, F, cfg.pdtype, name="w_up"))
    p.update(init_dense(ks[2], F, D, cfg.pdtype, name="w_down"))
    return p


def apply_mlp(p, x, cfg):
    act = act_fn(cfg.act)
    g = act(apply_dense(p, x, "w_gate", cfg.cdtype))
    u = apply_dense(p, x, "w_up", cfg.cdtype)
    return apply_dense(p, g * u, "w_down", cfg.cdtype)


# ------------------------------------------------------------------ MoE
def init_moe(key, cfg):
    m = cfg.moe
    ks = split_keys(key, 5)
    D, F, E = cfg.d_model, cfg.moe_d_ff, m.n_experts
    p = {
        "router": normal_init(ks[0], (D, E), jnp.float32, stddev=0.02),
        "moe_wg": normal_init(ks[1], (E, D, F), cfg.pdtype),
        "moe_wu": normal_init(ks[2], (E, D, F), cfg.pdtype),
        "moe_wd": normal_init(ks[3], (E, F, D), cfg.pdtype),
    }
    if m.shared_expert_ff:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=m.shared_expert_ff)
    return p


def apply_moe(p, x, cfg):
    """x: (B, S, D). Returns (y, aux_metrics dict of scalar losses)."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.n_experts, m.top_k
    T = B * S
    xt = x.reshape(T, D)
    act = act_fn(cfg.act)

    logits = (xt.astype(jnp.float32) @ p["router"])            # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                       # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    pol = act_sharding.current()
    if (pol is not None and pol.moe_dispatch == "shard_map"
            and pol.mesh is not None and S > 1):
        y = _dispatch_shard_map(xt, eidx, gate, p, cfg, pol, act)
        aux = _aux_losses(m, logits, probs, eidx)
        y = y.reshape(B, S, D)
        if m.shared_expert_ff:
            y = y + apply_mlp(p["shared"], x, cfg)
        return y, aux
    local = (pol is not None and pol.moe_dispatch == "local"
             and S > 1 and (T * K) % 32 == 0)
    flat_e = eidx.reshape(-1)                                  # (T*K,) token-major
    xk = jnp.repeat(xt, K, axis=0).astype(cfg.cdtype)          # (T*K, D)
    xk = act_sharding.constrain(xk, {0: "dp"})

    if local:
        # ---- block-local dispatch (the §Perf collective fix) -------------
        # The global-cumsum scatter below writes dp-sharded tokens into
        # GLOBAL capacity slots of the (E, C, D) buffer; GSPMD cannot prove
        # the writes disjoint across data shards and lowers it as partial
        # buffers + a giant all-reduce (measured 2.4 TB/device on dbrx).
        # Giving every token block its OWN capacity slice makes the scatter
        # shard-local; the block axis stays dp-sharded, experts tp-sharded,
        # and cross-shard movement becomes the (cheap) buf resharding.
        NB = 32                                # >= dp x pod; divides T*K
        Tb = (T * K) // NB
        Cb = max(int(m.capacity_factor * Tb / E), 1) if S > 1 else Tb
        eb = flat_e.reshape(NB, Tb)
        onehot = jax.nn.one_hot(eb, E, dtype=jnp.int32)        # (NB, Tb, E)
        pos = jnp.cumsum(onehot, axis=1) - 1                   # block-local
        pos_t = jnp.take_along_axis(pos, eb[..., None], axis=2)[..., 0]
        xb = xk.reshape(NB, Tb, D)
        buf = jnp.zeros((NB, E, Cb, D), cfg.cdtype)
        bidx = jnp.broadcast_to(jnp.arange(NB)[:, None], (NB, Tb))
        buf = buf.at[bidx, eb, pos_t].set(xb, mode="drop")
        buf = act_sharding.constrain(buf, {0: "dp", 1: "tp"})
        g = jnp.einsum("becd,edf->becf", buf, p["moe_wg"].astype(cfg.cdtype))
        u = jnp.einsum("becd,edf->becf", buf, p["moe_wu"].astype(cfg.cdtype))
        h = act(g) * u
        yb = jnp.einsum("becf,efd->becd", h, p["moe_wd"].astype(cfg.cdtype))
        keep = (pos_t < Cb).astype(cfg.cdtype)
        ytk = (yb[bidx, eb, jnp.minimum(pos_t, Cb - 1)]
               * keep[..., None]).reshape(T * K, D)
    else:
        # ---- paper-era global dispatch (kept as the measured baseline) ---
        # decode (S==1): no-drop — a dropped token at serving time corrupts
        # the stream; capacity waste is negligible at T = B tokens.
        C = (T * K) if S == 1 else (int(m.capacity_factor * T * K / E) or 1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (T*K, E)
        pos = jnp.cumsum(onehot, axis=0) - 1                   # global slots
        pos_t = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        buf = jnp.zeros((E, C, D), cfg.cdtype)
        buf = buf.at[flat_e, pos_t].set(xk, mode="drop")
        buf = act_sharding.constrain(buf, {0: "tp"})
        g = jnp.einsum("ecd,edf->ecf", buf, p["moe_wg"].astype(cfg.cdtype))
        u = jnp.einsum("ecd,edf->ecf", buf, p["moe_wu"].astype(cfg.cdtype))
        h = act(g) * u
        yb = jnp.einsum("ecf,efd->ecd", h, p["moe_wd"].astype(cfg.cdtype))
        keep = (pos_t < C).astype(cfg.cdtype)                  # dropped -> 0
        ytk = yb[flat_e, jnp.minimum(pos_t, C - 1)] * keep[:, None]

    y = (ytk.reshape(T, K, D) * gate.astype(cfg.cdtype)[..., None]).sum(axis=1)

    aux = _aux_losses(m, logits, probs, eidx)
    y = y.reshape(B, S, D)
    if m.shared_expert_ff:
        y = y + apply_mlp(p["shared"], x, cfg)
    return y, aux


def _aux_losses(m, logits, probs, eidx):
    """GShard load-balance + router z-loss."""
    E = m.n_experts
    me = probs.mean(axis=0)                                    # (E,)
    frac = jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32).mean(axis=0)
    return {
        "moe_aux": m.aux_loss * E * jnp.sum(me * frac),
        "moe_z": m.router_z_loss * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }


def _dispatch_shard_map(xt, eidx, gate, p, cfg, pol, act):
    """Explicit per-shard MoE dispatch (the §Perf dbrx fix).

    Key observation: activations are dp-sharded but REPLICATED over the
    model axis, so each model shard can select its own experts' tokens
    locally — the dispatch needs NO communication at all. Each shard builds
    a (E_local, C_local, D) buffer from its replicated token slice, runs
    its experts, scatters results back to token positions (zeros for
    foreign tokens) and a single psum over the model axis combines the
    top-k partial outputs. Wire cost: one (T_local, D) all-reduce per
    layer — ~50x less than the partial-buffer all-reduce GSPMD emits for
    the global scatter (measured 2.4 TB/device on dbrx train_4k).
    """
    from jax.sharding import PartitionSpec as P
    try:                                  # jax >= 0.5 top-level export
        from jax import shard_map
    except ImportError:                   # 0.4.x keeps it in experimental
        from jax.experimental.shard_map import shard_map

    m = cfg.moe
    E, K = m.n_experts, m.top_k
    D = cfg.d_model
    El = E // pol.tp_size
    dp = pol.dp_axes if len(pol.dp_axes) > 1 else pol.dp_axes[0]
    tp = pol.tp_axis
    cdt = cfg.cdtype

    def body(xt_l, e_l, g_l, wg_l, wu_l, wd_l):
        Tl = xt_l.shape[0]
        Cl = max(int(m.capacity_factor * Tl * K / E), 1)
        e0 = jax.lax.axis_index(tp).astype(jnp.int32) * El
        fe = e_l.reshape(-1) - e0                     # local expert index
        mine = (fe >= 0) & (fe < El)
        fe_c = jnp.clip(fe, 0, El - 1)
        onehot = jax.nn.one_hot(fe_c, El, dtype=jnp.int32) * mine[:, None]
        pos = jnp.cumsum(onehot, axis=0) - 1
        pos_t = jnp.take_along_axis(pos, fe_c[:, None], 1)[:, 0]
        keep = mine & (pos_t < Cl)
        xk = jnp.repeat(xt_l, K, axis=0)
        buf = jnp.zeros((El, Cl, D), cdt)
        # out-of-range expert index => dropped by scatter mode="drop"
        tgt_e = jnp.where(keep, fe_c, El)
        buf = buf.at[tgt_e, jnp.where(keep, pos_t, 0)].set(xk, mode="drop")
        g = jnp.einsum("ecd,edf->ecf", buf, wg_l)
        u = jnp.einsum("ecd,edf->ecf", buf, wu_l)
        yb = jnp.einsum("ecf,efd->ecd", act(g) * u, wd_l)
        ytk = (yb[fe_c, jnp.minimum(pos_t, Cl - 1)]
               * keep[:, None].astype(cdt))
        y_l = (ytk.reshape(Tl, K, D)
               * g_l[..., None].astype(cdt)).sum(axis=1)
        return jax.lax.psum(y_l, tp)                  # combine top-k partials

    fn = shard_map(
        body, mesh=pol.mesh,
        in_specs=(P(dp, None), P(dp, None), P(dp, None),
                  P(tp, None, None), P(tp, None, None), P(tp, None, None)),
        out_specs=P(dp, None))
    return fn(xt.astype(cdt), eidx, gate,
              p["moe_wg"].astype(cdt), p["moe_wu"].astype(cdt),
              p["moe_wd"].astype(cdt))
