"""Runtime stage-result cache: LRU under a byte budget + O(1) invalidation.

Replaces the executor's original `db._stage_cache` dict, which dropped the
ENTIRE cache whenever the byte budget overflowed. Entries are keyed by the
stage's structural signature — which, since the signatures embed each base
table's version tag (see `Database.table_version`), makes invalidation O(1)
on update: bumping a table's version means every signature derived from the
old data simply never matches again. Stale entries are not scanned or
eagerly dropped (that would be O(entries)); they age out through normal LRU
eviction.

Only row SETS are cached. Latency, shuffle accounting and OOM checks are
always recomputed by the executor against the current run's cluster, so
results are bit-identical with the cache off — the invariant the
invalidation tests pin down.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Hashable, Optional


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0          # table-version bumps observed

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "hit_rate": round(self.hit_rate, 4)}

    def reset(self) -> None:
        self.hits = self.misses = self.evictions = self.invalidations = 0


class StageCache:
    """Byte-budgeted LRU over opaque stage entries.

    The budget is on BYTES, not entry count: materialized stages can hold
    millions of rows, so an entry cap alone would let the host grow without
    limit over a long serving run. Oversized entries (> max_entry_bytes)
    are never admitted — huge stages are not worth pinning.
    """

    def __init__(self, max_bytes: int = 256 * 1024 * 1024,
                 max_entry_bytes: int = 32 * 1024 * 1024):
        self.max_bytes = max_bytes
        self.max_entry_bytes = max_entry_bytes
        self.bytes = 0
        self.stats = CacheStats()
        self._entries: "OrderedDict[Hashable, tuple]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, sig) -> bool:
        return sig in self._entries

    def get(self, sig) -> Optional[object]:
        slot = self._entries.get(sig)
        if slot is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(sig)
        self.stats.hits += 1
        return slot[0]

    def put(self, sig, entry, nbytes: int) -> bool:
        """Insert (or refresh) `entry`; evicts LRU entries until it fits.
        Returns False when the entry is too large to ever cache."""
        if nbytes > self.max_entry_bytes or nbytes > self.max_bytes:
            return False
        old = self._entries.pop(sig, None)
        if old is not None:
            self.bytes -= old[1]
        while self._entries and self.bytes + nbytes > self.max_bytes:
            _, (_, evicted_bytes) = self._entries.popitem(last=False)
            self.bytes -= evicted_bytes
            self.stats.evictions += 1
        self._entries[sig] = (entry, nbytes)
        self.bytes += nbytes
        return True

    def note_invalidation(self, table: str) -> None:
        """Called (via `Database.bump_version`) when a table mutates. O(1):
        the version tag inside every signature does the actual fencing."""
        self.stats.invalidations += 1

    def clear(self) -> None:
        self._entries.clear()
        self.bytes = 0

    def reset_stats(self) -> None:
        """Zero the counters WITHOUT touching resident entries: the seam
        that makes consecutive `QueryService` runs sharing one cache
        independently measurable (counters otherwise accumulate across
        runs and the second run's hit rate is polluted by the first's)."""
        self.stats.reset()


class PartitionedStageCache(StageCache):
    """Per-tenant cache partitions under one roof.

    Each tenant evicts ONLY against its own byte budget, so a
    noisy-neighbor tenant flooding the cache can never push out a
    well-behaved tenant's entries — the isolation property
    `benchmarks/bench_qos.py` pins down. Invalidation stays O(1) and
    GLOBAL: signatures embed per-table version tags, so one delta fences
    every tenant's stale entries at once without scanning any partition
    (`note_invalidation` only bumps the shared counter).

    The object itself IS the default partition (a plain `StageCache`
    with `default_bytes`), so code that treats `db._stage_cache` as a
    flat cache — `sql.executor.Executor`'s auto-attach, direct
    `run_adaptive` calls — keeps working unchanged and lands in the
    default tenant's budget. The scheduler routes each lane to
    `partition(arrival.tenant)` explicitly. Only tenants with a
    CONFIGURED budget get their own partition; unknown tenant ids share
    the default one, so total cache memory stays bounded by
    sum(budgets) + default_bytes no matter how many distinct ids a
    stream carries.
    """

    def __init__(self, default_bytes: int = 256 * 1024 * 1024,
                 max_entry_bytes: int = 32 * 1024 * 1024,
                 budgets: Optional[Dict[str, int]] = None):
        budgets = dict(budgets or {})
        # the object IS the "default" partition, so an explicit budget for
        # the default tenant must size THIS cache, not a side partition
        super().__init__(budgets.get("default", default_bytes),
                         max_entry_bytes)
        self.default_bytes = default_bytes
        self._budgets = budgets
        self._parts: Dict[str, StageCache] = {}

    def partition(self, tenant: Optional[str]) -> StageCache:
        """The `StageCache` serving `tenant`: its own partition (created
        lazily under its configured budget) for budgeted tenants, the
        default partition for everyone else."""
        if tenant is None or tenant == "default":
            return self
        p = self._parts.get(tenant)
        if p is None:
            budget = self._budgets.get(tenant)
            if budget is None:         # unbudgeted ids share the default
                return self
            p = self._parts[tenant] = StageCache(budget,
                                                 self.max_entry_bytes)
        return p

    def partitions(self) -> Dict[str, StageCache]:
        out = {"default": self}
        out.update(self._parts)
        return out

    # note_invalidation: the base method already only bumps the shared
    # counter — O(1) across ALL partitions, the version tags inside every
    # signature do the fencing

    def clear(self) -> None:
        super().clear()
        for p in self._parts.values():
            p.clear()

    def reset_stats(self) -> None:
        super().reset_stats()
        for p in self._parts.values():
            p.reset_stats()

    def stats_by_tenant(self) -> Dict[str, Dict[str, float]]:
        return {t: p.stats.as_dict() for t, p in self.partitions().items()}

    def aggregate_stats(self) -> Dict[str, float]:
        """Counters summed over every partition (invalidations are shared,
        counted once), shaped like `CacheStats.as_dict()`."""
        agg = CacheStats(invalidations=self.stats.invalidations)
        for p in self.partitions().values():
            agg.hits += p.stats.hits
            agg.misses += p.stats.misses
            agg.evictions += p.stats.evictions
        return agg.as_dict()
