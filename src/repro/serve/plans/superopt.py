"""Background superoptimizer: offline cycles spent on hot templates.

Rides the scheduler's completion hook exactly like `learn.BackgroundLearner`
— "background" means interleaved with serving ticks on the virtual clock,
not a thread: every `opt_every`-th completion it sweeps the hottest
not-yet-optimized (template x band) keys — hottest first, as many as the
round's `sim_budget` covers — running a deterministic beam search over
action sequences per key, simulating each candidate prefix through a
private resumable `AdaptiveRun` on the LIVE database
(`reuse_stages=False`, so simulations never warm the serving cache or
touch the virtual clock; all search cost is measured host seconds).

It also rides the delta barrier: when a delta moves templates onto a new
table-version band (the same moment `PlanMemory` fences their entries),
the superoptimizer re-keys their heat onto the new band and runs an
immediate round at the barrier's apply time — so re-promotion lands
BEFORE the first post-drift arrival probes the memory, instead of
lagging a completion cadence behind while stale-fenced templates fall
back to the agent.

Heat comes from the PR-8 plan-provenance ledger (`obs.monitor.PlanLedger`)
when one is provided — the same (template, band) latency stats the RCA
engine reads — and from the superoptimizer's own completion counts
otherwise. The beam is seeded with the incumbent memory entry and with
any FENCED prior (a stale best sequence is a hint, not garbage), expands
only mask-legal non-noop actions in sorted order under a hard `sim_budget`
per round, and PROMOTES into `PlanMemory` only when the best candidate's
modeled cost strictly beats the re-simulated incumbent's (by `margin`)
— so a promotion can never regress what serving already replays, and
the whole search is a pure function of (database state, seed-free
deterministic expansion order): two runs of one stream promote
identical sequences (pinned by tests/test_planmem.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

from repro.core.actions import action_mask, apply_action
from repro.serve.plans.memory import band_for, template_signature
from repro.sql.executor import AdaptiveRun
from repro.sql.plans import syntactic_plan

__all__ = ["Superoptimizer", "SuperoptStats"]

_INF = float("inf")


@dataclasses.dataclass
class SuperoptStats:
    completions: int = 0
    rounds: int = 0                    # beam searches run
    sims: int = 0                      # candidate simulations executed
    promotions: int = 0
    skipped_no_gain: int = 0           # rounds whose best lost to incumbent
    host_seconds: float = 0.0

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["host_seconds"] = round(d["host_seconds"], 4)
        return d


class Superoptimizer:
    def __init__(self, memory, *, ledger=None, opt_every: int = 8,
                 beam_width: int = 3, max_steps: int = 3,
                 sim_budget: int = 24, per_template: int = 8,
                 margin: float = 0.0, stage: int = 3):
        """memory     the `PlanMemory` promotions land in
        ledger      optional `obs.monitor.PlanLedger`: template heat is
                    read from its observation counts (the RCA engine's
                    provenance stats) instead of local counters
        opt_every   run one search round per this many completions
        beam_width  surviving prefixes per depth
        max_steps   search depth (action-sequence length ceiling)
        sim_budget  hard cap on candidate simulations per round, shared
                    across however many templates the round sweeps
        per_template  per-key slice of the round budget — stops one
                    deep beam from starving the rest of the sweep
        margin      required modeled-cost improvement over the incumbent
        stage       curriculum stage for legality masks (3 = full space —
                    offline search is not subject to the live curriculum)
        """
        self.memory = memory
        self.ledger = ledger
        self.opt_every = max(int(opt_every), 1)
        self.beam_width = max(int(beam_width), 1)
        self.max_steps = max(int(max_steps), 1)
        self.sim_budget = max(int(sim_budget), 1)
        self.per_template = max(int(per_template), 1)
        self.margin = float(margin)
        self.stage = stage
        self.stats = SuperoptStats()
        self.promote_log: List[Dict] = []
        self._sched = None
        self._heat: Dict[Tuple[str, Tuple], int] = {}
        self._repr: Dict[Tuple[str, Tuple], object] = {}
        self._done: set = set()

    # ------------------------------------------------------------- plumbing
    def attach(self, scheduler) -> None:
        self._sched = scheduler
        scheduler.on_complete.append(self._on_complete)
        # after PlanMemory._on_delta in hook order (the memory attaches
        # first), so re-optimization sees entries already fenced and
        # `prior` hands back the stale sequence as a beam hint
        scheduler.on_delta.append(self._on_delta)

    def _on_complete(self, comp) -> None:
        t0 = time.perf_counter()
        self.stats.completions += 1
        key = (template_signature(comp.query),
               band_for(comp.query, self._sched.db.versions,
                        self.memory.band_width))
        self._heat[key] = self._heat.get(key, 0) + 1
        self._repr[key] = comp.query
        if self.stats.completions % self.opt_every == 0:
            self._round(comp.finish_t)
        self.stats.host_seconds += time.perf_counter() - t0

    def _on_delta(self, t_apply: float, delta) -> None:
        """Delta barrier: re-key heat for templates whose band the delta
        moved, then re-optimize immediately at the apply time — the
        promotions land before any post-delta admission probes the
        memory."""
        t0 = time.perf_counter()
        versions = self._sched.db.versions
        moved = 0
        for sig, band in sorted(self._heat):
            if all(t != delta.table for t, _ in band):
                continue
            q = self._repr[(sig, band)]
            nb = band_for(q, versions, self.memory.band_width)
            if nb == band:
                continue
            nk = (sig, nb)
            self._heat[nk] = self._heat.get(nk, 0) + \
                self._heat.pop((sig, band))
            self._repr[nk] = self._repr.pop((sig, band))
            self._done.discard(nk)
            moved += 1
        if moved:
            # every moved template gets its full slice: re-promotion at
            # the barrier is worth more than a cadence round's cap
            self._round(t_apply, budget=self.per_template * moved)
        self.stats.host_seconds += time.perf_counter() - t0

    # ------------------------------------------------------------ selection
    def _heat_of(self, key: Tuple[str, Tuple]) -> int:
        if self.ledger is None:
            return self._heat[key]
        q = self._repr[key]
        n = sum(st[0] for (_, tmpl, band), st in self.ledger._stats.items()
                if tmpl == q.name and band == key[1])
        return n if n else self._heat[key]

    def _pick(self) -> Optional[Tuple[str, Tuple]]:
        """Hottest (template, band) not yet optimized whose band still
        matches the live catalog (a delta since the last sighting moves
        the key off its band — let a future completion re-heat it)."""
        versions = self._sched.db.versions
        cands = []
        for key in self._heat:
            if key in self._done:
                continue
            if band_for(self._repr[key], versions,
                        self.memory.band_width) != key[1]:
                continue
            cands.append((-self._heat_of(key), key))
        if not cands:
            return None
        return min(cands)[1]

    # ----------------------------------------------------------- simulation
    def _simulate(self, q, prefix: Tuple[int, ...], space):
        """Run `q` with `prefix` applied at its first stage boundaries and
        noop thereafter; returns (modeled cost, mask after the prefix).
        reuse_stages=False keeps the sim off the serving stage cache — no
        serving-visible side effects, fully deterministic."""
        sched = self._sched
        run = AdaptiveRun(sched.db, q, syntactic_plan(q), sched.est,
                          sched.cluster, max_hook_steps=len(prefix) + 1,
                          reuse_stages=False)
        state = run.start()
        for a in prefix:
            if state is None:
                break
            new_plan, _, _ = apply_action(space, state, a)
            state = run.resume(new_plan)
        mask = None if state is None else \
            action_mask(space, state, stage=self.stage)
        while state is not None:
            state = run.resume(None)
        self.stats.sims += 1
        res = run.result
        return (_INF if res.failed else res.latency), mask

    # --------------------------------------------------------------- search
    def _round(self, now: float, budget: Optional[int] = None) -> None:
        """One cadence round: sweep hottest-first templates, spending the
        shared `sim_budget` across as many keys as it covers."""
        self.stats.rounds += 1
        budget = self.sim_budget if budget is None else budget
        while budget > 0:
            key = self._pick()
            if key is None:
                break
            self._done.add(key)
            budget -= self._search(key, now,
                                   min(budget, self.per_template))

    def _search(self, key: Tuple[str, Tuple], now: float,
                budget: int) -> int:
        """Beam-search one (template, band) under `budget` simulations;
        returns the simulations spent."""
        q = self._repr[key]
        space = self._sched.agent.space
        versions = self._sched.db.versions

        base_cost, base_mask = self._simulate(q, (), space)
        sims = 1
        prior = self.memory.prior(q, versions)
        inc_cost = base_cost
        inc_actions: Tuple[int, ...] = ()
        best = (base_cost, ())
        # beam: (cost, prefix, mask-after-prefix); expansion order is
        # fully sorted, so the search is deterministic
        beam = [(base_cost, (), base_mask)]
        if prior is not None and prior.actions and sims < budget:
            c, m = self._simulate(q, prior.actions, space)
            sims += 1
            if not prior.fenced:
                # re-simulated on the live db: the freshest incumbent cost
                inc_cost, inc_actions = c, prior.actions
            if c < best[0]:
                best = (c, prior.actions)
            beam.append((c, prior.actions, m))

        for _ in range(self.max_steps):
            cands = []
            for cost, prefix, mask in beam:
                if mask is None or len(prefix) >= self.max_steps:
                    continue
                legal = sorted(int(i) for i in range(space.d)
                               if mask[i] > 0 and i != space.noop_idx)
                for a in legal:
                    if sims >= budget:
                        break
                    c, m = self._simulate(q, prefix + (a,), space)
                    sims += 1
                    cands.append((c, prefix + (a,), m))
            if not cands:
                break
            cands.sort(key=lambda x: (x[0], x[1]))
            beam = cands[:self.beam_width]
            if beam[0][0] < best[0]:
                best = (beam[0][0], beam[0][1])

        cost, actions = best
        if cost < _INF and cost + self.margin < inc_cost \
                and actions != inc_actions:
            self.memory.install(
                q, versions, actions, cost=cost, source="superopt",
                decoded=tuple(str(space.decode(a)) for a in actions),
                t=now)
            self.stats.promotions += 1
            self.promote_log.append(
                {"query": q.name, "band": [list(b) for b in key[1]],
                 "actions": list(actions),
                 "cost": round(cost, 6),
                 "incumbent_cost": round(inc_cost, 6)
                 if inc_cost < _INF else None,
                 "sims": sims, "t": round(now, 4)})
        else:
            self.stats.skipped_no_gain += 1
        return sims

    def summary(self) -> Dict:
        return {**self.stats.as_dict(),
                "templates_seen": len(self._heat),
                "templates_done": len(self._done),
                "promote_log": list(self.promote_log)}
