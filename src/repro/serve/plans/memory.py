"""Persistent per-template plan memory: the serving fast path.

A `PlanEntry` is the best-known re-optimization action sequence for one
(template signature x table-version band): replaying its stored actions
through a resumable `AdaptiveRun` reproduces the winning plan WITHOUT a
single `act_batch` call — a memoized hit removes the query from every
policy batch, which is the host-side win `benchmarks/bench_planmem.py`
prices. The memory is fed from two sides:

  serve ingest   every non-memoized successful completion is a promotion
                 candidate: its action sequence replaces the incumbent
                 only when its observed latency strictly beats the
                 incumbent's best (so the memory monotonically improves
                 under serving traffic alone);
  superopt       `plans.superopt.Superoptimizer` runs deterministic beam
                 search over hot templates on idle completion cadence and
                 calls `install` when a candidate's modeled cost beats
                 the incumbent's.

Staleness is handled by FENCING, not deletion: a delta on a table (the
scheduler's `on_delta` hook) or a re-ANALYZE (the drift controller's
`note_stats_refresh`) fences every entry whose band touches that table.
A fenced entry never serves as a blind replay — `probe` skips it — but
survives as a HINT PRIOR: `prior` still returns it, so the
superoptimizer seeds its beam with the old sequence instead of starting
cold on the new data.

Keying. `template_signature` is purely structural (relations, filters,
join conditions — not the query name), so two arrivals of the same
template hit regardless of how the workload labels them; the band is the
`PlanLedger`-style `(table, version // band_width)` tuple, so a version
bump on any referenced table moves the key off the memoized band even
before the fence lands.

Persistence goes through `repro.checkpoint.Checkpointer`: entries are
JSON in the manifest's `extra` blob (Python's JSON float round-trip is
exact, so restored latency stats are bit-identical — pinned by
tests/test_planmem.py).

Determinism: every decision consumes virtual-clock state and exact
latency comparisons; with the memory attached but empty and ingest off,
completions are bit-identical to a memory-less scheduler (pinned by
tests/test_planmem.py and the property test in tests/test_invariants.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

__all__ = ["PlanEntry", "PlanMemory", "template_signature", "band_for"]


def template_signature(query) -> str:
    """Stable structural identity of a query template: relations (alias,
    table, filters) + join conditions, independent of the query's name."""
    rels = tuple((r.alias, r.table,
                  tuple((f.column, f.op, tuple(f.value))
                        for f in r.filters))
                 for r in query.relations)
    conds = tuple((c.left, c.lcol, c.right, c.rcol) for c in query.conds)
    return repr((rels, conds))


def band_for(query, versions: Dict[str, int],
             band_width: int = 1) -> Tuple:
    """The query's table-version band (PlanLedger convention): one
    (table, version // band_width) pair per referenced table, sorted."""
    tables = sorted({r.table for r in query.relations})
    w = max(int(band_width), 1)
    return tuple((t, int(versions.get(t, 0)) // w) for t in tables)


@dataclasses.dataclass
class PlanEntry:
    """Best-known action sequence for one (template, band), with streaming
    latency stats (Welford) over its memoized replays."""
    template: str
    band: Tuple
    actions: Tuple[int, ...]
    decoded: Tuple[str, ...] = ()
    source: str = "serve"              # "serve" | "superopt"
    created_t: float = 0.0
    modeled_cost: float = 0.0          # latency that earned the promotion
    fenced: bool = False
    fence_reason: str = ""
    n_hits: int = 0                    # memoized replays served
    n_obs: int = 0                     # latency observations folded in
    mean: float = 0.0
    m2: float = 0.0
    best: float = float("inf")         # best observed/modeled latency

    def observe(self, latency: float) -> None:
        self.n_obs += 1
        d = latency - self.mean
        self.mean += d / self.n_obs
        self.m2 += d * (latency - self.mean)
        self.best = min(self.best, latency)

    def as_dict(self) -> Dict:
        return {"template": self.template,
                "band": [[t, v] for t, v in self.band],
                "actions": list(self.actions),
                "decoded": list(self.decoded),
                "source": self.source, "created_t": self.created_t,
                "modeled_cost": self.modeled_cost,
                "fenced": self.fenced, "fence_reason": self.fence_reason,
                "n_hits": self.n_hits, "n_obs": self.n_obs,
                "mean": self.mean, "m2": self.m2, "best": self.best}

    @classmethod
    def from_dict(cls, d: Dict) -> "PlanEntry":
        return cls(template=d["template"],
                   band=tuple((t, int(v)) for t, v in d["band"]),
                   actions=tuple(int(a) for a in d["actions"]),
                   decoded=tuple(str(x) for x in d["decoded"]),
                   source=d["source"], created_t=d["created_t"],
                   modeled_cost=d["modeled_cost"], fenced=d["fenced"],
                   fence_reason=d["fence_reason"], n_hits=d["n_hits"],
                   n_obs=d["n_obs"], mean=d["mean"], m2=d["m2"],
                   best=d["best"])


class PlanMemory:
    """Memoized (template x band) -> action-sequence store.

    Attach to a scheduler (directly, via `LaneScheduler(plan_memory=...)`
    or `QueryService(plan_memory=...)`): the scheduler probes it at
    `_start` (a hit replays the stored actions with zero `act_batch`
    calls), its `on_complete` hook folds observed latencies back into
    entry stats and ingest-promotes better serving plans, and its
    `on_delta` hook fences entries whose tables were written."""

    def __init__(self, *, band_width: int = 1, ingest_serving: bool = True):
        self.band_width = max(int(band_width), 1)
        self.ingest_serving = ingest_serving
        self._entries: Dict[Tuple[str, Tuple], PlanEntry] = {}
        self._sched = None
        self.n_probes = 0
        self.n_hits = 0
        self.n_misses = 0
        self.n_fenced = 0
        self.n_promoted_serve = 0
        self.n_promoted_superopt = 0
        self.n_replay_failures = 0

    # ------------------------------------------------------------- plumbing
    def attach(self, scheduler) -> None:
        self._sched = scheduler
        scheduler.plan_memory = self
        scheduler.on_complete.append(self._on_complete)
        scheduler.on_delta.append(self._on_delta)

    def __len__(self) -> int:
        return len(self._entries)

    def key_for(self, query, versions: Dict[str, int]) -> Tuple[str, Tuple]:
        return (template_signature(query),
                band_for(query, versions, self.band_width))

    def entries(self) -> List[PlanEntry]:
        return [self._entries[k] for k in sorted(self._entries)]

    def _emit(self, kind: str, attrs: Dict, t: Optional[float]) -> None:
        obs = getattr(self._sched, "obs", None) if self._sched is not None \
            else None
        if obs is not None:
            obs.event(kind, attrs, t=t)

    # -------------------------------------------------------------- serving
    def probe(self, query, versions: Dict[str, int]) -> Optional[PlanEntry]:
        """The scheduler's fast-path lookup: the unfenced entry for this
        exact (template, band), or None. Counts a hit/miss."""
        self.n_probes += 1
        e = self._entries.get(self.key_for(query, versions))
        if e is None or e.fenced:
            self.n_misses += 1
            return None
        self.n_hits += 1
        e.n_hits += 1
        return e

    def would_hit(self, query, versions: Dict[str, int]) -> bool:
        """Count-free peek (the QoS ladder's memo-rung check)."""
        e = self._entries.get(self.key_for(query, versions))
        return e is not None and not e.fenced

    def prior(self, query, versions: Dict[str, int]) -> Optional[PlanEntry]:
        """Hint prior for the superoptimizer: the entry for this key even
        when fenced (a stale best sequence still seeds the beam)."""
        return self._entries.get(self.key_for(query, versions))

    # ------------------------------------------------------------ promotion
    def install(self, query, versions: Dict[str, int], actions, *,
                cost: float, source: str = "superopt", decoded=(),
                t: float = 0.0) -> PlanEntry:
        """Promote `actions` as the best-known sequence for this key.
        Replaces any incumbent unconditionally — callers are responsible
        for the beats-the-incumbent check (see `Superoptimizer`)."""
        sig, band = self.key_for(query, versions)
        e = PlanEntry(template=sig, band=band,
                      actions=tuple(int(a) for a in actions),
                      decoded=tuple(str(d) for d in decoded),
                      source=source, created_t=float(t),
                      modeled_cost=float(cost))
        e.observe(float(cost))
        self._entries[(sig, band)] = e
        if source == "superopt":
            self.n_promoted_superopt += 1
        else:
            self.n_promoted_serve += 1
        self._emit("plan_memory_promoted",
                   {"query": query.name, "source": source,
                    "n_actions": len(e.actions),
                    "cost": round(float(cost), 6)}, t=t)
        return e

    # -------------------------------------------------------------- fencing
    def _fence(self, e: PlanEntry, reason: str, t: float) -> None:
        if e.fenced:
            return
        e.fenced = True
        e.fence_reason = reason
        self.n_fenced += 1
        self._emit("plan_memory_fenced",
                   {"reason": reason, "source": e.source,
                    "band": [list(b) for b in e.band]}, t=t)

    def fence_table(self, table: str, reason: str, t: float = 0.0) -> int:
        """Fence every entry whose band references `table` (its stats
        moved: blind replay is no longer safe, hint-prior status remains).
        Returns how many entries were newly fenced."""
        before = self.n_fenced
        for e in self._entries.values():
            if not e.fenced and any(tbl == table for tbl, _ in e.band):
                self._fence(e, reason, t)
        return self.n_fenced - before

    def note_stats_refresh(self, tables, t: float = 0.0) -> int:
        """Drift-controller seam: a re-ANALYZE rewrote these tables'
        statistics under the entries' feet."""
        return sum(self.fence_table(tbl, "re-analyze", t)
                   for tbl in sorted(set(tables)))

    # ----------------------------------------------------------- scheduler
    def _on_delta(self, t_apply: float, delta) -> None:
        self.fence_table(delta.table, "delta", t_apply)

    def _on_complete(self, comp) -> None:
        versions = self._sched.db.versions
        sig, band = self.key_for(comp.query, versions)
        e = self._entries.get((sig, band))
        if getattr(comp, "memoized", False):
            if e is None:
                return                 # fenced/replaced mid-flight
            e.observe(comp.result.latency)
            if comp.result.failed:
                # a replayed plan that fails on its own band is stale
                # evidence the band key missed (e.g. in-band growth):
                # demote it to hint-prior immediately
                self.n_replay_failures += 1
                self._fence(e, f"replay-failed:{comp.failure_kind}",
                            comp.finish_t)
            return
        if not self.ingest_serving or comp.result.failed:
            return
        latency = comp.result.latency
        if e is None or e.fenced or latency < e.best:
            self.install(comp.query, versions, tuple(comp.traj.actions),
                         cost=latency, source="serve",
                         decoded=tuple(str(d) for d in comp.traj.decoded),
                         t=comp.finish_t)

    def note_latency(self, query, versions: Dict[str, int],
                     latency: float) -> bool:
        """Harvester feedback seam: fold an observed (non-memoized, e.g.
        agent-served) latency for this key into the entry's streaming
        stats WITHOUT letting it claim the `best` slot — only memoized
        replays and promotions move `best`, so serving noise widens the
        entry's variance instead of silently raising its bar."""
        e = self._entries.get(self.key_for(query, versions))
        if e is None:
            return False
        e.n_obs += 1
        d = latency - e.mean
        e.mean += d / e.n_obs
        e.m2 += d * (latency - e.mean)
        return True

    # ----------------------------------------------------------- accounting
    def stats(self) -> Dict:
        return {"entries": len(self._entries),
                "fenced_entries": sum(e.fenced
                                      for e in self._entries.values()),
                "probes": self.n_probes, "hits": self.n_hits,
                "misses": self.n_misses, "fenced": self.n_fenced,
                "promoted_serve": self.n_promoted_serve,
                "promoted_superopt": self.n_promoted_superopt,
                "replay_failures": self.n_replay_failures}

    def reset_stats(self, *, clear_entries: bool = False) -> None:
        self.n_probes = self.n_hits = self.n_misses = 0
        self.n_fenced = self.n_replay_failures = 0
        self.n_promoted_serve = self.n_promoted_superopt = 0
        if clear_entries:
            self._entries.clear()

    # ---------------------------------------------------------- persistence
    def to_dict(self) -> Dict:
        return {"band_width": self.band_width,
                "ingest_serving": self.ingest_serving,
                "entries": [self._entries[k].as_dict()
                            for k in sorted(self._entries)]}

    @classmethod
    def from_dict(cls, d: Dict) -> "PlanMemory":
        mem = cls(band_width=d["band_width"],
                  ingest_serving=d["ingest_serving"])
        for ed in d["entries"]:
            e = PlanEntry.from_dict(ed)
            mem._entries[(e.template, e.band)] = e
        return mem

    def save(self, directory, step: Optional[int] = None) -> int:
        """Persist entries through the manifest-fenced checkpointer (the
        same store policy versions go through). JSON float round-trip is
        exact, so save->load restores entries bit-identically."""
        from repro.checkpoint import Checkpointer
        ck = Checkpointer(directory)
        step = ck.next_step() if step is None else step
        assert ck.save(step, {}, extra={"plan_memory": self.to_dict()}), \
            f"step {step} already exists under {directory}"
        return step

    @classmethod
    def load(cls, directory, step: Optional[int] = None) -> "PlanMemory":
        from repro.checkpoint import Checkpointer
        _, _, extra = Checkpointer(directory).restore({}, step=step)
        return cls.from_dict(extra["plan_memory"])
