"""Plan memory + background superoptimization (the serving fast path).

`PlanMemory` memoizes the best-known re-optimization action sequence per
(template signature x table-version band); a scheduler probe hit replays
it through `AdaptiveRun` with zero `act_batch` calls. `Superoptimizer`
spends idle completion cadence on deterministic beam search over hot
templates, promoting candidates that beat the incumbent's modeled cost.
Drift fences entries (demotes them to hint priors) instead of deleting.
"""
from repro.serve.plans.memory import (PlanEntry, PlanMemory, band_for,
                                      template_signature)
from repro.serve.plans.superopt import Superoptimizer, SuperoptStats

__all__ = ["PlanEntry", "PlanMemory", "Superoptimizer", "SuperoptStats",
           "band_for", "template_signature"]
