"""Streaming workload driver: open-loop arrivals for the query service.

Generates a trace of `scheduler.Arrival`s — queries instantiated from the
JOB/ExtJOB/STACK templates (or any caller-supplied query source) with
exponential (Poisson-process) interarrival gaps, optionally interleaved
with delta batches every `delta_every` queries so the stream exercises the
cache's version-tag invalidation. Open-loop means arrival times never wait
on completions: when the service falls behind, queueing delay shows up in
the reported p50/p99 — the honest way to measure a serving system.

The trace is a plain list, so the same stream can be replayed against
different scheduling policies (async vs lockstep) for apples-to-apples
comparisons.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.serve.deltas import FACT_TABLES, DeltaBatch
from repro.serve.scheduler import Arrival
from repro.sql import workloads


def _query_source(source, seed: int) -> Iterator:
    if isinstance(source, str):                  # benchmark name
        return workloads.query_stream(source, seed=seed)
    if hasattr(source, "__next__"):              # already a generator
        return source

    def cycle(qs):
        i = 0
        while True:
            yield qs[i % len(qs)]
            i += 1
    return cycle(list(source))


def open_loop_stream(source: Union[str, Iterable], *, rate: float,
                     n_queries: int, seed: int = 0,
                     delta_every: int = 0,
                     delta_tables: Sequence[str] = (),
                     delta_rows: int = 0,
                     delete_frac: float = 0.0,
                     start: float = 0.0,
                     tenant: str = "default",
                     slo: Optional[float] = None) -> List[Arrival]:
    """Build an open-loop trace: `n_queries` arrivals at `rate` qps.

    source       benchmark name ("job"/"extjob"/"stack"), a query list
                 (cycled), or a query generator.
    delta_every  inject one DeltaBatch after every `delta_every` queries,
                 round-robin over `delta_tables` (defaults to the
                 benchmark's fact tables), each appending `delta_rows`
                 rows and deleting `delete_frac` of the table.
    tenant/slo   stamp every query arrival with this tenant id and (when
                 `slo` is set) an absolute deadline of arrival + slo.
    """
    rng = np.random.default_rng(seed)
    qs = _query_source(source, seed)
    if delta_every and not delta_tables:
        assert isinstance(source, str), "delta_tables required for " \
            "non-benchmark sources"
        delta_tables = FACT_TABLES[source]
    t = start
    out: List[Arrival] = []
    n_deltas = 0
    for i in range(n_queries):
        t += float(rng.exponential(1.0 / rate))
        out.append(Arrival(t, query=next(qs),
                           seed=int(rng.integers(2 ** 31)),
                           tenant=tenant,
                           deadline=None if slo is None else t + slo))
        if delta_every and (i + 1) % delta_every == 0:
            table = delta_tables[n_deltas % len(delta_tables)]
            out.append(Arrival(t, delta=DeltaBatch(
                table, n_append=delta_rows, delete_frac=delete_frac,
                seed=int(rng.integers(2 ** 31)))))
            n_deltas += 1
    return out


@dataclasses.dataclass
class TenantTraffic:
    """One tenant's open-loop traffic for `multi_tenant_stream`."""
    tenant: str
    source: Union[str, Iterable]      # as open_loop_stream's `source`
    rate: float                       # this tenant's own Poisson rate
    n_queries: int
    slo: Optional[float] = None       # relative deadline (virtual seconds)
    seed: int = 0
    start: float = 0.0


def multi_tenant_stream(traffics: Sequence[TenantTraffic], *,
                        deltas: Sequence[Arrival] = ()) -> List[Arrival]:
    """Merge per-tenant open-loop traces into one arrival stream.

    Each tenant's trace is generated independently (own source, rate,
    seed, SLO) and the union is stable-sorted by arrival time, so any
    tenant's sub-stream is identical whether it serves alone or in the
    mix — the property the isolation tests replay against. Optional
    `deltas` (Arrivals with `delta` set) are merged at their own times
    and act as write barriers for every tenant.
    """
    out: List[Arrival] = []
    for tr in traffics:
        out.extend(open_loop_stream(
            tr.source, rate=tr.rate, n_queries=tr.n_queries, seed=tr.seed,
            start=tr.start, tenant=tr.tenant, slo=tr.slo))
    out.extend(deltas)
    return sorted(out, key=lambda a: a.t)
