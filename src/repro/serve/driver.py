"""Streaming workload driver: open-loop arrivals for the query service.

Generates a trace of `scheduler.Arrival`s — queries instantiated from the
JOB/ExtJOB/STACK templates (or any caller-supplied query source) with
exponential (Poisson-process) interarrival gaps, optionally interleaved
with delta batches every `delta_every` queries so the stream exercises the
cache's version-tag invalidation. Open-loop means arrival times never wait
on completions: when the service falls behind, queueing delay shows up in
the reported p50/p99 — the honest way to measure a serving system.

The trace is a plain list, so the same stream can be replayed against
different scheduling policies (async vs lockstep) for apples-to-apples
comparisons.
"""
from __future__ import annotations

from typing import Iterable, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.serve.deltas import FACT_TABLES, DeltaBatch
from repro.serve.scheduler import Arrival
from repro.sql import workloads


def _query_source(source, seed: int) -> Iterator:
    if isinstance(source, str):                  # benchmark name
        return workloads.query_stream(source, seed=seed)
    if hasattr(source, "__next__"):              # already a generator
        return source

    def cycle(qs):
        i = 0
        while True:
            yield qs[i % len(qs)]
            i += 1
    return cycle(list(source))


def open_loop_stream(source: Union[str, Iterable], *, rate: float,
                     n_queries: int, seed: int = 0,
                     delta_every: int = 0,
                     delta_tables: Sequence[str] = (),
                     delta_rows: int = 0,
                     delete_frac: float = 0.0,
                     start: float = 0.0) -> List[Arrival]:
    """Build an open-loop trace: `n_queries` arrivals at `rate` qps.

    source       benchmark name ("job"/"extjob"/"stack"), a query list
                 (cycled), or a query generator.
    delta_every  inject one DeltaBatch after every `delta_every` queries,
                 round-robin over `delta_tables` (defaults to the
                 benchmark's fact tables), each appending `delta_rows`
                 rows and deleting `delete_frac` of the table.
    """
    rng = np.random.default_rng(seed)
    qs = _query_source(source, seed)
    if delta_every and not delta_tables:
        assert isinstance(source, str), "delta_tables required for " \
            "non-benchmark sources"
        delta_tables = FACT_TABLES[source]
    t = start
    out: List[Arrival] = []
    n_deltas = 0
    for i in range(n_queries):
        t += float(rng.exponential(1.0 / rate))
        out.append(Arrival(t, query=next(qs),
                           seed=int(rng.integers(2 ** 31))))
        if delta_every and (i + 1) % delta_every == 0:
            table = delta_tables[n_deltas % len(delta_tables)]
            out.append(Arrival(t, delta=DeltaBatch(
                table, n_append=delta_rows, delete_frac=delete_frac,
                seed=int(rng.integers(2 ** 31)))))
            n_deltas += 1
    return out
