"""Async lane scheduler: a fixed pool of lanes over resumable AdaptiveRuns.

Each lane holds at most one in-flight query, suspended at its next stage
boundary. One scheduler tick:

  1. admit — every idle lane is immediately refilled from the admission
     queue (FCFS by default; policy="edf" or an installed
     `serve.qos.AdmissionPolicy` picks earliest-deadline-first with
     fair-share tie-breaks, and may defer, degrade or reject — see
     qos/admission.py); a delta batch at the head of
     the queue is a write barrier: it applies once every previously
     admitted query has drained, and every query behind it sees the new
     table version;
  2. gather — whichever lanes are currently suspended at a stage boundary
     (optionally only those whose boundary falls inside a `window`-second
     batching horizon) are padded into ONE `agent.act_batch` call;
  3. scatter — each decided lane applies its action (Alg. 2) and resumes
     to its next boundary or to completion. A finished lane frees at its
     virtual completion time and is refilled on the next tick.

There is NO global barrier: lanes join and leave mid-flight, and a
straggler occupies exactly one lane while the others keep streaming.

Virtual time. Queries are timed on a deterministic virtual clock: a run
admitted at `admit_t` reaches its k-th boundary at `admit_t + elapsed_k`
(the executor's simulated seconds) and completes at `admit_t + latency`.
Policy decisions are free on this clock (their host cost is tracked
separately in `Trajectory.hook_seconds`), so per-query plans, latencies
and completion times are bit-reproducible for ANY lane count, batching
window or scheduling policy — serial execution (n_lanes=1) and the PR-1
lockstep engine (policy="lockstep", which admits barriered waves of
n_lanes queries) are special cases of the same loop, and
`core.vec_rollout.rollout_batch` is now a thin wrapper over this module.

Scheduling still changes what matters for serving: under "lockstep" a
wave's lanes all wait for the slowest member before the next wave is
admitted, while "async" refills each lane the moment it frees — which is
what `benchmarks/bench_serve.py` quantifies on straggler-heavy mixes.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.actions import action_mask, apply_action
from repro.core.encoding import MAX_NODES, encode_state
from repro.core.rollout import Trajectory, as_key, finalize_trajectory
from repro.serve.cache import PartitionedStageCache
from repro.serve.deltas import DeltaBatch, apply_delta
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel
from repro.sql.executor import AdaptiveRun, RunResult
from repro.sql.plans import syntactic_plan


@dataclasses.dataclass
class Arrival:
    """One item of the admission stream: a query (with its PRNG seed) or a
    delta batch, arriving at virtual time `t`. Multi-tenant streams tag
    each arrival with a `tenant` and (optionally) an absolute virtual
    `deadline`; `not_before` is written by admission deferrals (token-
    bucket rate limits) and floors the admit time."""
    t: float
    query: object = None
    seed: object = None
    delta: Optional[DeltaBatch] = None
    seq: int = -1                     # stream position, assigned by run()
    tenant: str = "default"
    deadline: Optional[float] = None  # absolute virtual-clock deadline
    not_before: float = 0.0           # admission deferral floor
    ticket: object = None             # recover.RetryTicket on re-admissions


@dataclasses.dataclass
class Completion:
    seq: int
    query: object
    seed: object
    arrival_t: float
    admit_t: float
    finish_t: float
    lane: int
    tick: int                         # scheduler tick at which it finished
    traj: Trajectory
    result: RunResult
    tenant: str = "default"
    deadline: Optional[float] = None
    hook_budget: Optional[int] = None  # None = agent default (full budget)
    degraded: bool = False             # admission shrank the hook budget
    predicted: Optional[float] = None  # admission-time latency estimate
    attempts: int = 1                  # lane admissions this query consumed
    recovered: bool = False            # succeeded after >=1 failed attempt
    hedged: bool = False               # resolved through a hedge race
    failure_kind: str = ""             # final failure kind, or (recovered)
    #                                    the kind of the FIRST failed attempt
    first_admit_t: float = 0.0         # attempt 1's admission (== admit_t
    #                                    for single-attempt queries)
    memoized: bool = False             # served by a plan-memory replay
    #                                    (zero act_batch participation)

    @property
    def latency(self) -> float:
        """Queueing + service time on the virtual clock."""
        return self.finish_t - self.arrival_t

    @property
    def service_t(self) -> float:
        return self.finish_t - self.admit_t

    @property
    def queue_wait(self) -> float:
        """Virtual time spent in the admission queue before a lane."""
        return self.admit_t - self.arrival_t

    @property
    def slo_miss(self) -> bool:
        return self.deadline is not None and self.finish_t > self.deadline


@dataclasses.dataclass
class Rejection:
    """A query turned away at admission (predicted-hopeless): it never
    occupies a lane and produces no Completion."""
    seq: int
    query: object
    seed: object
    tenant: str
    arrival_t: float
    reject_t: float                   # virtual time of the decision
    deadline: Optional[float]
    predicted: Optional[float]
    reason: str


@dataclasses.dataclass
class _Lane:
    idx: int
    free_at: float = 0.0
    run: Optional[AdaptiveRun] = None
    traj: Optional[Trajectory] = None
    state: object = None              # pending RuntimeState (None = no run)
    key: Optional[np.ndarray] = None  # uint32[2] PRNG chain head
    extra_plan: float = 0.0
    arrival: Optional[Arrival] = None
    admit_t: float = 0.0
    hook_budget: Optional[int] = None  # admission-assigned (None = full)
    degraded: bool = False
    predicted: Optional[float] = None
    memoized: bool = False             # running a plan-memory replay
    held: Optional[float] = None       # hedge-race stash: the run finished
    #   at this virtual time but its completion is deferred until the pair
    #   resolves — the lane stays occupied (blocks refill + write barriers)

    @property
    def next_event(self) -> float:
        """Virtual time of the pending stage boundary."""
        return self.admit_t + self.state.elapsed


class LaneScheduler:
    """Admits a stream of Arrivals into `n_lanes` lanes; one batched policy
    call per tick over every gathered suspension point.

    policy   "async"    — work-conserving: finished lanes refill at once.
             "edf"      — async, but idle lanes take the pending query
                          with the EARLIEST DEADLINE (ties: stream order)
                          from the segment ahead of the next write
                          barrier, instead of strict FCFS.
             "lockstep" — barriered waves of n_lanes (the PR-1 engine).
    window   batching horizon in virtual seconds: a tick decides only the
             lanes suspended within `window` of the earliest pending
             boundary (0.0 = event-ordered ticks, None = gather ALL
             suspended lanes). Affects host batching and tick ordering
             only — per-query plans, latencies and completion times are
             window-independent.
    admission  optional `serve.qos.AdmissionPolicy`: overrides the pick
             among pending queries (EDF + fair share), and may defer
             (rate limits), degrade (shrunken hook budget) or reject
             queries. None keeps the PR-2 FCFS path bit-identical.
    """

    def __init__(self, db, est: Estimator, agent, *, n_lanes: int = 4,
                 stage: int = 3, explore: bool = False,
                 cluster: Optional[ClusterModel] = None,
                 policy: str = "async", window: Optional[float] = None,
                 reuse_stages: bool = True, admission=None, recovery=None,
                 plan_memory=None):
        assert policy in ("async", "edf", "lockstep"), policy
        assert admission is None or policy != "lockstep", \
            "admission control needs per-lane refill (async/edf)"
        assert recovery is None or policy != "lockstep", \
            "the recovery plane needs per-lane refill (async/edf)"
        self.db, self.est, self.agent = db, est, agent
        self.n_lanes, self.stage, self.explore = n_lanes, stage, explore
        self.cluster = cluster if cluster is not None else ClusterModel()
        self.policy = policy
        self.window = None if policy == "lockstep" else window
        self.reuse_stages = reuse_stages
        if admission is None and policy == "edf":
            # lazy: scheduler must stay importable without pulling the
            # whole qos package at module load
            from repro.serve.qos.admission import EdfPolicy
            admission = EdfPolicy()
        self.admission = admission
        self.lanes = [_Lane(i) for i in range(n_lanes)]
        self.completions: List[Completion] = []
        self.rejections: List[Rejection] = []
        self.delta_log: List[tuple] = []
        # dynamically scheduled write-barrier tasks (e.g. the drift control
        # plane's incremental re-ANALYZE): each runs like a delta — only
        # once every previously admitted query has drained — so every
        # query decides all its stages against one consistent catalog
        self._barrier_tasks: deque = deque()
        # one (barrier END time, label) entry per task run: apply time
        # plus any virtual charge the task returned — the floor later
        # admissions see (deltas in delta_log log their APPLY time)
        self.task_log: List[tuple] = []
        self.ticks = 0
        self.decide_sizes: List[int] = []
        self._write_ts = 0.0          # virtual time of the last delta apply
        # opt-in completion hooks (the lifelong-learning loop's harvest
        # point): each callback sees every Completion in deterministic
        # completion-processing order (lane order within a tick — NOT
        # necessarily sorted by virtual finish time), between policy
        # batches — never mid-`act_batch` — so a callback may mutate
        # `self.agent`'s params or `self.stage` and the change
        # deterministically takes effect from the next tick on.
        self.on_complete: List[Callable[[Completion], None]] = []
        # opt-in delta hooks: fired right after a delta batch applies (the
        # lanes are drained — it IS the write barrier), with the apply
        # time. The drift controller reacts here so a stats refresh lands
        # at the same barrier with zero extra drain: a task scheduled from
        # this hook runs before any post-delta query is admitted.
        self.on_delta: List[Callable[[float, DeltaBatch], None]] = []
        if admission is not None:     # after on_complete: attach hooks it
            admission.attach(self)
        # failure-recovery control plane (serve.recover.RecoveryManager):
        # fault profiles at _start, retry/hedge interception at _finish,
        # hedge launches each tick. None = no recovery seams on any path.
        self.recovery = recovery
        # observability plane (serve.obs.Tracer.attach sets this): every
        # emit point below is guarded by `self.obs is not None`, so
        # obs=None keeps the run bit-identical to an untraced scheduler
        self.obs = None
        # plan memory (serve.plans.PlanMemory.attach sets this): probed at
        # `_start` ahead of the agent — a hit replays the stored action
        # sequence with ZERO act_batch participation. None (or an empty
        # memory with ingest off) keeps completions bit-identical.
        self.plan_memory = None
        self._pending: deque = deque()
        if recovery is not None:
            recovery.attach(self)
        if plan_memory is not None:
            plan_memory.attach(self)

    # ------------------------------------------------------------- driving
    def run(self, stream: Sequence[Arrival]) -> List[Completion]:
        """Drain `stream` (any order; stable-sorted by arrival time) and
        return one Completion per admitted query, in stream order
        (admission-rejected queries land in `self.rejections`)."""
        # work on COPIES: admission mutates per-run state on arrivals
        # (deferral not_before, stamped default deadlines), and the
        # caller's stream must replay identically through another
        # scheduler — e.g. the QoS-off bit-identity comparisons
        stream = [dataclasses.replace(a) for a in stream]
        for i, a in enumerate(stream):
            a.seq = i
        if self.admission is not None:
            self.admission.prepare(stream)
        pending = deque(sorted(stream, key=lambda a: a.t))
        self._pending = pending       # the recovery plane requeues retries
        while True:
            self._admit(pending)
            if self.recovery is not None:
                # speculative execution claims lanes the admission queue
                # left idle (so hedges never starve real arrivals)
                self.recovery.maybe_hedge()
            susp = [l for l in self.lanes if l.state is not None]
            if not susp:
                assert not pending, "admission stalled with idle lanes"
                break
            t_min = min(l.next_event for l in susp)
            horizon = np.inf if self.window is None else t_min + self.window
            self._decide([l for l in susp if l.next_event <= horizon])
            self.ticks += 1
            if self.obs is not None:
                self.obs.on_tick(t_min)
        return sorted(self.completions, key=lambda c: c.seq)

    def schedule_barrier(self, fn: Callable, label: str = "task") -> None:
        """Schedule `fn(scheduler, t_apply)` as a write-barrier task: it
        runs once every previously admitted query has drained, at the
        virtual time the last of them frees, and every query admitted
        afterwards starts at or after that time (plus any virtual-seconds
        charge the task returns). Callable from an `on_complete` hook
        (the drift controller's trigger point), so the task lands
        deterministically between policy batches."""
        self._barrier_tasks.append((label, fn))

    # ----------------------------------------------------------- admission
    def _admit(self, pending: deque) -> None:
        while True:
            if self._barrier_tasks:
                # same drain discipline as a delta arrival: the task may
                # mutate what in-flight queries depend on (catalog stats,
                # table data), so it waits for every admitted query
                if any(l.run is not None for l in self.lanes):
                    return
                label, fn = self._barrier_tasks.popleft()
                t_apply = max([self._write_ts] +
                              [l.free_at for l in self.lanes])
                # a task may return a virtual-seconds charge (e.g. a
                # re-ANALYZE run as a foreground maintenance window):
                # queries admitted after the barrier start no earlier
                # than its end
                dt = fn(self, t_apply)
                self._write_ts = t_apply + (dt or 0.0)
                self.task_log.append((self._write_ts, label))
                if self.obs is not None:
                    self.obs.event("barrier_task",
                                   {"label": label,
                                    "charge_s": round(dt or 0.0, 6)},
                                   t=self._write_ts)
                continue
            if not pending:
                return
            item = pending[0]
            if item.delta is not None:
                # write barrier: drain every previously admitted query
                if any(l.run is not None for l in self.lanes):
                    return
                pending.popleft()
                # _write_ts participates: a delta right behind a charged
                # barrier task must not rewind the write floor into the
                # window the task just charged
                t_apply = max([item.t, self._write_ts] +
                              [l.free_at for l in self.lanes])
                counts = apply_delta(self.db, item.delta)
                self._write_ts = t_apply
                self.delta_log.append((t_apply, item.delta, counts))
                for cb in self.on_delta:
                    cb(t_apply, item.delta)
                continue
            if self.policy == "lockstep":
                if any(l.run is not None for l in self.lanes):
                    return            # wave still in flight (barrier)
                base = max([self._write_ts] +
                           [l.free_at for l in self.lanes])
                k = 0
                while (pending and k < self.n_lanes
                       and pending[0].delta is None):
                    nxt = pending.popleft()
                    self._start(self.lanes[k], nxt, max(base, nxt.t))
                    k += 1
                continue
            idle = [l for l in self.lanes if l.run is None]
            if not idle:
                return
            # selection: FCFS takes the head; an admission policy (EDF is
            # `qos.EdfPolicy`, auto-installed for policy="edf") picks from
            # the whole segment ahead of the next write barrier (a delta
            # stays a barrier: nothing behind it is eligible)
            if self.admission is not None:
                seg = []
                for a in pending:
                    if a.delta is not None:
                        break
                    seg.append(a)
                now = max(min(l.free_at for l in idle), self._write_ts)
                item = self.admission.select(seg, now)
            lane = min(idle, key=lambda l: (max(item.t, l.free_at), l.idx))
            start_t = max(item.t, item.not_before, lane.free_at,
                          self._write_ts)
            # FCFS on the virtual clock: an in-flight lane frees no earlier
            # than its current stage boundary, so only take the idle lane
            # once no busy lane can possibly beat it — otherwise defer and
            # let the ticks sharpen the busy lanes' lower bounds. (This is
            # what keeps a 300s straggler's lane from swallowing queries
            # another lane would serve within a second.)
            # (a held lane — hedge stash — bounds at its stashed finish)
            busy_bound = min(
                (max(item.t, l.next_event if l.state is not None
                     else l.held) for l in self.lanes
                 if l.run is not None), default=np.inf)
            if start_t > busy_bound:
                return
            budget, degraded, predicted = None, False, None
            if self.admission is not None:
                dec = self.admission.admit(item, start_t)
                if dec.action == "reject":
                    pending.remove(item)
                    self.rejections.append(Rejection(
                        seq=item.seq, query=item.query, seed=item.seed,
                        tenant=item.tenant, arrival_t=item.t,
                        reject_t=start_t, deadline=item.deadline,
                        predicted=dec.predicted, reason=dec.reason))
                    if self.obs is not None:
                        self.obs.event("admission_reject",
                                       {"seq": item.seq,
                                        "tenant": item.tenant,
                                        "reason": dec.reason}, t=start_t)
                    continue
                if dec.action == "defer":
                    # rate-limited: floor the admit time and re-select —
                    # the raised not_before feeds straight into start_t,
                    # so one retry later this same arrival admits cleanly
                    item.not_before = max(item.not_before, dec.not_before)
                    continue
                budget, degraded = dec.hook_budget, dec.degraded
                predicted = dec.predicted
            pending.remove(item)
            self._start(lane, item, start_t, hook_budget=budget,
                        degraded=degraded, predicted=predicted)

    def _start(self, lane: _Lane, arrival: Arrival, admit_t: float, *,
               hook_budget: Optional[int] = None, degraded: bool = False,
               predicted: Optional[float] = None) -> None:
        q = arrival.query
        ticket = arrival.ticket
        if ticket is not None:
            # a retry/hedge re-admission: the ticket overrides the hook
            # budget (0 by default — retries run the resumed/replanned
            # remainder without competing for policy bandwidth)
            hook_budget = ticket.hook_budget
        # plan-memory fast path: probe AHEAD of the agent — on a hit the
        # run gets exactly len(actions) suspensions and `_replay` scripts
        # them, so this query never enters an act_batch. Retries keep
        # their ticket semantics (a memoized plan already failed once on
        # this band would be fenced by the completion hook anyway).
        memo = None
        if arrival.ticket is None and self.plan_memory is not None:
            memo = self.plan_memory.probe(q, self.db.versions)
            if self.obs is not None:
                self.obs.event(
                    "plan_memory_hit" if memo is not None
                    else "plan_memory_miss",
                    {"lane": lane.idx, "query": q.name}, t=admit_t)
        if memo is not None:
            steps = len(memo.actions)
        else:
            steps = self.agent.cfg.max_steps if hook_budget is None \
                else min(hook_budget, self.agent.cfg.max_steps)
        cache = None
        shared = getattr(self.db, "_stage_cache", None)
        if self.reuse_stages and isinstance(shared, PartitionedStageCache):
            cache = shared.partition(arrival.tenant)
        plan = syntactic_plan(q) if ticket is None or ticket.plan is None \
            else ticket.plan
        faults = None
        if self.recovery is not None:
            faults = self.recovery.run_faults(arrival)
            self.recovery.on_admit(arrival, admit_t)
        # the tracer opens an attempt record and returns the sink the
        # executor writes scan/join/failure notes into
        trace = None if self.obs is None \
            else self.obs.on_admit(lane, arrival, admit_t)
        run = AdaptiveRun(self.db, q, plan, self.est,
                          self.cluster, max_hook_steps=steps,
                          plan_time=0.0, reuse_stages=self.reuse_stages,
                          cache=cache, faults=faults,
                          init_mats=None if ticket is None else ticket.mats,
                          init_stages_done=0 if ticket is None
                          else ticket.stages_done, trace=trace)
        lane.run, lane.traj = run, Trajectory()
        lane.key = as_key(arrival.seed if arrival.seed is not None
                          else lane.idx)
        lane.extra_plan = 0.0
        lane.arrival, lane.admit_t = arrival, admit_t
        lane.hook_budget, lane.degraded = hook_budget, degraded
        lane.predicted = predicted
        lane.memoized = memo is not None
        lane.state = run.start()
        if memo is not None and lane.state is not None:
            self._replay(lane, memo)
        if lane.state is None:        # ran to completion with no boundary
            self._finish(lane)

    def _replay(self, lane: _Lane, entry) -> None:
        """Script a memoized entry's stored actions through the lane's run
        — the plan-memory fast path. Decisions are free on the virtual
        clock like agent decisions; the (tiny) apply cost is charged to
        hook_seconds. No states/masks are recorded (there was no policy
        evaluation — the harvester skips memoized completions), and a
        stored action that is illegal on the current state degrades to a
        noop inside `apply_action` (returns no plan change), so replays
        are robust to in-band drift."""
        space = self.agent.space
        for a in entry.actions:
            if lane.state is None:
                break
            t0 = time.perf_counter()
            a = int(a)
            new_plan, r, extra = apply_action(space, lane.state, a)
            lane.traj.actions.append(a)
            lane.traj.logps.append(0.0)    # scripted, not sampled
            lane.traj.rewards.append(r)
            lane.traj.decoded.append(space.decode(a))
            lane.extra_plan += extra
            if self.obs is not None:
                self.obs.on_decide(lane, lane.next_event,
                                   lane.traj.decoded[-1], r)
            lane.traj.hook_seconds += time.perf_counter() - t0
            lane.state = lane.run.resume(new_plan)
        while lane.state is not None:      # entry shorter than boundaries
            lane.state = lane.run.resume(None)

    # ------------------------------------------------------------ deciding
    def _decide(self, decide: List[_Lane]) -> None:
        """ONE batched policy call for `decide`, then resume each lane.
        The batch is padded to the fixed lane count so the jit cache sees
        one batch shape regardless of how many lanes are suspended."""
        agent, meta = self.agent, self.agent.meta
        B, F, d = self.n_lanes, self.agent.meta.feat_dim, self.agent.space.d
        self.decide_sizes.append(len(decide))
        feat = np.zeros((B, MAX_NODES, F), np.float32)
        left = np.zeros((B, MAX_NODES), np.int32)
        right = np.zeros((B, MAX_NODES), np.int32)
        mask = np.zeros((B, MAX_NODES), np.float32)
        amask = np.zeros((B, d), np.float32)
        amask[:, agent.space.noop_idx] = 1.0   # padded slots sample noop
        keys = np.zeros((B, 2), np.uint32)
        encs, prep_t = {}, {}
        for lane in decide:
            bi = lane.idx
            t0 = time.perf_counter()
            enc = encode_state(lane.state, meta)
            am = action_mask(agent.space, lane.state, stage=self.stage)
            feat[bi], left[bi], right[bi], mask[bi] = enc
            amask[bi] = am
            keys[bi] = lane.key
            encs[bi] = (enc, am)
            prep_t[bi] = time.perf_counter() - t0

        t0 = time.perf_counter()
        if hasattr(agent, "act_batch"):
            acts, logps, new_keys = agent.act_batch(
                feat, left, right, mask, amask, keys, explore=self.explore)
        else:                  # value-based agents (DQN) have no batch path
            acts = np.zeros(B, np.int32)
            logps = np.zeros(B, np.float32)
            new_keys = keys
            for lane in decide:
                a, lp = agent.act(encs[lane.idx][0], encs[lane.idx][1],
                                  explore=self.explore)
                acts[lane.idx], logps[lane.idx] = a, lp
        act_share = (time.perf_counter() - t0) / max(len(decide), 1)

        for lane in decide:
            bi = lane.idx
            t0 = time.perf_counter()
            enc, am = encs[bi]
            a = int(acts[bi])
            lane.key = new_keys[bi]
            new_plan, r, extra = apply_action(agent.space, lane.state, a)
            lane.traj.states.append(enc)
            lane.traj.actions.append(a)
            lane.traj.logps.append(float(logps[bi]))
            lane.traj.masks.append(am)
            lane.traj.rewards.append(r)
            lane.traj.decoded.append(agent.space.decode(a))
            lane.extra_plan += extra
            if self.obs is not None:
                # the decision lands at the suspended stage boundary
                self.obs.on_decide(lane, lane.next_event,
                                   lane.traj.decoded[-1], r)
            lane.traj.hook_seconds += (prep_t[bi] + act_share
                                       + time.perf_counter() - t0)
            lane.state = lane.run.resume(new_plan)
            if lane.state is None:
                self._finish(lane)

    # ----------------------------------------------------------- finishing
    def _finish(self, lane: _Lane) -> None:
        res = lane.run.result
        arr = lane.arrival
        traj = finalize_trajectory(lane.traj, res, arr.query, self.est,
                                   self.agent, self.cluster, self.agent.meta,
                                   lane.extra_plan)
        # virtual completion: simulated execution seconds only — the policy
        # decision cost is a host metric (traj.hook_seconds / C_plan), kept
        # off the clock so completion times are bit-reproducible
        finish_t = lane.admit_t + res.latency
        if self.obs is not None:
            # annotate BEFORE recovery interception: a requeued/stashed
            # attempt still records its own result and finish time
            self.obs.on_run_finish(lane, res, finish_t)
        if self.recovery is not None and \
                self.recovery.on_finish(lane, traj, res, finish_t):
            return                    # requeued as a retry, or hedge-stashed
        comp = self._build_comp(arr, traj, res, lane.admit_t, finish_t,
                                lane.idx, lane.hook_budget, lane.degraded,
                                lane.predicted, memoized=lane.memoized)
        self.completions.append(comp)
        self._release(lane, finish_t)
        for cb in self.on_complete:
            cb(comp)

    def _build_comp(self, arr: Arrival, traj: Trajectory, res: RunResult,
                    admit_t: float, finish_t: float, lane_idx: int,
                    hook_budget: Optional[int], degraded: bool,
                    predicted: Optional[float], hedged: bool = False,
                    first_admit: Optional[float] = None,
                    memoized: bool = False) -> Completion:
        ticket = arr.ticket
        attempts = 1 if ticket is None else ticket.attempt
        recovered = attempts > 1 and not res.failed
        if res.failed:
            kind = res.failure_kind
        else:
            kind = ticket.kinds[0] if recovered and ticket.kinds else ""
        if first_admit is None:
            first_admit = admit_t if ticket is None else ticket.first_admit_t
        return Completion(
            seq=arr.seq, query=arr.query, seed=arr.seed, arrival_t=arr.t,
            admit_t=admit_t, finish_t=finish_t, lane=lane_idx,
            tick=self.ticks, traj=traj, result=res, tenant=arr.tenant,
            deadline=arr.deadline, hook_budget=hook_budget,
            degraded=degraded, predicted=predicted, attempts=attempts,
            recovered=recovered, hedged=hedged, failure_kind=kind,
            first_admit_t=first_admit, memoized=memoized)

    def _emit(self, comp: Completion) -> None:
        """Record a recovery-plane completion (the manager has already
        released the lanes involved) and fire the completion hooks."""
        self.completions.append(comp)
        for cb in self.on_complete:
            cb(comp)

    def _release(self, lane: _Lane, free_at: float) -> None:
        if self.obs is not None:
            # archive the lane's attempt closed at free_at — for a
            # cancelled hedge loser that is the winner's finish time
            self.obs.on_release(lane, free_at)
        lane.free_at = free_at
        lane.run = lane.state = lane.arrival = None
        lane.hook_budget, lane.degraded, lane.predicted = None, False, None
        lane.memoized = False
        lane.held = None
