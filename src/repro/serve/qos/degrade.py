"""Degradation ladder: "how much re-optimization" as a runtime decision.

LQRS's thesis is that optimization decisions belong at execution time;
the ladder pushes that one level up: the amount of learned
re-optimization a query receives is itself decided at admission, from
the ratio of its predicted latency to its remaining deadline slack
(severity = predicted / slack).

  severity <= 1      on track: full hook budget (the agent's max_steps).
  1 < s <= mild      predicted to miss but close: shrink the hook budget
                     (fewer act_batch boundaries) — the query still gets
                     a cheap shot at re-optimization without consuming
                     full policy bandwidth it can't convert into an
                     on-time finish.
  mild < s <= hard   hopeless-ish: budget 0 — the syntactic plan + rule-
                     based AQE runs as-is (the PR-2 cold path), and the
                     saved act_batch slots go to queries still inside
                     their deadlines.
  s > hard           hopeless: reject at admission (when the admission
                     policy allows) — burning lane-seconds on a
                     guaranteed miss only pushes OTHER queries past
                     their deadlines.

A rung's budget may also be the sentinel `"memo"`: replay-a-memoized-plan
— cheaper than ANY hook budget (a plan-memory hit runs zero act_batch
calls AND reuses a proven plan, where budget 0 runs the raw syntactic
plan). A memo rung only matches when the admission policy reports the
query would hit the plan memory (`choose(..., memo_hit=True)`); without
a hit it is skipped and severity falls through to the next rung /
reject, so ladders stay well-defined with no memory attached.

`choose` is a pure function of virtual-clock quantities (predicted
seconds vs deadline slack) plus the deterministic memo-hit bit, so
ladder decisions are bit-reproducible; the admission policy owns the
counters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

MEMO = "memo"                         # rung sentinel: replay memoized plan


def _as_budget(b) -> Optional[int]:
    """Collapse a rung budget to the int the scheduler consumes: a memo
    rung admits with budget 0 (the memory probe, not the budget, scripts
    the replay — and on a fence race 0 is the cheapest safe fallback)."""
    return 0 if b == MEMO else b


@dataclasses.dataclass(frozen=True)
class Rung:
    max_severity: float               # rung applies while severity <= this
    hook_budget: object               # None = agent default (full budget),
    #                                   int = shrunken, "memo" = replay


@dataclasses.dataclass(frozen=True)
class DegradeDecision:
    action: str                       # "admit" | "reject"
    hook_budget: Optional[int]        # None = full budget
    severity: float
    degraded: bool                    # True when the budget was shrunk
    memo_only: bool = False           # admitted on the memo rung


class DegradationLadder:
    """Maps (predicted latency, deadline slack) -> hook budget / reject."""

    def __init__(self, rungs: Sequence[Tuple[float, Optional[int]]] = (
            (1.0, None), (2.0, 1), (4.0, 0)),
            reject_above: Optional[float] = 4.0):
        assert rungs, "ladder needs at least one rung"
        self.rungs = tuple(Rung(float(c), b) for c, b in rungs)
        assert all(a.max_severity < b.max_severity for a, b in
                   zip(self.rungs, self.rungs[1:])), \
            "rung ceilings must increase"
        assert reject_above is None or \
            reject_above >= self.rungs[-1].max_severity, \
            "reject_above below the last rung ceiling would never fire " \
            "(rungs match first)"
        self.reject_above = reject_above

    @classmethod
    def with_memo_rung(cls) -> "DegradationLadder":
        """The standard ladder plus a memoized-replay rung below reject:
        severity in (4, 8] queries that would previously be rejected (or
        caught at budget 0) instead replay their template's best-known
        plan when the memory has one — zero policy cost, proven plan."""
        return cls(rungs=((1.0, None), (2.0, 1), (4.0, 0), (8.0, MEMO)),
                   reject_above=8.0)

    def choose(self, predicted: float, slack: float,
               memo_hit: bool = False) -> DegradeDecision:
        """Pick the rung for a query predicted to take `predicted` virtual
        seconds with `slack` seconds left until its deadline. `memo_hit`
        gates memo rungs: True iff the plan memory would serve this query
        (the admission policy probes `PlanMemory.would_hit`)."""
        severity = predicted / slack if slack > 0.0 else float("inf")
        for rung in self.rungs:
            if rung.hook_budget == MEMO and not memo_hit:
                continue              # no memoized plan: fall through
            if severity <= rung.max_severity:
                if rung.hook_budget == MEMO:
                    return DegradeDecision("admit", 0, severity, True,
                                           memo_only=True)
                return DegradeDecision("admit", rung.hook_budget, severity,
                                       rung.hook_budget is not None)
        if self.reject_above is not None and severity > self.reject_above:
            return DegradeDecision("reject", None, severity, False)
        # no reject rung: the cheapest budget catches everything above
        return DegradeDecision("admit", _as_budget(self.rungs[-1].hook_budget),
                               severity, True)
