"""Admission control: whether, when, and how hard to re-optimize.

`AdmissionPolicy` is the scheduler's pluggable admission seam. The base
class reproduces the PR-2 behavior exactly — head-of-queue FCFS, every
query admitted with the full hook budget — so a scheduler with the base
policy (or none) is bit-identical to the plain async path.

`QoSAdmission` layers the SLO machinery on top, deciding per query:

  whether   a query whose predicted completion blows its deadline by
            more than the ladder's last rung is REJECTED at admission —
            it would only burn lane-seconds pushing other queries past
            their deadlines;
  when      a tenant over its token-bucket rate is DEFERRED to the
            earliest virtual time a token exists (never silently
            dropped: the wait lands in its queueing latency), and
            among eligible queries the pick is earliest-deadline-first,
            with weighted fair share (then stream order) breaking ties —
            so a flooding tenant cannot starve a light one;
  how hard  queries predicted to miss their SLO get a shrunken
            re-optimization hook budget from the `DegradationLadder`
            instead of the agent's full max_steps.

All three decisions compare virtual-clock quantities and consult
deterministic state (token buckets on the virtual clock, a jitted
predictor, seeded training), so the whole control plane is
bit-reproducible: same stream + same seeds => same admissions, same
degradations, same rejections.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

from repro.serve.qos.degrade import DegradationLadder, _as_budget
from repro.serve.qos.predictor import LatencyPredictor
from repro.serve.qos.tenancy import TenantRegistry


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    action: str                        # "admit" | "reject" | "defer"
    hook_budget: Optional[int] = None  # None = agent default
    not_before: float = 0.0            # defer: earliest admissible time
    predicted: Optional[float] = None  # predictor's latency estimate
    severity: float = 0.0              # predicted / deadline slack
    degraded: bool = False
    reason: str = ""


_ADMIT = AdmissionDecision("admit")


class AdmissionPolicy:
    """FCFS pass-through: the PR-2 semantics as an explicit policy object.
    Subclasses override `select` (which pending query gets the next idle
    lane) and `admit` (admit / defer / reject + hook budget)."""

    def attach(self, scheduler) -> None:
        self._sched = scheduler

    def prepare(self, stream) -> None:
        """Called once per `run()` with the full arrival list, before
        sorting — the hook where deadlines get stamped."""

    def select(self, candidates: List, now: float):
        """Pick the next arrival to place, from the pending queries ahead
        of the next write barrier (stream order preserved by default)."""
        return candidates[0]

    def admit(self, arrival, start_t: float) -> AdmissionDecision:
        return _ADMIT

    def on_complete(self, comp) -> None:
        """Completion feedback (fair-share charging, predictor refresh)."""


class EdfPolicy(AdmissionPolicy):
    """Deadline-only EDF selection (no registry, every query admitted):
    what `LaneScheduler` installs for policy="edf" when no admission
    policy is given, and the single home of the EDF pick."""

    def select(self, candidates: List, now: float):
        # EDF among queries already waiting at `now` — an idle lane never
        # holds for a future arrival (work conserving); with nothing
        # waiting, take the next to arrive
        waiting = [a for a in candidates if max(a.t, a.not_before) <= now]
        if waiting:
            return min(waiting, key=lambda a: (
                a.deadline if a.deadline is not None else math.inf,
                a.t, a.seq))
        return min(candidates, key=lambda a: (max(a.t, a.not_before),
                                              a.seq))


class QoSAdmission(AdmissionPolicy):
    """Learned admission control over a tenant registry: token-bucket
    deferral, EDF + weighted-fair-share selection, predictor-vs-deadline
    rejection, and ladder degradation."""

    def __init__(self, registry: Optional[TenantRegistry] = None, *,
                 predictor: Optional[LatencyPredictor] = None,
                 ladder: Optional[DegradationLadder] = None,
                 reject_hopeless: bool = True, plan_memory=None):
        """`plan_memory` (a `serve.plans.PlanMemory`) enables the ladder's
        memo rungs: at admission the policy peeks (`would_hit`, count-
        free) whether the query's template is memoized on the current
        version band and passes that bit to `ladder.choose` — so a
        severity band that would otherwise reject can admit on the
        replay-the-memoized-plan rung instead."""
        self.registry = registry if registry is not None else TenantRegistry()
        self.predictor = predictor
        # a predictor without a ladder would reject everything it flags or
        # nothing at all — default to the standard 3-rung ladder
        self.ladder = ladder if ladder is not None else DegradationLadder()
        self.reject_hopeless = reject_hopeless
        self.plan_memory = plan_memory
        self.n_admitted = 0
        self.n_degraded = 0
        self.n_rejected = 0
        self.n_deferred = 0            # defer events (retries count once each)
        self.n_memo_admits = 0         # admits earned by a memo rung

    # ------------------------------------------------------------ plumbing
    def attach(self, scheduler) -> None:
        super().attach(scheduler)
        scheduler.on_complete.append(self.on_complete)

    def prepare(self, stream) -> None:
        # a fresh run restarts the virtual clock at its first arrival:
        # token buckets / fair-share must not carry the PREVIOUS stream's
        # end time, or every rate-limited tenant would defer to it
        self.registry.reset_clock()
        for a in stream:
            if a.delta is None:
                a.deadline = self.registry.deadline_for(a.tenant, a.t,
                                                        a.deadline)

    def on_complete(self, comp) -> None:
        self.registry.charge(comp.tenant, comp.service_t)

    # ------------------------------------------------------------ deciding
    def _ready_at(self, a, now: float) -> float:
        t = max(a.t, a.not_before, now)
        return max(t, self.registry.earliest_admit(a.tenant, t))

    def select(self, candidates: List, now: float):
        """EDF within the eligible set: queries already admissible at `now`
        sort by (deadline, fair share, stream order); rate-limited ones
        sort after, by when they become admissible — so a token-starved
        head never blocks another tenant's lane."""
        def key(a):
            ready = self._ready_at(a, now)
            waiting = ready > now
            dl = a.deadline if a.deadline is not None else math.inf
            return (waiting, ready if waiting else 0.0, dl,
                    self.registry.fair_key(a.tenant), a.seq)
        return min(candidates, key=key)

    def admit(self, a, start_t: float) -> AdmissionDecision:
        ready = self._ready_at(a, start_t)
        if ready > start_t + 1e-12:
            self.n_deferred += 1
            return AdmissionDecision("defer", not_before=ready,
                                     reason="rate-limited")
        predicted = None
        if self.predictor is not None and a.deadline is not None:
            predicted = self.predictor.predict_query(a.query)
            slack = a.deadline - start_t
            memo_hit = False
            if self.plan_memory is not None:
                memo_hit = self.plan_memory.would_hit(
                    a.query, self._sched.db.versions)
            d = self.ladder.choose(predicted, slack, memo_hit=memo_hit)
            if d.memo_only:
                self.n_memo_admits += 1
            if d.action == "reject" and self.reject_hopeless:
                self.n_rejected += 1
                return AdmissionDecision(
                    "reject", predicted=predicted, severity=d.severity,
                    reason=f"predicted {predicted:.1f}s vs "
                           f"{slack:.1f}s slack")
            budget = d.hook_budget if d.action == "admit" \
                else _as_budget(self.ladder.rungs[-1].hook_budget)
            self.registry.acquire(a.tenant, start_t)
            self.n_admitted += 1
            self.n_degraded += d.degraded or d.action == "reject"
            return AdmissionDecision(
                "admit", hook_budget=budget, predicted=predicted,
                severity=d.severity,
                degraded=d.degraded or d.action == "reject")
        self.registry.acquire(a.tenant, start_t)
        self.n_admitted += 1
        return AdmissionDecision("admit", predicted=predicted)

    def stats(self):
        return {"admitted": self.n_admitted, "degraded": self.n_degraded,
                "rejected": self.n_rejected, "deferred": self.n_deferred,
                "memo_admits": self.n_memo_admits,
                "tenants": self.registry.stats(),
                "predictor": None if self.predictor is None
                else getattr(self.predictor, "stats", dict)()}
