"""Admission-time latency predictor: a small jitted value net over the
encoded syntactic plan.

Neo showed a learned value network predicts plan latency well enough to
steer search; here the same idea steers ADMISSION: before a query touches
a lane, its syntactic plan is encoded exactly like a pre-execution hook
state (all cardinalities unobserved) and a critic-shaped encoder+head
predicts its latency, which the admission policy compares against the
query's deadline.

Two ties to the rest of the system keep this honest:

  * Warm start. The net is critic-shaped on purpose: the serving agent's
    critic already approximates v(s0) ~= -sqrt(T_execute) (Alg. 1's
    return), so `LatencyPredictor(meta, agent=agent)` copies the critic's
    params and is calibrated from the first request — the head's output
    is read as -sqrt(latency), and training keeps that convention.
  * Training data is harvested serving traffic: `fit_from_replay` draws
    prioritized samples from the PR-3 `learn.ReplayBuffer` (each
    `Experience.traj.states[0]` IS the encoded pre-exec state, and
    failed runs carry the timeout as their latency), so the predictor
    tracks drift for free alongside the background learner.

Everything is deterministic: fixed-shape jitted batches, a caller-seeded
rng for sampling, and per-query prediction memoized by (fit generation,
query name) — the syntactic encoding of a query never changes.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nets
from repro.core.encoding import MAX_NODES, WorkloadMeta, encode_state
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sql.executor import RuntimeState
from repro.sql.plans import syntactic_plan


def encode_query(query, meta: WorkloadMeta):
    """Encode `query`'s syntactic plan exactly like the pre-execution hook
    state (no materialized stages, every cardinality unobserved)."""
    state = RuntimeState(query, syntactic_plan(query), {}, None, 0, 0.0, 0,
                         None)
    return encode_state(state, meta)


class LatencyPredictor:
    """Critic-shaped latency regressor: head output o(s) is trained toward
    -sqrt(latency); `predict` returns max(0, -o)^2 seconds."""

    def __init__(self, meta: WorkloadMeta, *, agent=None, net: str = "treecnn",
                 hidden: int = 96, head_hidden: int = 96, seed: int = 0,
                 lr: float = 1e-3):
        self.meta = meta
        if agent is not None:
            from repro.checkpoint import copy_tree
            net, hidden = agent.cfg.net, agent.cfg.hidden
            self.params = copy_tree(agent.critic)     # warm start, no alias
        else:
            k = jax.random.split(jax.random.PRNGKey(seed), 2)
            self.params = {
                "enc": nets.init_encoder(k[0], net, meta.feat_dim, hidden,
                                         MAX_NODES),
                "head": nets.init_mlp_head(k[1], hidden, head_hidden, 1)}
        self.net = net
        self.opt = adamw_init(self.params)
        self._cfg = AdamWConfig(lr=lr, weight_decay=0.0, grad_clip=5.0)
        self.n_fit_steps = 0
        self.generation = 0               # bumped per fit(); fences the memo
        self.n_refits = 0                 # drift-triggered refresh count
        self.refit_log: List[Dict] = []   # one record per refit_on_drift
        # keyed by the (frozen, value-hashed) Query itself — names are not
        # unique across tenants, but structurally distinct queries must
        # never share a prediction
        self._enc_memo: Dict[object, tuple] = {}
        self._pred_memo: Dict[object, float] = {}

        def forward(params, feat, left, right, mask):
            h = nets.apply_encoder(params["enc"], self.net, feat, left,
                                   right, mask)
            return nets.apply_mlp_head(params["head"], h)[:, 0]

        def loss_fn(params, batch):
            o = forward(params, batch["feat"], batch["left"], batch["right"],
                        batch["mask"])
            err = (o - batch["target"]) ** 2
            return jnp.sum(err * batch["valid"]) / \
                jnp.maximum(batch["valid"].sum(), 1.0)

        def update(params, opt, batch):
            l, g = jax.value_and_grad(loss_fn)(params, batch)
            params, opt, _ = adamw_update(params, g, opt, self._cfg)
            return params, opt, l

        self._forward = jax.jit(forward)
        self._update = jax.jit(update, donate_argnums=(0, 1))

    # ------------------------------------------------------------ predict
    def predict_enc(self, enc) -> float:
        """Predicted latency (virtual seconds) for one encoded state."""
        feat, left, right, mask = enc
        o = float(self._forward(self.params, feat[None], left[None],
                                right[None], mask[None])[0])
        return max(0.0, -o) ** 2

    def predict_query(self, query) -> float:
        """Predicted latency for `query`'s syntactic plan (memoized — the
        encoding is a pure function of the query, and predictions only
        change when `fit` bumps the generation)."""
        hit = self._pred_memo.get(query)
        if hit is not None:
            return hit
        enc = self._enc_memo.get(query)
        if enc is None:
            enc = self._enc_memo[query] = encode_query(query, self.meta)
        p = self.predict_enc(enc)
        self._pred_memo[query] = p
        return p

    # ---------------------------------------------------------------- fit
    def fit(self, encs: List[tuple], latencies: List[float], *,
            batch_size: int = 16, epochs: int = 1) -> float:
        """Regress o(enc) -> -sqrt(latency) with jitted AdamW steps over
        fixed-shape padded batches. Returns the last batch loss."""
        assert len(encs) == len(latencies) and encs
        F = self.meta.feat_dim
        n = len(encs)
        last = 0.0
        for _ in range(epochs):
            for s in range(0, n, batch_size):
                chunk = list(range(s, min(s + batch_size, n)))
                feat = np.zeros((batch_size, MAX_NODES, F), np.float32)
                left = np.zeros((batch_size, MAX_NODES), np.int32)
                right = np.zeros((batch_size, MAX_NODES), np.int32)
                mask = np.zeros((batch_size, MAX_NODES), np.float32)
                target = np.zeros(batch_size, np.float32)
                valid = np.zeros(batch_size, np.float32)
                for bi, i in enumerate(chunk):
                    feat[bi], left[bi], right[bi], mask[bi] = encs[i]
                    target[bi] = -np.sqrt(max(latencies[i], 0.0))
                    valid[bi] = 1.0
                batch = {"feat": jnp.asarray(feat), "left": jnp.asarray(left),
                         "right": jnp.asarray(right),
                         "mask": jnp.asarray(mask),
                         "target": jnp.asarray(target),
                         "valid": jnp.asarray(valid)}
                self.params, self.opt, l = self._update(self.params,
                                                        self.opt, batch)
                self.n_fit_steps += 1
                last = float(l)
        self.generation += 1
        self._pred_memo.clear()
        return last

    def fit_from_replay(self, replay, rng: np.random.Generator, *,
                        n_samples: int = 64, batch_size: int = 16,
                        epochs: int = 2,
                        current_versions: Optional[Dict] = None) -> float:
        """Train from harvested serving experience (PR-3 replay buffer).
        Uses each trajectory's FIRST state — the pre-exec encoding the
        predictor sees at admission — against the realized latency (the
        timeout for failed runs, matching how the scheduler charges them).
        Prioritized sampling keeps the regression pointed at the fresh,
        high-regret traffic. Deterministic given `rng`."""
        exps = [e for e in replay.sample(min(n_samples, len(replay)), rng,
                                         current_versions)
                if e.traj.states]
        if not exps:
            return 0.0
        return self.fit([e.traj.states[0] for e in exps],
                        [e.latency for e in exps],
                        batch_size=batch_size, epochs=epochs)

    def refit_on_drift(self, replay, rng: np.random.Generator, *,
                       current_versions: Optional[Dict] = None,
                       n_samples: int = 64, batch_size: int = 16,
                       epochs: int = 2, trigger: str = "") -> float:
        """Online refresh, replacing one-shot calibration: retrain from the
        LIVE replay buffer when the drift detector says predictions have
        diverged from realized latencies. Freshness-prioritized sampling
        (the versions tags) points the regression at post-delta traffic.
        Generation-fenced: `fit` bumps `generation` and clears the
        per-query memo, so every admission decision after the refit sees
        the new model — never a stale memoized estimate — while decisions
        already made keep the prediction they were made with."""
        gen0 = self.generation
        loss = self.fit_from_replay(replay, rng, n_samples=n_samples,
                                    batch_size=batch_size, epochs=epochs,
                                    current_versions=current_versions)
        if self.generation == gen0:
            # every sampled experience was state-less (e.g. hook-budget-0
            # degradations): nothing trainable, no fit ran, the memo is
            # still valid — skip this refit rather than mis-record it
            return loss
        self.n_refits += 1
        self.refit_log.append({"refit": self.n_refits, "trigger": trigger,
                               "generation": self.generation,
                               "loss": round(float(loss), 4)})
        return loss

    def reset_stats(self) -> None:
        """Drop the per-query memos (counters stay; the generation is NOT
        reset — it fences memos and must only move forward). Call between
        independent serving runs so one run's memoized predictions don't
        leak into the next run's measurements."""
        self._pred_memo.clear()
        self._enc_memo.clear()

    def stats(self) -> Dict[str, float]:
        return {"fit_steps": self.n_fit_steps, "generation": self.generation,
                "refits": self.n_refits,
                "memo_entries": len(self._pred_memo)}
