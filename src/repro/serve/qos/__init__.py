"""QoS control plane: SLO-aware multi-tenant serving on top of the lane
scheduler.

The serving tier (PR 2) decides *which plan* each query runs; the
lifelong loop (PR 3) decides *what the policy knows*; this package
decides *whether and how hard* each query gets re-optimized under
latency SLOs and tenant contention. Four cooperating pieces:

  tenancy.py    `TenantRegistry`: per-tenant token-bucket rate limits on
                the virtual clock, weighted fair-share lane accounting,
                default SLOs, cache partition budgets.

  predictor.py  `LatencyPredictor`: a critic-shaped jitted net over the
                encoded syntactic plan (warm-startable from the serving
                agent's value head, trained from harvested latencies via
                the PR-3 replay buffer) predicting query latency at
                admission time.

  degrade.py    `DegradationLadder`: predicted-miss severity -> shrunken
                re-optimization hook budget (down to the pure
                syntactic/AQE plan) or rejection.

  admission.py  `AdmissionPolicy` (FCFS pass-through base) and
                `QoSAdmission`: token-bucket deferral, EDF + fair-share
                selection, predictor-vs-deadline rejection, ladder
                degradation — plugged into `LaneScheduler(admission=…)`.

Everything runs on the deterministic virtual clock with seeded RNGs, so
QoS decisions are bit-reproducible; with no admission policy installed
the scheduler is bit-identical to the PR-2/PR-3 async path.
"""
from repro.serve.qos.admission import (AdmissionDecision, AdmissionPolicy,
                                       EdfPolicy, QoSAdmission)
from repro.serve.qos.degrade import DegradationLadder, DegradeDecision
from repro.serve.qos.predictor import LatencyPredictor, encode_query
from repro.serve.qos.tenancy import TenantRegistry, TenantSpec

__all__ = [
    "AdmissionDecision", "AdmissionPolicy", "EdfPolicy", "QoSAdmission",
    "DegradationLadder", "DegradeDecision",
    "LatencyPredictor", "encode_query",
    "TenantRegistry", "TenantSpec",
]
