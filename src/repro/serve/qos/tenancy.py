"""Tenant registry: rate limits, fair-share accounting, SLO defaults.

Every `Arrival` carries a `tenant` id; the registry is where a tenant's
serving contract lives:

  rate/burst     a token bucket ON THE VIRTUAL CLOCK — refill is a pure
                 function of virtual time, so rate-limit decisions are
                 bit-reproducible. A tenant over its rate is never
                 rejected outright; its query is DEFERRED to the earliest
                 virtual time a token exists (`acquire` returns that
                 time), which shows up honestly as queueing latency.
  weight         weighted fair share over lane time: the registry
                 accumulates each tenant's virtual service seconds, and
                 `fair_key` (accumulated/weight) orders tenants the way a
                 stride scheduler would — the admission policy uses it to
                 break deadline ties, so a flooding tenant cannot starve
                 a light one even when both are inside their rate.
  slo            default relative deadline (virtual seconds) stamped onto
                 arrivals that don't carry one.
  cache_bytes    this tenant's partition budget in the
                 `PartitionedStageCache` (None = the partition default).

Unknown tenants resolve to a permissive default spec, so single-tenant
streams need no registry setup at all.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    tenant: str
    weight: float = 1.0               # fair-share weight (>0)
    rate: Optional[float] = None      # admitted queries / virtual second
    burst: int = 1                    # token-bucket depth
    slo: Optional[float] = None       # default deadline = arrival + slo
    cache_bytes: Optional[int] = None  # stage-cache partition budget


@dataclasses.dataclass
class _Bucket:
    tokens: float
    last_t: float


class TenantRegistry:
    def __init__(self, specs=()):
        self._specs: Dict[str, TenantSpec] = {}
        self._buckets: Dict[str, _Bucket] = {}
        self._service: Dict[str, float] = {}   # virtual service secs used
        self._admitted: Dict[str, int] = {}
        for s in specs:
            self.register(s)

    def register(self, spec: TenantSpec) -> TenantSpec:
        assert spec.weight > 0, "fair-share weight must be positive"
        if spec.rate is not None:
            assert spec.rate > 0, "token rate must be positive"
            assert spec.burst >= 1, \
                "burst < 1 can never hold a whole token: nothing would " \
                "ever admit"
        self._specs[spec.tenant] = spec
        if spec.rate is not None:
            self._buckets[spec.tenant] = _Bucket(float(spec.burst), 0.0)
        return spec

    def reset_clock(self) -> None:
        """Restore the virtual-clock-relative state (full token buckets at
        t=0, fair-share accounting) for a fresh serving run. Called by
        `QoSAdmission.prepare`, so one admission object can serve several
        streams — each starting from the same reproducible state — while
        the lifetime `admitted` counters keep accumulating."""
        for tenant, b in self._buckets.items():
            b.tokens, b.last_t = float(self.spec(tenant).burst), 0.0
        self._service.clear()

    def spec(self, tenant: str) -> TenantSpec:
        s = self._specs.get(tenant)
        if s is None:                  # unknown tenants: permissive default
            s = TenantSpec(tenant)
            self._specs[tenant] = s
        return s

    @property
    def tenants(self):
        return sorted(self._specs)

    # --------------------------------------------------------- token bucket
    def earliest_admit(self, tenant: str, t: float) -> float:
        """Earliest virtual time >= t at which a token is available. PURE
        (no bucket mutation): the admission loop may probe the same tenant
        at several candidate times before committing, and a probe must not
        change the answer of the next one."""
        spec = self.spec(tenant)
        b = self._buckets.get(tenant)
        if b is None:
            return t
        tokens = b.tokens if t <= b.last_t else \
            min(float(spec.burst), b.tokens + (t - b.last_t) * spec.rate)
        if tokens >= 1.0:
            return t
        return b.last_t + (1.0 - b.tokens) / spec.rate

    def acquire(self, tenant: str, t: float) -> None:
        """Consume one token at virtual time t (caller must have checked
        `earliest_admit(tenant, t) <= t`)."""
        self._admitted[tenant] = self._admitted.get(tenant, 0) + 1
        spec = self.spec(tenant)
        b = self._buckets.get(tenant)
        if b is None:
            return
        if t > b.last_t:
            b.tokens = min(float(spec.burst),
                           b.tokens + (t - b.last_t) * spec.rate)
            b.last_t = t
        assert b.tokens >= 1.0 - 1e-9, \
            f"token bucket underflow for {tenant!r} at t={t}"
        b.tokens = max(b.tokens - 1.0, 0.0)

    # ----------------------------------------------------------- fair share
    def charge(self, tenant: str, service_seconds: float) -> None:
        """Account `service_seconds` of lane time to `tenant`."""
        self._service[tenant] = self._service.get(tenant, 0.0) \
            + max(service_seconds, 0.0)

    def fair_key(self, tenant: str) -> float:
        """Weighted virtual service time — smaller = more underserved."""
        return self._service.get(tenant, 0.0) / self.spec(tenant).weight

    def deadline_for(self, tenant: str, arrival_t: float,
                     deadline: Optional[float]) -> Optional[float]:
        """Explicit arrival deadline, else the tenant's default SLO."""
        if deadline is not None:
            return deadline
        slo = self.spec(tenant).slo
        return None if slo is None else arrival_t + slo

    def stats(self) -> Dict[str, Dict[str, float]]:
        return {t: {"admitted": self._admitted.get(t, 0),
                    "service_seconds": round(self._service.get(t, 0.0), 4),
                    "weight": self.spec(t).weight}
                for t in self.tenants}
