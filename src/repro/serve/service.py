"""Service façade: one object that owns the cache, the scheduler and the
serving metrics.

`QueryService` installs a fresh `StageCache` on the database (so every
service instance starts with cold, independently-budgeted cache state),
runs an arrival stream through a `LaneScheduler`, and distills the
completions into the numbers a serving benchmark cares about: throughput
(qps on the virtual clock), p50/p99 query latency (queueing + execution)
with the queue-wait/in-lane breakdown, cache hit rate, and the host-side
cost of the policy (decision batches per tick, hook seconds per query).

With a `TenantRegistry` the cache becomes per-tenant partitions
(`PartitionedStageCache`) and the stats gain a per-tenant breakdown —
qps, p50/p99, SLO-miss rate, rejected/degraded counts, partition cache
counters; with an `AdmissionPolicy` (`serve.qos`) the scheduler runs
admission control / EDF / degradation. Both default to off, keeping the
PR-2/PR-3 serving path bit-identical.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.cache import PartitionedStageCache, StageCache
from repro.serve.scheduler import (Arrival, Completion, LaneScheduler,
                                   Rejection)
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel


def _round_floats(x):
    if isinstance(x, float):
        return round(x, 4)
    if isinstance(x, dict):
        return {k: _round_floats(v) for k, v in x.items()}
    return x


@dataclasses.dataclass
class TenantStats:
    """Per-tenant slice of a serving run (virtual-clock metrics)."""
    n_completed: int = 0
    n_failed: int = 0
    n_rejected: int = 0
    n_degraded: int = 0
    n_slo_miss: int = 0               # completed past their deadline
    slo_miss_rate: float = 0.0        # misses / completed-with-deadline
    qps: float = 0.0                  # completions / global makespan
    latency_p50: float = 0.0
    latency_p99: float = 0.0
    queue_wait_mean: float = 0.0
    cache: Optional[Dict[str, float]] = None   # this tenant's partition
    failure_kinds: Optional[Dict[str, int]] = None  # failed, by kind
    n_recovered: int = 0              # succeeded after >=1 failed attempt
    n_hedged: int = 0                 # resolved through a hedge race
    # ---- SLO watchdog (serve.obs.monitor; 0 unless a monitor is attached)
    n_anomalies: int = 0              # detector alerts on this tenant's series
    n_incidents: int = 0              # incidents opened on this tenant

    def as_dict(self) -> Dict:
        return _round_floats(dataclasses.asdict(self))


@dataclasses.dataclass
class ServiceStats:
    n_completed: int
    n_failed: int
    makespan: float                  # first arrival -> last completion (s)
    qps: float
    latency_mean: float              # arrival -> completion, virtual secs
    latency_p50: float
    latency_p99: float
    service_mean: float              # in-lane: admission -> completion
    cache: Optional[Dict[str, float]]
    ticks: int
    mean_decide_batch: float
    hook_seconds: float              # total host-side policy cost
    queue_wait_mean: float = 0.0     # in admission queue: arrival -> admit
    queue_wait_p99: float = 0.0
    n_rejected: int = 0              # turned away at admission
    n_degraded: int = 0              # admitted with a shrunken hook budget
    n_slo_miss: int = 0
    slo_miss_rate: float = 0.0       # over completed queries with deadlines
    per_tenant: Optional[Dict[str, TenantStats]] = None
    # ---- failure-recovery breakdown (serve.recover) ---------------------
    failure_kinds: Optional[Dict[str, int]] = None  # failed comps, by kind
    #   (oom vs timeout vs injected crash/transient)
    attempts_total: int = 0          # lane admissions incl. retries
    n_retried: int = 0               # completions that needed >1 attempt
    n_recovered: int = 0             # succeeded after >=1 failed attempt
    n_hedged: int = 0                # resolved through a hedge race
    # ---- SLO watchdog totals (serve.obs.monitor) ------------------------
    n_anomalies: int = 0             # detector alerts, all series
    n_incidents: int = 0             # incidents opened
    # ---- plan memory (serve.plans; None unless one is attached) ---------
    n_memoized: int = 0              # completions served by memo replay
    plan_memory: Optional[Dict] = None   # PlanMemory.stats() counters

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        return _round_floats(d)


def _slo_counts(comps: List[Completion]) -> Tuple[int, float]:
    with_dl = [c for c in comps if c.deadline is not None]
    n_miss = sum(c.slo_miss for c in with_dl)
    return n_miss, (n_miss / len(with_dl) if with_dl else 0.0)


def _failure_kinds(comps: List[Completion]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for c in comps:
        if c.result.failed:
            k = c.failure_kind or "unknown"
            out[k] = out.get(k, 0) + 1
    return out


class QueryService:
    """Online query service over a database + trained (or cold) agent."""

    def __init__(self, db, agent, *, est: Optional[Estimator] = None,
                 cluster: Optional[ClusterModel] = None, n_lanes: int = 8,
                 policy: str = "async", window: Optional[float] = None,
                 cache_bytes: int = 256 * 1024 * 1024,
                 reuse_stages: bool = True, explore: bool = False,
                 hooks: Sequence = (), tenants=None, admission=None,
                 recovery=None, obs=None, monitor=None, plan_memory=None):
        """`hooks` are objects with an `attach(scheduler)` method (e.g. the
        lifelong-learning loop's `learn.TrajectoryHarvester` /
        `learn.BackgroundLearner`); each is attached to every scheduler
        this service creates, in order. `explore=True` samples the policy
        instead of taking argmax — the online loop uses it to keep
        gathering off-greedy experience while serving.

        `tenants` (a `serve.qos.TenantRegistry`) partitions the stage
        cache per tenant (each spec's `cache_bytes`, else `cache_bytes`)
        and switches the stats to a per-tenant breakdown. `admission` (a
        `serve.qos.AdmissionPolicy`) plugs admission control into every
        scheduler this service creates. `recovery` (a
        `serve.recover.RecoveryManager`) plugs the failure-recovery
        control plane in the same way. `obs` (a `serve.obs.Tracer`)
        attaches the observability plane — BEFORE the hooks, so hook
        attach seams (learner/breaker) can wire their own emit paths to
        it. `monitor` (a `serve.obs.SloMonitor`) attaches the online SLO
        watchdog AFTER the hooks — it reads each completion's assembled
        span tree, so the tracer (auto-created when `obs` is None) must
        observe first. `plan_memory` (a `serve.plans.PlanMemory`) attaches
        the memoized-replay fast path right after the tracer (its events
        need `scheduler.obs` live) and before the hooks (so harvesters see
        `comp.memoized`). All None = the PR-2 path, bit-identical; a
        monitor with alerts unwired keeps completions bit-identical too."""
        self.db = db
        self.agent = agent
        self.est = est if est is not None else Estimator(db, db.stats)
        self.cluster = cluster if cluster is not None else ClusterModel()
        self.n_lanes, self.policy, self.window = n_lanes, policy, window
        self.reuse_stages = reuse_stages
        self.explore = explore
        self.hooks = list(hooks)
        self.tenants = tenants
        self.admission = admission
        self.recovery = recovery
        if monitor is not None and obs is None:
            from repro.serve.obs import Tracer
            obs = Tracer()
        self.obs = obs
        self.monitor = monitor
        self.plan_memory = plan_memory
        if reuse_stages:
            if tenants is not None:
                # every REGISTERED tenant gets its own partition (explicit
                # budget or the service default); unregistered ids share
                # the default partition, so memory stays bounded
                budgets = {t: tenants.spec(t).cache_bytes
                           if tenants.spec(t).cache_bytes is not None
                           else cache_bytes for t in tenants.tenants}
                self.cache = PartitionedStageCache(
                    default_bytes=cache_bytes, budgets=budgets)
            else:
                self.cache = StageCache(max_bytes=cache_bytes)
            db._stage_cache = self.cache     # shared by every AdaptiveRun
        else:
            self.cache = None
        self.scheduler: Optional[LaneScheduler] = None

    def run(self, stream: Sequence[Arrival]) \
            -> Tuple[List[Completion], ServiceStats]:
        """Serve `stream` to completion; returns (completions, stats)."""
        self.scheduler = LaneScheduler(
            self.db, self.est, self.agent, n_lanes=self.n_lanes,
            explore=self.explore, cluster=self.cluster, policy=self.policy,
            window=self.window, reuse_stages=self.reuse_stages,
            admission=self.admission, recovery=self.recovery)
        if self.obs is not None:
            self.obs.attach(self.scheduler)
        if self.plan_memory is not None:
            self.plan_memory.attach(self.scheduler)
        for h in self.hooks:
            h.attach(self.scheduler)
        if self.monitor is not None:
            # last attacher: the monitor consumes the span trees the
            # tracer's own on_complete assembles
            self.monitor.attach(self.scheduler)
        comps = self.scheduler.run(list(stream))
        if self.monitor is not None:
            self.monitor.finalize()
        return comps, self._stats(comps)

    def reset_stats(self, *, clear_entries: bool = False) -> None:
        """Zero the measurement state that otherwise ACCUMULATES across
        `run()` calls sharing this service's executor state: stage-cache
        counters (all partitions) and, when the admission policy carries a
        `LatencyPredictor`, its per-query prediction memos. With
        `clear_entries=True` the cache contents are dropped too, so the
        next run starts cold — on an unmutated database that makes two
        identical streams produce identical stats end to end."""
        if self.cache is not None:
            self.cache.reset_stats()
            if clear_entries:
                self.cache.clear()
        pred = getattr(self.admission, "predictor", None)
        if pred is not None and hasattr(pred, "reset_stats"):
            pred.reset_stats()
        if self.obs is not None:
            # spans, events, metrics registry and flight recorder all
            # accumulate across run() calls — same discipline as the
            # cache counters above
            self.obs.reset()
        if self.monitor is not None:
            # detector baselines, anomaly/incident history and the
            # plan-provenance ledger accumulate the same way
            self.monitor.reset()
        if self.plan_memory is not None:
            # probe/hit/promotion counters accumulate across runs; the
            # ENTRIES only drop with clear_entries (they are the product)
            self.plan_memory.reset_stats(clear_entries=clear_entries)

    def run_queries(self, queries: Sequence, *, seeds=None) \
            -> Tuple[List[Completion], ServiceStats]:
        """Closed batch convenience: all queries arrive at t=0."""
        if seeds is None:
            seeds = range(len(queries))
        return self.run([Arrival(0.0, query=q, seed=s)
                         for q, s in zip(queries, seeds)])

    # -------------------------------------------------------------- stats
    def _cache_dict(self) -> Optional[Dict[str, float]]:
        if self.cache is None:
            return None
        if isinstance(self.cache, PartitionedStageCache):
            return self.cache.aggregate_stats()
        return self.cache.stats.as_dict()

    def _tenant_stats(self, comps: List[Completion],
                      rejects: List[Rejection], makespan: float) \
            -> Dict[str, TenantStats]:
        names = sorted({c.tenant for c in comps} |
                       {r.tenant for r in rejects} |
                       (set(self.tenants.tenants)
                        if self.tenants is not None else set()))
        parts = self.cache.partitions() \
            if isinstance(self.cache, PartitionedStageCache) else {}
        out = {}
        for name in names:
            cs = [c for c in comps if c.tenant == name]
            n_miss, miss_rate = _slo_counts(cs)
            lat = np.asarray([c.latency for c in cs]) if cs else None
            part = parts.get(name)
            n_anom, n_inc = self.monitor.tenant_counts(name) \
                if self.monitor is not None else (0, 0)
            out[name] = TenantStats(
                n_completed=len(cs),
                n_failed=sum(c.result.failed for c in cs),
                n_rejected=sum(r.tenant == name for r in rejects),
                n_degraded=sum(c.degraded for c in cs),
                n_slo_miss=n_miss, slo_miss_rate=miss_rate,
                qps=len(cs) / max(makespan, 1e-9),
                latency_p50=float(np.percentile(lat, 50)) if cs else 0.0,
                latency_p99=float(np.percentile(lat, 99)) if cs else 0.0,
                queue_wait_mean=float(np.mean([c.queue_wait for c in cs]))
                if cs else 0.0,
                cache=part.stats.as_dict() if part is not None else None,
                failure_kinds=_failure_kinds(cs) or None,
                n_recovered=sum(c.recovered for c in cs),
                n_hedged=sum(c.hedged for c in cs),
                n_anomalies=n_anom, n_incidents=n_inc)
        return out

    def _stats(self, comps: List[Completion]) -> ServiceStats:
        sched = self.scheduler
        rejects = sched.rejections
        # NB: `if self.cache` would be False for an EMPTY cache (StageCache
        # defines __len__) — the None-check matters on the empty-stream path
        if not comps:
            return ServiceStats(
                0, 0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, self._cache_dict(),
                sched.ticks, 0.0, 0.0, n_rejected=len(rejects),
                per_tenant=self._tenant_stats([], rejects, 0.0)
                if self.tenants is not None else None,
                plan_memory=self.plan_memory.stats()
                if self.plan_memory is not None else None)
        lat = np.asarray([c.latency for c in comps])
        wait = np.asarray([c.queue_wait for c in comps])
        first = min(c.arrival_t for c in comps)
        makespan = max(c.finish_t for c in comps) - first
        n_miss, miss_rate = _slo_counts(comps)
        n_anom, n_inc = self.monitor.totals() \
            if self.monitor is not None else (0, 0)
        return ServiceStats(
            n_completed=len(comps),
            n_failed=sum(c.result.failed for c in comps),
            makespan=makespan,
            qps=len(comps) / max(makespan, 1e-9),
            latency_mean=float(lat.mean()),
            latency_p50=float(np.percentile(lat, 50)),
            latency_p99=float(np.percentile(lat, 99)),
            service_mean=float(np.mean([c.service_t for c in comps])),
            cache=self._cache_dict(),
            ticks=sched.ticks,
            mean_decide_batch=float(np.mean(sched.decide_sizes))
            if sched.decide_sizes else 0.0,
            hook_seconds=float(sum(c.traj.hook_seconds for c in comps)),
            queue_wait_mean=float(wait.mean()),
            queue_wait_p99=float(np.percentile(wait, 99)),
            n_rejected=len(rejects),
            n_degraded=sum(c.degraded for c in comps),
            n_slo_miss=n_miss, slo_miss_rate=miss_rate,
            per_tenant=self._tenant_stats(comps, rejects, makespan)
            if self.tenants is not None else None,
            failure_kinds=_failure_kinds(comps) or None,
            attempts_total=sum(c.attempts for c in comps),
            n_retried=sum(c.attempts > 1 for c in comps),
            n_recovered=sum(c.recovered for c in comps),
            n_hedged=sum(c.hedged for c in comps),
            n_anomalies=n_anom, n_incidents=n_inc,
            n_memoized=sum(c.memoized for c in comps),
            plan_memory=self.plan_memory.stats()
            if self.plan_memory is not None else None)
