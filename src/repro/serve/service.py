"""Service façade: one object that owns the cache, the scheduler and the
serving metrics.

`QueryService` installs a fresh `StageCache` on the database (so every
service instance starts with cold, independently-budgeted cache state),
runs an arrival stream through a `LaneScheduler`, and distills the
completions into the numbers a serving benchmark cares about: throughput
(qps on the virtual clock), p50/p99 query latency (queueing + execution),
cache hit rate, and the host-side cost of the policy (decision batches per
tick, hook seconds per query).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.cache import StageCache
from repro.serve.scheduler import Arrival, Completion, LaneScheduler
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel


@dataclasses.dataclass
class ServiceStats:
    n_completed: int
    n_failed: int
    makespan: float                  # first arrival -> last completion (s)
    qps: float
    latency_mean: float              # arrival -> completion, virtual secs
    latency_p50: float
    latency_p99: float
    service_mean: float              # admission -> completion (no queueing)
    cache: Optional[Dict[str, float]]
    ticks: int
    mean_decide_batch: float
    hook_seconds: float              # total host-side policy cost

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        for k, v in d.items():
            if isinstance(v, float):
                d[k] = round(v, 4)
        return d


class QueryService:
    """Online query service over a database + trained (or cold) agent."""

    def __init__(self, db, agent, *, est: Optional[Estimator] = None,
                 cluster: Optional[ClusterModel] = None, n_lanes: int = 8,
                 policy: str = "async", window: Optional[float] = None,
                 cache_bytes: int = 256 * 1024 * 1024,
                 reuse_stages: bool = True, explore: bool = False,
                 hooks: Sequence = ()):
        """`hooks` are objects with an `attach(scheduler)` method (e.g. the
        lifelong-learning loop's `learn.TrajectoryHarvester` /
        `learn.BackgroundLearner`); each is attached to every scheduler
        this service creates, in order. `explore=True` samples the policy
        instead of taking argmax — the online loop uses it to keep
        gathering off-greedy experience while serving."""
        self.db = db
        self.agent = agent
        self.est = est if est is not None else Estimator(db, db.stats)
        self.cluster = cluster if cluster is not None else ClusterModel()
        self.n_lanes, self.policy, self.window = n_lanes, policy, window
        self.reuse_stages = reuse_stages
        self.explore = explore
        self.hooks = list(hooks)
        if reuse_stages:
            self.cache = StageCache(max_bytes=cache_bytes)
            db._stage_cache = self.cache     # shared by every AdaptiveRun
        else:
            self.cache = None
        self.scheduler: Optional[LaneScheduler] = None

    def run(self, stream: Sequence[Arrival]) \
            -> Tuple[List[Completion], ServiceStats]:
        """Serve `stream` to completion; returns (completions, stats)."""
        self.scheduler = LaneScheduler(
            self.db, self.est, self.agent, n_lanes=self.n_lanes,
            explore=self.explore, cluster=self.cluster, policy=self.policy,
            window=self.window, reuse_stages=self.reuse_stages)
        for h in self.hooks:
            h.attach(self.scheduler)
        comps = self.scheduler.run(list(stream))
        return comps, self._stats(comps)

    def run_queries(self, queries: Sequence, *, seeds=None) \
            -> Tuple[List[Completion], ServiceStats]:
        """Closed batch convenience: all queries arrive at t=0."""
        if seeds is None:
            seeds = range(len(queries))
        return self.run([Arrival(0.0, query=q, seed=s)
                         for q, s in zip(queries, seeds)])

    def _stats(self, comps: List[Completion]) -> ServiceStats:
        sched = self.scheduler
        # NB: `if self.cache` would be False for an EMPTY cache (StageCache
        # defines __len__) — the None-check matters on the empty-stream path
        if not comps:
            return ServiceStats(0, 0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
                                self.cache.stats.as_dict()
                                if self.cache is not None else None,
                                sched.ticks, 0.0, 0.0)
        lat = np.asarray([c.latency for c in comps])
        first = min(c.arrival_t for c in comps)
        makespan = max(c.finish_t for c in comps) - first
        return ServiceStats(
            n_completed=len(comps),
            n_failed=sum(c.result.failed for c in comps),
            makespan=makespan,
            qps=len(comps) / max(makespan, 1e-9),
            latency_mean=float(lat.mean()),
            latency_p50=float(np.percentile(lat, 50)),
            latency_p99=float(np.percentile(lat, 99)),
            service_mean=float(np.mean([c.service_t for c in comps])),
            cache=self.cache.stats.as_dict()
            if self.cache is not None else None,
            ticks=sched.ticks,
            mean_decide_batch=float(np.mean(sched.decide_sizes))
            if sched.decide_sizes else 0.0,
            hook_seconds=float(sum(c.traj.hook_seconds for c in comps)),
        )
