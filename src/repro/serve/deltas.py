"""Delta-table dynamic workloads: append/delete batches against the live
database.

A `DeltaBatch` mutates one table in place (appending bootstrap-resampled
rows and/or deleting a random row fraction) and bumps the table's version
tag via `Database.bump_version`. Because stage-cache signatures embed those
tags, every cached stage derived from the old contents stops matching the
moment the delta lands — a stale entry served after the delta would return
provably wrong rows, which is exactly what the invalidation tests assert
never happens.

Optimizer statistics (`db.stats`) are deliberately NOT refreshed: queries
after a delta plan with stale estimates over fresh data, reproducing the
paper's dynamic-evaluation setting (and LIMAO's data-drift motivation).

Deletes are only generated for fact tables (no dense `id` primary key), so
foreign keys in the rest of the schema never dangle.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import numpy as np

from repro.sql import datagen
from repro.sql.catalog import Database


@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One update batch against `table`: `n_append` bootstrap-resampled new
    rows, then `delete_frac` of the (post-append) rows removed."""
    table: str
    n_append: int = 0
    delete_frac: float = 0.0
    seed: int = 0

    def __str__(self) -> str:
        return (f"delta({self.table}: +{self.n_append} rows, "
                f"-{self.delete_frac:.0%})")


def apply_delta(db: Database, delta: DeltaBatch) -> Dict[str, int]:
    """Mutate the table in place and bump its version. Returns counts."""
    t = db.table(delta.table)
    rng = np.random.default_rng(delta.seed)
    appended = deleted = 0
    if delta.n_append > 0:
        new = datagen.delta_rows(t, delta.n_append, rng)
        t.columns = {k: np.concatenate([v, new[k]])
                     for k, v in t.columns.items()}
        appended = delta.n_append
    if delta.delete_frac > 0.0 and t.nrows:
        keep = rng.random(t.nrows) >= delta.delete_frac
        deleted = int(t.nrows - keep.sum())
        if deleted:
            t.columns = {k: v[keep] for k, v in t.columns.items()}
    db.bump_version(delta.table)
    return {"appended": appended, "deleted": deleted}


# fact tables (no dense `id` PK referenced elsewhere): safe delete targets
FACT_TABLES = {
    "job": ("movie_info", "movie_keyword", "cast_info", "movie_companies",
            "movie_info_idx"),
    "extjob": ("movie_info", "movie_keyword", "cast_info", "movie_companies",
               "movie_info_idx"),
    "stack": ("answer", "tag_question", "comment", "badge"),
}


def make_delta(db: Database, tables: Sequence[str], i: int, *,
               n_append: int, delete_frac: float = 0.0,
               seed: int = 0) -> DeltaBatch:
    """The i-th delta of a stream: round-robin over `tables`."""
    return DeltaBatch(tables[i % len(tables)], n_append=n_append,
                      delete_frac=delete_frac, seed=seed + i)
