"""The drift controller: one scheduler hook wiring detection to its three
actuators.

Attached via `QueryService(hooks=[DriftController(...)])` (after the
harvester, so replay regret for the triggering completion is already
up to date), the controller runs entirely inside `on_complete` — between
policy batches, in deterministic completion order — and:

  1. feeds the `DriftDetector` each completion's execution evidence
     (latency regret from the replay buffer's per-template bests,
     relative predicted-vs-actual error from the QoS predictor);

  2. asks the `RefreshPolicy` which drifted tables earn a re-ANALYZE and
     schedules one `LaneScheduler.schedule_barrier` task for them: the
     task drains in-flight queries (a stats swap mid-query would make a
     run's planning inconsistent), runs `catalog.analyze_table`
     incrementally per table, and charges an EXPLICIT cost — modeled
     seconds from the cluster's scan model (deterministic; optionally
     also pushed onto the virtual clock with `charge_virtual=True`, so
     refresh delays traffic like a real maintenance window) plus
     measured wall seconds (reported, never consulted);

  3. refits the `LatencyPredictor` from the LIVE replay buffer when the
     peak drift score crosses `refit_threshold` (generation-fenced,
     cooldown `refit_every` completions) — replacing one-shot
     calibration;

  4. re-samples the `PolicyStore` gate probes through `CoverageProbeSet`
     whenever the set of above-threshold tables changes, so candidates
     are gated on the traffic that actually drifted.

Every decision consumes only virtual-clock state, modeled costs and
seeded RNGs: a run with the controller attached is bit-reproducible, and
with `RefreshPolicy("never")` + no refit/probe actuators it is
completion-bit-identical to a run with no controller at all (pinned by
tests/test_drift.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serve.drift.detector import DriftDetector
from repro.serve.drift.policy import RefreshPolicy
from repro.serve.drift.probes import CoverageProbeSet
from repro.sql.catalog import analyze_table

__all__ = ["DriftController", "DriftStats"]


@dataclasses.dataclass
class DriftStats:
    completions: int = 0
    refresh_events: int = 0            # barrier tasks run
    tables_refreshed: int = 0          # table re-ANALYZEs (events x tables)
    analyze_modeled_s: float = 0.0     # deterministic cluster-model price
    analyze_wall_s: float = 0.0        # measured host cost (reported only)
    refits: int = 0
    probe_resamples: int = 0
    curriculum_demotions: int = 0      # stage drops via note_drift
    host_seconds: float = 0.0          # controller's own on_complete cost

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        for k in ("analyze_modeled_s", "analyze_wall_s", "host_seconds"):
            d[k] = round(d[k], 4)
        return d


class DriftController:
    def __init__(self, *, detector: Optional[DriftDetector] = None,
                 policy: Optional[RefreshPolicy] = None,
                 replay=None, predictor=None, store=None,
                 probes: Optional[CoverageProbeSet] = None,
                 curriculum=None, plan_memory=None,
                 refit_threshold: float = 1.0, refit_every: int = 8,
                 refit_samples: int = 64, refit_epochs: int = 2,
                 probe_threshold: float = 1.0,
                 sample_frac: float = 0.05, charge_virtual: bool = False,
                 seed: int = 0):
        """`replay` is the PR-3 `learn.ReplayBuffer` (regret source and the
        refit training set); `predictor` the QoS `LatencyPredictor` (error
        source and refit target); `store` the `learn.PolicyStore` whose
        probe set `probes` re-covers; `curriculum` an
        `learn.AdaptiveCurriculum` (with `drift_demote_threshold` set)
        that gets the peak drift score per completion — the fourth
        actuator: detector-attributed drift demotes the serving stage
        (share the instance with the `BackgroundLearner`, which copies
        `stage` onto the scheduler between ticks). All are optional: the
        detector scores from catalog lag alone when evidence sources are
        absent, and actuators without their dependency simply stay off.
        `plan_memory` (a `serve.plans.PlanMemory`) gets `note_stats_refresh`
        whenever a re-ANALYZE rewrites a table's statistics: the memory's
        entries on that table are fenced — demoted from blind replay to
        superoptimizer hint prior — because the plan that won under the
        old stats is no longer evidence under the new ones."""
        self.detector = detector if detector is not None else DriftDetector()
        self.policy = policy if policy is not None else RefreshPolicy("never")
        self.replay = replay
        self.predictor = predictor
        self.store = store
        self.probes = probes
        self.curriculum = curriculum
        self.plan_memory = plan_memory
        assert probes is None or store is not None, \
            "probe coverage needs a PolicyStore to install the set on"
        self.refit_threshold = refit_threshold
        self.refit_every = max(refit_every, 1)
        self.refit_samples = refit_samples
        self.refit_epochs = refit_epochs
        self.probe_threshold = probe_threshold
        self.sample_frac = sample_frac
        self.charge_virtual = charge_virtual
        self._refit_rng = np.random.default_rng(seed)
        self._analyze_rng = np.random.default_rng(seed + 1)
        self.stats = DriftStats()
        self.refresh_log: List[Dict] = []
        self._sched = None
        self._pending: set = set()       # tables in a scheduled, unrun task
        self._since_refit = 0
        self._probe_cover_set: tuple = ()  # drifted-table set last installed

    # ------------------------------------------------------------- plumbing
    def attach(self, scheduler) -> None:
        self._sched = scheduler
        self.detector.snapshot(scheduler.db)
        scheduler.on_complete.append(self._on_complete)
        scheduler.on_delta.append(self._on_delta)

    def scores(self):
        return self.detector.score(self._sched.db)

    def _analyze_cost_s(self, table: str) -> float:
        """Deterministic price of ANALYZE(table): the cluster's scan model
        over the bytes the sampler actually reads, plus one stage of
        scheduling overhead."""
        cl = self._sched.cluster
        nbytes = self._sched.db.table(table).bytes() * self.sample_frac
        return cl.scan_time(nbytes) + cl.stage_overhead

    # ----------------------------------------------------------- completion
    def _on_complete(self, comp) -> None:
        t0 = time.perf_counter()
        self.stats.completions += 1
        self._since_refit += 1
        tables = tuple(sorted({r.table for r in comp.query.relations}))
        regret = None
        if self.replay is not None:
            regret = self.replay.regret_for(comp.query.name,
                                            comp.result.latency)
        pred_err = None
        if self.predictor is not None:
            predicted = comp.predicted
            if predicted is None:
                predicted = self.predictor.predict_query(comp.query)
            actual = comp.result.latency
            pred_err = abs(predicted - actual) / max(actual, 1e-9)
        self.detector.observe(tables, regret=regret, pred_err=pred_err)

        # with no actuator able to consume them (never-policy, no refit
        # target, no probe pool) scoring the catalog per completion is
        # pure serving-path overhead — scores() stays available on demand
        if self.policy.kind != "never" or self.predictor is not None \
                or self.probes is not None or self.curriculum is not None:
            drifts = self.detector.score(self._sched.db)
            self._maybe_refresh(drifts, comp.finish_t)
            self._maybe_refit(drifts)
            self._maybe_recover_probes(drifts)
            self._maybe_demote_curriculum(drifts)
        self.stats.host_seconds += time.perf_counter() - t0

    def _on_delta(self, t_apply: float, delta) -> None:
        """Delta batches are where catalog lag is born — and the one point
        where every lane is already drained. Deciding a refresh HERE means
        the barrier task (scheduled from this hook) runs at the very same
        barrier, before any post-delta query is admitted: auto-ANALYZE
        triggered by DML, not by a later completion, with zero extra
        drain stall."""
        if self.policy.kind == "never":
            return                     # no actuator: keep the baseline free
        t0 = time.perf_counter()
        self._maybe_refresh(self.detector.score(self._sched.db), t_apply)
        self.stats.host_seconds += time.perf_counter() - t0

    # ------------------------------------------------------------ actuators
    def _maybe_refresh(self, drifts, now: float) -> None:
        dec = self.policy.decide(
            {t: d for t, d in drifts.items() if t not in self._pending},
            now, self._analyze_cost_s)
        if not dec.tables:
            return
        self._pending.update(dec.tables)
        self._sched.schedule_barrier(self._refresh_task(dec.tables),
                                     label=f"re-analyze:{','.join(dec.tables)}")

    def note_external_evidence(self, tables, now: float,
                               reason: str = "") -> tuple:
        """Opt-in alert path (serve.obs.AlertHooks): an external monitor
        attributed a live regression to stale stats on `tables` —
        schedule an immediate re-ANALYZE barrier for them, bypassing the
        RefreshPolicy's thresholds/budget (the policy prices routine
        maintenance; an attributed incident has already paid for it).
        Returns the tables actually scheduled."""
        avail = tuple(t for t in sorted(set(tables))
                      if t not in self._pending and t in self._sched.db.tables)
        if not avail:
            return ()
        self._pending.update(avail)
        self._sched.schedule_barrier(
            self._refresh_task(avail),
            label=f"re-analyze[alert]:{','.join(avail)}")
        return avail

    def _refresh_task(self, tables):
        def task(sched, t_apply: float):
            modeled_total = 0.0
            for t in tables:
                w0 = time.perf_counter()
                modeled = self._analyze_cost_s(t)   # pre-ANALYZE bytes
                ts = analyze_table(sched.db, t, self.sample_frac,
                                   rng=self._analyze_rng)
                version = sched.db.table_version(t)
                sched.db.stats.tables[t] = ts
                if sched.db.stats.versions is not None:
                    sched.db.stats.versions[t] = version
                est_stats = getattr(sched.est, "stats", None)
                if est_stats is not None and est_stats is not sched.db.stats:
                    est_stats.tables[t] = ts
                    if est_stats.versions is not None:
                        est_stats.versions[t] = version
                self.detector.note_refreshed(t, version)
                self.policy.note_refreshed(t, t_apply)
                self.stats.tables_refreshed += 1
                self.stats.analyze_modeled_s += modeled
                self.stats.analyze_wall_s += time.perf_counter() - w0
                modeled_total += modeled
            self._pending.difference_update(tables)
            self.stats.refresh_events += 1
            self.refresh_log.append(
                {"t": round(t_apply, 4), "tables": list(tables),
                 "modeled_s": round(modeled_total, 4)})
            if getattr(sched, "obs", None) is not None:
                sched.obs.event("re_analyze",
                                {"tables": list(tables),
                                 "modeled_s": round(modeled_total, 6)},
                                t=t_apply)
            if self.store is not None:
                # fresh stats change probe planning without a version bump:
                # the store's version-keyed incumbent cache must not survive
                self.store.note_stats_refresh()
            if self.plan_memory is not None:
                # same staleness, different store: memoized plans that won
                # under the old stats are fenced to hint-prior status
                self.plan_memory.note_stats_refresh(tables, t_apply)
            return modeled_total if self.charge_virtual else 0.0
        return task

    def _maybe_refit(self, drifts) -> None:
        if self.predictor is None or self.replay is None \
                or not len(self.replay):
            return
        if self._since_refit < self.refit_every:
            return
        peak = max((d.score for d in drifts.values()), default=0.0)
        if peak < self.refit_threshold:
            return
        n0 = self.predictor.n_refits
        self.predictor.refit_on_drift(
            self.replay, self._refit_rng,
            current_versions=dict(self._sched.db.versions),
            n_samples=self.refit_samples, epochs=self.refit_epochs,
            trigger=f"peak drift score {peak:.2f}")
        # a sample of all state-less trajectories trains nothing and is
        # not counted as a refit; the cooldown restarts either way
        refitted = self.predictor.n_refits > n0
        self.stats.refits += refitted
        self._since_refit = 0
        if refitted and getattr(self._sched, "obs", None) is not None:
            self._sched.obs.event("predictor_refit",
                                  {"peak_score": round(peak, 6),
                                   "n_refits": self.predictor.n_refits})

    def _maybe_demote_curriculum(self, drifts) -> None:
        if self.curriculum is None:
            return
        peak = max((d.score for d in drifts.values()), default=0.0)
        if self.curriculum.note_drift(peak):
            self.stats.curriculum_demotions += 1
            if getattr(self._sched, "obs", None) is not None:
                self._sched.obs.event(
                    "curriculum_demote",
                    {"peak_score": round(peak, 6),
                     "stage": self.curriculum.stage})

    def _maybe_recover_probes(self, drifts) -> None:
        if self.probes is None:
            return
        hot = tuple(sorted(t for t, d in drifts.items()
                           if d.score >= self.probe_threshold))
        if not hot or hot == self._probe_cover_set:
            return
        self.store.set_probe(self.probes.resample(drifts),
                             reason=f"drifted tables: {','.join(hot)}")
        self._probe_cover_set = hot
        self.stats.probe_resamples += 1
        if getattr(self._sched, "obs", None) is not None:
            self._sched.obs.event("probe_resample",
                                  {"drifted_tables": list(hot)})

    def summary(self) -> Dict:
        return {**self.stats.as_dict(),
                "detector": self.detector.stats(),
                "policy": self.policy.stats(),
                "predictor": None if self.predictor is None
                else self.predictor.stats(),
                "probes": None if self.probes is None
                else self.probes.stats()}
