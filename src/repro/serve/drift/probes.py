"""Coverage-driven probe sets for the policy-store gate.

The PR-3 `PolicyStore` shadow-evaluates every candidate policy on a FIXED
held-out probe list. After drift that list measures the wrong thing: a
candidate can look "no worse" on probes whose tables never moved while
regressing badly on the drifted ones (exactly the queries the lifelong
loop is trying to unlearn). `CoverageProbeSet` keeps a larger held-out
POOL and re-samples the k gate probes whenever the detector reports
drift, weighting each pool query by the drift scores of the tables it
touches:

    w(q) = base_weight + Σ_{t ∈ tables(q)} score(t)

Sampling is weighted-without-replacement from an OWN seeded generator, so
a fixed seed makes every resample (and therefore every gate verdict
downstream) bit-reproducible. With zero drift everywhere the weights are
uniform and the set is an unbiased draw from the pool — the fixed-list
behavior, modulo which k queries represent it.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.serve.drift.detector import TableDrift

__all__ = ["CoverageProbeSet"]


class CoverageProbeSet:
    def __init__(self, pool: Sequence, *, k: int = 4,
                 base_weight: float = 0.25, seed: int = 0):
        assert pool, "probe pool must not be empty"
        assert base_weight > 0.0, "zero base weight starves undrifted " \
            "templates of any gate coverage"
        self.pool = list(pool)
        self.k = min(k, len(self.pool))
        self.base_weight = base_weight
        self._rng = np.random.default_rng(seed)
        self._tables = [tuple(sorted({r.table for r in q.relations}))
                        for q in self.pool]
        self.n_resamples = 0

    def weights(self, drifts: Dict[str, TableDrift]) -> np.ndarray:
        w = np.full(len(self.pool), self.base_weight, np.float64)
        for i, tabs in enumerate(self._tables):
            w[i] += sum(drifts[t].score for t in tabs if t in drifts)
        return w

    def resample(self, drifts: Dict[str, TableDrift]) -> List:
        """Draw the next k-probe gate set, biased toward drifted tables.
        Returned in pool order so the gate replays probes in a stable
        order regardless of draw order."""
        w = self.weights(drifts)
        idx = self._rng.choice(len(self.pool), size=self.k, replace=False,
                               p=w / w.sum())
        self.n_resamples += 1
        return [self.pool[i] for i in sorted(idx)]

    def stats(self) -> Dict[str, float]:
        return {"pool": len(self.pool), "k": self.k,
                "resamples": self.n_resamples}
