"""Stats-refresh policies: when is re-ANALYZE worth its cost?

The paper keeps statistics deliberately stale and defers correction to
runtime; classical practice re-ANALYZEs on a cadence and trusts the
optimizer. `RefreshPolicy` makes that a pluggable, benchmarked decision
(`benchmarks/bench_drift.py` sweeps all four kinds against online
adaptation):

  never      today's baseline: statistics are written once and never
             touched — the scheduler path is bit-identical to a run with
             no drift control plane at all (pinned by tests).
  always     re-ANALYZE every table whose data version moved, as soon as
             the detector sees the lag — classical eager maintenance;
             maximal stats quality, maximal (modeled + wall) cost.
  threshold  re-ANALYZE a table only once its fused drift score crosses
             `threshold` — catalog lag alone does not trigger a scan
             until data movement or execution evidence makes it matter.
  budgeted   threshold, plus a hard ceiling on cumulative MODELED
             re-ANALYZE cost (`budget_s`, priced by the cluster model so
             decisions stay bit-deterministic — wall time is reported,
             never consulted): highest-score tables first; a table whose
             cost would bust the ceiling is skipped, and cheaper
             lower-score tables that still fit are taken.

`min_interval` (virtual seconds) floors how often any single table may
be re-ANALYZEd under every kind except "never" — the backstop against a
churn-heavy stream turning "always" into a scan storm.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.serve.drift.detector import TableDrift

__all__ = ["RefreshPolicy", "RefreshDecision"]

KINDS = ("never", "always", "threshold", "budgeted")


@dataclasses.dataclass(frozen=True)
class RefreshDecision:
    tables: tuple                  # to re-ANALYZE, highest score first
    modeled_cost_s: float          # deterministic price of this decision
    reason: str = ""


_NOOP = RefreshDecision((), 0.0, "")


class RefreshPolicy:
    def __init__(self, kind: str = "threshold", *, threshold: float = 1.0,
                 budget_s: Optional[float] = None,
                 min_interval: float = 0.0):
        assert kind in KINDS, f"kind must be one of {KINDS}, got {kind!r}"
        if kind == "budgeted":
            assert budget_s is not None, "budgeted policy needs budget_s"
        self.kind = kind
        self.threshold = threshold
        self.budget_s = budget_s
        self.min_interval = min_interval
        self.spent_modeled_s = 0.0         # charged by the controller
        self.last_refresh: Dict[str, float] = {}   # table -> virtual time
        self.n_decisions = 0

    # ------------------------------------------------------------- deciding
    def _eligible(self, d: TableDrift, now: float) -> bool:
        if not d.drifted:
            return False
        last = self.last_refresh.get(d.table)
        if last is not None and now - last < self.min_interval:
            return False
        if self.kind == "always":
            return True
        return d.score >= self.threshold

    def decide(self, drifts: Dict[str, TableDrift], now: float,
               cost_fn: Callable[[str], float]) -> RefreshDecision:
        """Pick the tables to re-ANALYZE at virtual time `now`. `cost_fn`
        prices one table's ANALYZE in MODELED seconds (cluster scan model
        over the sampled bytes) — the only cost the budgeted policy
        consults, so the decision is a pure function of the stream.

        The budget is RESERVED here, not when the barrier task later
        runs: a second decision taken while the first task still waits
        for lanes to drain must already see its cost, or two
        decided-but-unrun refreshes could together overshoot the hard
        ceiling."""
        if self.kind == "never":
            return _NOOP
        self.n_decisions += 1
        cands = sorted((d for d in drifts.values()
                        if self._eligible(d, now)),
                       key=lambda d: (-d.score, d.table))
        if not cands:
            return _NOOP
        picked: List[str] = []
        cost = 0.0
        for d in cands:
            c = cost_fn(d.table)
            if self.kind == "budgeted" and \
                    self.spent_modeled_s + cost + c > self.budget_s:
                continue               # cheaper lower-score table may fit
            picked.append(d.table)
            cost += c
        if not picked:
            return _NOOP
        self.spent_modeled_s += cost   # reserve against the ceiling NOW
        reason = {"always": "version lag",
                  "threshold": f"score >= {self.threshold}",
                  "budgeted": f"score >= {self.threshold} within "
                              f"{self.budget_s}s budget"}[self.kind]
        return RefreshDecision(tuple(picked), cost, reason)

    # ------------------------------------------------------------ lifecycle
    def note_refreshed(self, table: str, now: float) -> None:
        """Record the refresh time for `min_interval` (the modeled cost
        was already reserved by the `decide` that picked the table)."""
        self.last_refresh[table] = now

    def stats(self) -> Dict[str, float]:
        return {"kind": self.kind, "decisions": self.n_decisions,
                "spent_modeled_s": round(self.spent_modeled_s, 4),
                "tables_refreshed": len(self.last_refresh)}
