"""Drift detection: per-table staleness as a measured, managed quantity.

The paper's premise is that optimizer statistics go stale while data
changes; everything downstream of `analyze()` treats that staleness as a
fixed fact. This module makes it a NUMBER. Per base table the detector
fuses three signal families, each observable on the serving path for
free:

  catalog lag     `Database.versions` bumps since the table's stats were
                  last ANALYZEd, and |ln(live rows / stats rows)| — how
                  far the data moved while the optimizer wasn't looking.
                  Both are O(1) reads; no scan, no sample.

  latency regret  harvested execution feedback (the PR-3 `ReplayBuffer`
                  keeps per-template best latencies): completions that
                  run far above their template's best are evidence the
                  plans chosen for this data are no longer the right
                  ones. Attributed to every base table the query touches.

  predictor error relative |predicted − actual| latency error of the QoS
                  `LatencyPredictor`: the learned model of the workload
                  disagreeing with reality is drift made legible even
                  when regret is masked (e.g. every execution of a
                  template degraded together).

A table with ZERO version lag scores 0.0 by construction — its data did
not change, so its statistics are not stale, and regret/error on it is a
policy problem, not a stats problem. For drifted tables the catalog-lag
magnitude is amplified by the execution evidence:

  score = (w_version·lag + w_rows·|ln(live/stats)|)
          · (1 + w_regret·regret̄ + w_pred·err̄)

with regret̄/err̄ windowed means over the last `window` completions
touching the table (capped so one 300s timeout cannot saturate the
score). Everything is a pure function of observed completions, so two
identical runs produce identical scores — pinned by tests/test_drift.py.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Dict, Iterable, List, Optional

__all__ = ["TableDrift", "DriftDetector"]


@dataclasses.dataclass(frozen=True)
class TableDrift:
    """One table's staleness assessment at scoring time."""
    table: str
    version_lag: int        # data-version bumps since last ANALYZE
    rows_ratio: float       # live rows / stats rows (1.0 = in sync)
    regret: float           # windowed mean latency regret (capped)
    pred_err: float         # windowed mean relative predictor error
    score: float

    @property
    def drifted(self) -> bool:
        return self.version_lag > 0


class DriftDetector:
    def __init__(self, *, window: int = 32, w_version: float = 0.25,
                 w_rows: float = 1.0, w_regret: float = 1.0,
                 w_pred: float = 1.0, regret_cap: float = 4.0,
                 err_cap: float = 4.0):
        self.window = window
        self.w_version, self.w_rows = w_version, w_rows
        self.w_regret, self.w_pred = w_regret, w_pred
        self.regret_cap, self.err_cap = regret_cap, err_cap
        # per-table data version at the last ANALYZE of that table
        self.stats_versions: Dict[str, int] = {}
        self._regret: Dict[str, deque] = {}
        self._pred_err: Dict[str, deque] = {}
        self.n_observed = 0

    # ------------------------------------------------------------ lifecycle
    def snapshot(self, db) -> None:
        """Baseline the catalog's per-table versions (call once when the
        controller attaches). `analyze()` stamps the versions its
        statistics were taken at, so staleness that PREDATES attachment
        is still measured as lag; hand-built Stats without a stamp fall
        back to 'in sync as of now'."""
        tables = db.stats.tables if db.stats is not None else db.tables
        stamped = getattr(db.stats, "versions", None) or {}
        for t in tables:
            self.stats_versions.setdefault(
                t, stamped.get(t, db.table_version(t)))

    def note_refreshed(self, table: str, version: int) -> None:
        """A re-ANALYZE of `table` landed at data version `version`: its
        catalog lag returns to zero and its execution-evidence windows
        restart (pre-refresh regret described plans chosen under the OLD
        statistics)."""
        self.stats_versions[table] = version
        self._regret.pop(table, None)
        self._pred_err.pop(table, None)

    # ------------------------------------------------------------ observing
    def observe(self, tables: Iterable[str], *,
                regret: Optional[float] = None,
                pred_err: Optional[float] = None) -> None:
        """Fold one completion's execution evidence into every base table
        the query touched."""
        self.n_observed += 1
        for t in tables:
            if regret is not None:
                self._regret.setdefault(
                    t, deque(maxlen=self.window)).append(regret)
            if pred_err is not None:
                self._pred_err.setdefault(
                    t, deque(maxlen=self.window)).append(pred_err)

    # -------------------------------------------------------------- scoring
    def _mean(self, dq: Optional[deque], cap: float) -> float:
        if not dq:
            return 0.0
        return min(sum(dq) / len(dq), cap)

    def score_table(self, db, table: str) -> TableDrift:
        lag = db.table_version(table) - self.stats_versions.get(table, 0)
        live = db.table(table).nrows
        ts = None if db.stats is None else db.stats.tables.get(table)
        believed = live if ts is None else ts.nrows
        ratio = (live / believed) if believed else math.inf
        regret = self._mean(self._regret.get(table), self.regret_cap)
        err = self._mean(self._pred_err.get(table), self.err_cap)
        if lag <= 0:
            score = 0.0            # data unchanged => stats are not stale
        else:
            # a table emptied or grown from nothing maxes the magnitude
            rows_drift = abs(math.log(ratio)) if 0.0 < ratio < math.inf \
                else 10.0
            score = (self.w_version * lag + self.w_rows * rows_drift) * \
                (1.0 + self.w_regret * regret + self.w_pred * err)
        return TableDrift(table, lag, round(ratio, 4) if ratio != math.inf
                          else math.inf, regret, err, score)

    def score(self, db) -> Dict[str, TableDrift]:
        """Score every table the catalog has statistics on, in sorted
        name order (deterministic iteration for every consumer)."""
        tables = db.stats.tables if db.stats is not None else db.tables
        return {t: self.score_table(db, t) for t in sorted(tables)}

    def top(self, db, k: int = 3) -> List[TableDrift]:
        ds = sorted(self.score(db).values(),
                    key=lambda d: (-d.score, d.table))
        return ds[:k]

    def stats(self) -> Dict[str, float]:
        return {"observed": self.n_observed,
                "tables_tracked": len(self.stats_versions)}
