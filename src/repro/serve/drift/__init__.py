"""Drift control plane: staleness as a measured, managed quantity.

The serving stack (PR 2-4) treats optimizer-statistics staleness as a
fixed fact — `analyze()` runs once, the QoS predictor is calibrated
one-shot, the policy-store gate probes a fixed list. This package closes
the remaining loop: DETECT how stale each table's statistics actually
are, then spend background cycles correcting whichever model of the data
drifted — the catalog, the latency predictor, or the gate's probe
coverage. Four cooperating pieces:

  detector.py    `DriftDetector` — per-table staleness scores fusing
                 catalog lag (`Database.versions` bumps + live/believed
                 row ratio), harvested latency regret (PR-3 replay), and
                 predicted-vs-actual latency error (PR-4 predictor).

  policy.py      `RefreshPolicy` — never / always / threshold / budgeted
                 re-ANALYZE policies; "never" is the paper's stale-stats
                 premise as the bit-identical baseline, the rest make
                 re-ANALYZE a benchmarked tradeoff (modeled cost
                 deterministic, wall cost reported).

  probes.py      `CoverageProbeSet` — re-samples the policy-store gate's
                 held-out probes to cover drifted templates/tables
                 instead of a fixed list.

  controller.py  `DriftController` — the scheduler hook tying it
                 together: feeds the detector per completion, schedules
                 incremental `catalog.analyze_table` runs as write-
                 barrier tasks (`LaneScheduler.schedule_barrier`),
                 refits the predictor from the live replay buffer
                 (generation-fenced), installs re-covered probe sets.

Everything decides from virtual-clock state, modeled costs and seeded
RNGs, so serving with the control plane attached stays bit-reproducible;
`benchmarks/bench_drift.py` sweeps refresh-policy x predictor-refresh
arms under a drifting delta workload. See serve/README.md for the
dataflow diagram.
"""
from repro.serve.drift.controller import DriftController, DriftStats
from repro.serve.drift.detector import DriftDetector, TableDrift
from repro.serve.drift.policy import RefreshDecision, RefreshPolicy
from repro.serve.drift.probes import CoverageProbeSet

__all__ = [
    "DriftController", "DriftStats",
    "DriftDetector", "TableDrift",
    "RefreshDecision", "RefreshPolicy",
    "CoverageProbeSet",
]
