"""Online serving subsystem: an async-lane query service on top of the
resumable `sql.executor.AdaptiveRun` suspension points.

Architecture
------------
Four cooperating pieces, each in its own module:

  cache.py      Runtime stage/statistics cache. Replaces the executor's
                ad-hoc clear-all dict: LRU eviction under a byte budget,
                per-table version tags baked into every signature (so a
                table update invalidates all derived entries in O(1) —
                stale signatures simply never match again and age out via
                LRU), and hit/miss/evict/invalidate counters.

  scheduler.py  The async lane scheduler. A fixed pool of lanes, each
                holding one suspended `AdaptiveRun`; at every tick,
                whichever lanes are currently suspended at a stage
                boundary are gathered into ONE batched policy call
                (`agent.act_batch`) — no global barrier. Lanes join and
                leave mid-flight; a finished lane is immediately refilled
                from the admission queue. Completion times live on a
                deterministic virtual clock (admission time + the run's
                simulated latency), so serial execution (n_lanes=1) and
                lockstep batching (policy="lockstep", the PR-1 engine)
                remain bit-reproducible special cases of the same loop.

  deltas.py     Delta-table dynamic workloads: append/delete batches that
                mutate the live database between queries and bump the
                per-table version, making stale cache entries provably
                wrong if ever served. The scheduler applies a delta as a
                write barrier: every query admitted before it drains
                first, every query after it sees the new version.

  driver.py     Streaming workload driver: open-loop (Poisson) arrivals
                instantiated from the JOB/ExtJOB/STACK templates, with
                optional interleaved delta batches.

  service.py    Façade tying it together: `QueryService.run(stream)`
                installs the cache, runs the scheduler, and reports
                throughput (qps), p50/p99 latency (with the queue-wait /
                in-lane breakdown), and cache hit rate — the numbers
                `benchmarks/bench_serve.py` persists to
                results/BENCH_serve.json. With a tenant registry the
                stats gain a per-tenant breakdown (SLO-miss rate,
                rejected/degraded counts, partition cache counters).

  recover/      Failure-recovery control plane: seeded FaultInjector,
                stage-resume retry ladder with re-planned OOM fallbacks,
                hedged stragglers, and the post-swap policy circuit
                breaker — all wired in through one `RecoveryManager`
                passed as `LaneScheduler(recovery=...)`. Inert by
                default: without it (or with the injector disabled and
                no retry/hedge/breaker) completions are bit-identical.

  obs/          Deterministic observability plane: per-query span trees
                on the virtual clock (`Tracer`), fixed-bucket metrics
                sampled into a time series (`MetricsRegistry`), Chrome-
                trace/JSONL export with a schema validator, a bounded
                flight recorder, and the trace-diff explainer. Attached
                via `QueryService(obs=Tracer())`; obs=None keeps every
                emit point short-circuited and completions bit-identical.

  qos/          SLO-aware multi-tenant control plane: tenant registry
                (token buckets, fair share, cache budgets), admission-
                time latency predictor, degradation ladder, and the
                pluggable `AdmissionPolicy` the scheduler consults —
                see qos/__init__.py and README.md.

Imports are lazy so that `sql.executor` can depend on `serve.cache`
without creating an import cycle through this package.
"""
from __future__ import annotations

_EXPORTS = {
    "StageCache": "repro.serve.cache",
    "CacheStats": "repro.serve.cache",
    "PartitionedStageCache": "repro.serve.cache",
    "Arrival": "repro.serve.scheduler",
    "Completion": "repro.serve.scheduler",
    "Rejection": "repro.serve.scheduler",
    "LaneScheduler": "repro.serve.scheduler",
    "DeltaBatch": "repro.serve.deltas",
    "apply_delta": "repro.serve.deltas",
    "open_loop_stream": "repro.serve.driver",
    "multi_tenant_stream": "repro.serve.driver",
    "TenantTraffic": "repro.serve.driver",
    "QueryService": "repro.serve.service",
    "ServiceStats": "repro.serve.service",
    "TenantStats": "repro.serve.service",
    "AdmissionPolicy": "repro.serve.qos",
    "DriftController": "repro.serve.drift",
    "DriftDetector": "repro.serve.drift",
    "RefreshPolicy": "repro.serve.drift",
    "CoverageProbeSet": "repro.serve.drift",
    "QoSAdmission": "repro.serve.qos",
    "FaultInjector": "repro.serve.recover",
    "ScriptedFaults": "repro.serve.recover",
    "RetryPolicy": "repro.serve.recover",
    "HedgePolicy": "repro.serve.recover",
    "PolicyBreaker": "repro.serve.recover",
    "RecoveryManager": "repro.serve.recover",
    "RecoveryStats": "repro.serve.recover",
    "Tracer": "repro.serve.obs",
    "MetricsRegistry": "repro.serve.obs",
    "FlightRecorder": "repro.serve.obs",
    "DegradationLadder": "repro.serve.qos",
    "LatencyPredictor": "repro.serve.qos",
    "TenantRegistry": "repro.serve.qos",
    "TenantSpec": "repro.serve.qos",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    target = _EXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(target), name)
