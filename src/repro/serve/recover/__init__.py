"""Failure-recovery control plane for the serving stack.

  faults    seeded virtual-clock FaultInjector (crash / transient / slow /
            stats-corruption), bit-identical when inert
  retry     per-query retry ladder: stage resume -> OOM fallback replan ->
            degradation-ladder handoff -> give up
  hedge     speculative execution for overrunning stragglers
  breaker   post-swap circuit breaker on the PolicyStore
  manager   RecoveryManager: wires all of it into one LaneScheduler run

See serve/README.md for the dataflow and failure-semantics table.
"""
from repro.serve.recover.breaker import PolicyBreaker
from repro.serve.recover.faults import (FaultEvent, FaultInjector, RunFaults,
                                        ScriptedFaults)
from repro.serve.recover.hedge import HedgePolicy
from repro.serve.recover.manager import RecoveryManager, RecoveryStats
from repro.serve.recover.retry import (RetryPolicy, RetryTicket,
                                       fallback_plan)

__all__ = ["FaultEvent", "FaultInjector", "RunFaults", "ScriptedFaults",
           "RetryPolicy", "RetryTicket", "fallback_plan", "HedgePolicy",
           "PolicyBreaker", "RecoveryManager", "RecoveryStats"]
