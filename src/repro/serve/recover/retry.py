"""Per-query retry ladder with virtual-clock backoff and stage resume.

A failure is the most actionable runtime observation there is, and the
retry ladder treats it the way LQRS treats every other runtime signal —
as input to re-optimization rather than a terminal verdict:

  1. resume   transient/timeout failures keep the failed attempt's
              materialized stage results (`RuntimeState.mats` survives the
              `QueryFailure`), so the retry is seeded with them and the
              remaining plan: it pays only the failed stage onwards on the
              virtual clock. A "crash" loses the lane's in-flight state —
              the retry restarts from scratch (the version-tagged stage
              cache still shortcuts the host-side numpy work).
  2. replan   an OOM is DETERMINISTIC — resuming or blindly re-running the
              same remaining plan hits the same blowup. The retry instead
              re-plans the remainder with fallback hints: broadcast hints
              stripped (a hinted BHJ past `executor_mem` is the one OOM a
              plan can force), and the remaining leaves re-folded greedy
              smallest-first by ACTUAL materialized bytes (estimates only
              where a leaf never materialized), refusing to re-try the
              exact join pair that just blew up when any alternative
              exists — runtime re-optimization applied to failure.
  3. ladder   on the final allowed attempt, an optional PR-4
              `DegradationLadder` + `LatencyPredictor` pair arbitrates:
              if the predicted retry cannot fit the query's remaining
              deadline slack, give up instead of burning a lane.
  4. give up  the failure is emitted as a normal failed Completion
              (tagged with its kind and attempt count).

Backoff is exponential on the virtual clock (`backoff * mult**(attempt-1)`)
and a total `budget_s` of failed-attempt seconds caps how much chaos one
query may absorb. Retries default to hook budget 0 (syntactic + rule-based
AQE, or the resumed/replanned remainder as-is): deterministic, cheap, and
never competing with first-run queries for policy bandwidth.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.sql.plans import (Leaf, Node, build_left_deep, copy_leaf, leaves)


def _next_join(node):
    """Leftmost-deepest join whose children are both leaves — the stage the
    executor was running when it failed (mirror of AdaptiveRun._drive)."""
    if isinstance(node, Leaf):
        return None
    j = _next_join(node.left)
    if j is not None:
        return j
    j = _next_join(node.right)
    if j is not None:
        return j
    if isinstance(node.left, Leaf) and isinstance(node.right, Leaf):
        return node
    return None


def fallback_plan(state) -> Optional[Node]:
    """Memory-safe replan of a failed run's REMAINING plan (see module
    docstring, rung 2). Returns None when no alternative left-deep fold
    exists — the caller then falls back to a plain restart."""
    plan = state.plan
    if isinstance(plan, Leaf):
        return None
    lvs = [copy_leaf(l) for l in leaves(plan)]
    if len(lvs) < 2:
        return None
    for l in lvs:
        l.broadcast_hint = False
    jn = _next_join(plan)
    banned = None if jn is None else \
        frozenset((jn.left.covered(), jn.right.covered()))
    q = state.query
    # smallest-first by actual materialized bytes where known (alias order
    # breaks ties so the fold is stream-independent)
    rest = sorted(lvs, key=lambda l: (state.leaf_bytes_est(l),
                                      tuple(sorted(l.covered()))))
    order = [rest.pop(0)]
    covered = frozenset(order[0].covered())
    while rest:
        pick = None
        for i, lf in enumerate(rest):
            if not q.conds_between(covered, frozenset(lf.covered())):
                continue
            if (len(order) == 1 and banned is not None
                    and frozenset((order[0].covered(), lf.covered()))
                    == banned):
                continue               # don't re-run the join that blew up
            pick = i
            break
        if pick is None:               # only the banned pair connects: take it
            for i, lf in enumerate(rest):
                if q.conds_between(covered, frozenset(lf.covered())):
                    pick = i
                    break
        if pick is None:
            return None                # disconnected remainder
        lf = rest.pop(pick)
        order.append(lf)
        covered |= lf.covered()
    return build_left_deep(q, order)


@dataclasses.dataclass
class RetryTicket:
    """Rides on a requeued Arrival: everything the next attempt needs."""
    attempt: int = 2                  # attempt number of the NEXT run
    mode: str = "restart"             # "restart" | "resume" | "replan"
    kinds: tuple = ()                 # failure kinds seen so far, in order
    spent_s: float = 0.0              # virtual seconds burned by failures
    plan: Optional[Node] = None       # remaining plan (resume/replan)
    mats: Optional[Dict] = None       # materialized stage results to seed
    stages_done: int = 0
    hook_budget: Optional[int] = 0    # 0 = no policy steps on retries
    first_admit_t: float = 0.0        # attempt 1's lane admission time
    hedge: bool = False               # speculative re-run, not a retry


@dataclasses.dataclass
class RetryDecision:
    ticket: RetryTicket
    delay: float                      # virtual backoff before re-admission


class RetryPolicy:
    """Decides whether/how a failed attempt is re-admitted."""

    def __init__(self, *, max_attempts: int = 3, backoff: float = 0.5,
                 backoff_mult: float = 2.0,
                 budget_s: Optional[float] = None,
                 resume: bool = True, fallback: bool = True,
                 hook_budget: Optional[int] = 0,
                 ladder=None, predictor=None):
        assert max_attempts >= 1
        self.max_attempts = max_attempts
        self.backoff, self.backoff_mult = backoff, backoff_mult
        self.budget_s = budget_s
        self.resume, self.fallback = resume, fallback
        self.hook_budget = hook_budget
        self.ladder, self.predictor = ladder, predictor

    def decide(self, arrival, ticket: Optional[RetryTicket], res, run,
               now: float, admit_t: float) -> Optional[RetryDecision]:
        """None = give up (emit the failure); else the requeue ticket.
        `run` is the failed AdaptiveRun (its .state carries the remaining
        plan and materialized stages); `now` the virtual failure time."""
        prev_attempt = 1 if ticket is None else ticket.attempt
        spent = (0.0 if ticket is None else ticket.spent_s) + res.latency
        first_admit = admit_t if ticket is None else ticket.first_admit_t
        kinds = (() if ticket is None else ticket.kinds) + (res.failure_kind,)
        if prev_attempt >= self.max_attempts:
            return None
        if self.budget_s is not None and spent >= self.budget_s:
            return None
        delay = self.backoff * self.backoff_mult ** (prev_attempt - 1)
        # final-attempt arbitration: hand off to the PR-4 degradation
        # ladder — a retry predicted to blow the remaining deadline slack
        # is given up (or degraded), not re-admitted on hope
        hook_budget = self.hook_budget
        if (prev_attempt + 1 == self.max_attempts and self.ladder is not None
                and self.predictor is not None
                and arrival.deadline is not None):
            pred = self.predictor.predict_query(arrival.query)
            slack = arrival.deadline - (now + delay)
            dec = self.ladder.choose(pred, slack)
            if dec.action == "reject":
                return None
            if dec.hook_budget is not None:
                hook_budget = dec.hook_budget

        kind = res.failure_kind
        mode, plan, mats, stages_done = "restart", None, None, 0
        if self.resume and kind != "crash" and run is not None:
            st = run.state
            plan, mats = st.plan, dict(st.mats)
            stages_done = st.stages_done
            mode = "resume"
            if kind == "oom" and not self.fallback:
                # deterministic failure and no replanning allowed: a
                # resume would OOM identically — restart from scratch
                # (exactly what a blind retry would do)
                mode, plan, mats, stages_done = "restart", None, None, 0
            elif kind in ("oom", "timeout") and self.fallback:
                fb = fallback_plan(st)
                if fb is not None:
                    plan, mode = fb, "replan"
                elif kind == "oom":
                    mode, plan, mats = "restart", None, None
                    stages_done = 0
        return RetryDecision(
            RetryTicket(attempt=prev_attempt + 1, mode=mode, kinds=kinds,
                        spent_s=spent, plan=plan, mats=mats,
                        stages_done=stages_done, hook_budget=hook_budget,
                        first_admit_t=first_admit),
            delay)
