"""Seeded, virtual-clock fault injection.

A `FaultInjector` is a pure function of its seed: every fault decision is
drawn from a counter-based PRNG keyed by `(seed, kind, seq, attempt, k)`,
so the schedule is independent of lane count, batching window, scheduling
policy and host execution order — the same chaos replays bit-identically
through every scheduler configuration (which is what lets the fault
benchmark run the SAME storm through every recovery variant), and a
retry or hedge (a new `attempt`) rolls fresh dice, the way a re-run on a
different executor escapes a flaky host but not a deterministic OOM.

Fault kinds (all priced on the virtual clock):

  crash      the lane dies mid-stage: a fraction of the stage's seconds is
             charged, the in-flight run is lost (`QueryFailure("crash")`),
             and resume state is NOT salvageable — a retry restarts from
             scratch (the stage cache still shortcuts the numpy work, but
             latency is always re-charged).
  transient  a stage-level error (fetch failure, shuffle corruption): same
             charging, but the attempt's materialized stages survive, so a
             resume retry pays only the failed stage.
  slow       a per-attempt straggler multiplier (slow executor / noisy
             neighbour): every charge of the attempt is stretched by
             `factor`; the run itself succeeds unless the stretch trips
             the timeout. Sampled once per (seq, attempt).
  corrupt    stats corruption at admission: the believed row count of one
             of the query's base tables is scaled by `corrupt_factor`
             (the catalog lies to the CBO — downstream plans go bad until
             a re-ANALYZE or a failure-driven replan fixes them). Applied
             by the RecoveryManager on first-attempt admissions only.

The injector is inert when `enabled=False` or every probability is 0 —
the executor seam then never fires and completions are bit-identical to
the injector-less stack (pinned by tests/test_recover.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.sql.executor import QueryFailure

# kind tags mixed into the PRNG key so the per-stage and per-run draws are
# independent streams
_K_STAGE, _K_RUN, _K_ADMIT = 1, 2, 3


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str                  # "crash" | "transient" | "slow" | "corrupt"
    seq: int
    attempt: int
    k: int = -1                # charge index within the attempt (-1 = run)
    factor: float = 1.0        # slowdown multiplier / corruption scale
    frac: float = 0.5          # fraction of the stage charged before abort
    table: str = ""            # corrupted table (kind == "corrupt")


class RunFaults:
    """Per-attempt view handed to `AdaptiveRun(faults=...)`: consulted at
    every latency charge, in the executor's deterministic charge order."""

    def __init__(self, injector: "FaultInjector", seq: int, attempt: int):
        self._inj = injector
        self._seq, self._attempt = seq, attempt
        self._k = 0
        self.slow_factor = injector.run_slowdown(seq, attempt)

    def charge(self, seconds: float, state) -> float:
        ev = self._inj.stage_fault(self._seq, self._attempt, self._k)
        self._k += 1
        seconds *= self.slow_factor
        if ev is None:
            return seconds
        # the stage dies part-way through: charge the wasted fraction, then
        # abort the run with the injected kind
        state.elapsed += seconds * ev.frac
        self._inj.log.append(ev)
        raise QueryFailure(ev.kind, f"injected at charge {ev.k}")


class FaultInjector:
    def __init__(self, seed: int = 0, *, p_crash: float = 0.0,
                 p_transient: float = 0.0, p_slow: float = 0.0,
                 slow_factor: Tuple[float, float] = (8.0, 32.0),
                 fault_frac: float = 0.5,
                 p_corrupt: float = 0.0, corrupt_factor: float = 0.02,
                 window: Optional[Tuple[int, int]] = None,
                 enabled: bool = True):
        """`window=(lo, hi)` confines every draw to stream positions
        lo <= seq < hi — a seeded fault BURST (an outage with a start and
        an end) instead of a uniform storm. Queries outside the window
        see an inert injector, and the counter-based keying means the
        in-window schedule is unchanged by the gate. Default None keeps
        the PR-6 uniform behavior bit-identical."""
        assert p_crash + p_transient <= 1.0
        self.seed = int(seed)
        self.p_crash, self.p_transient = p_crash, p_transient
        self.p_slow = p_slow
        self.slow_factor = slow_factor
        self.fault_frac = fault_frac
        self.p_corrupt, self.corrupt_factor = p_corrupt, corrupt_factor
        self.window = None if window is None else (int(window[0]),
                                                   int(window[1]))
        self.enabled = enabled
        self.log: List[FaultEvent] = []      # events that actually FIRED

    @property
    def active(self) -> bool:
        return self.enabled and (self.p_crash > 0 or self.p_transient > 0
                                 or self.p_slow > 0 or self.p_corrupt > 0)

    def _rng(self, kind_tag: int, seq: int, attempt: int, k: int = 0):
        return np.random.default_rng(
            (self.seed, kind_tag, seq, attempt, k))

    def _in_window(self, seq: int) -> bool:
        return self.window is None or \
            self.window[0] <= seq < self.window[1]

    # ---------------------------------------------------------- sampling
    def run_faults(self, seq: int, attempt: int) -> Optional[RunFaults]:
        """The fault profile for one attempt, or None when inert."""
        if not self.active:
            return None
        rf = RunFaults(self, seq, attempt)
        if rf.slow_factor != 1.0:
            self.log.append(FaultEvent("slow", seq, attempt,
                                       factor=rf.slow_factor))
        return rf

    def run_slowdown(self, seq: int, attempt: int) -> float:
        """Straggler multiplier for this attempt (1.0 = healthy)."""
        if not (self.enabled and self.p_slow > 0) \
                or not self._in_window(seq):
            return 1.0
        rng = self._rng(_K_RUN, seq, attempt)
        if rng.random() >= self.p_slow:
            return 1.0
        lo, hi = self.slow_factor
        return float(lo + (hi - lo) * rng.random())

    def stage_fault(self, seq: int, attempt: int, k: int) \
            -> Optional[FaultEvent]:
        """Crash/transient decision for the k-th charge of an attempt."""
        if not (self.enabled and (self.p_crash > 0 or self.p_transient > 0)) \
                or not self._in_window(seq):
            return None
        u = float(self._rng(_K_STAGE, seq, attempt, k).random())
        if u < self.p_crash:
            return FaultEvent("crash", seq, attempt, k,
                              frac=self.fault_frac)
        if u < self.p_crash + self.p_transient:
            return FaultEvent("transient", seq, attempt, k,
                              frac=self.fault_frac)
        return None

    def admit_corruption(self, seq: int, tables: List[str]) \
            -> Optional[FaultEvent]:
        """Stats-corruption decision at a first-attempt admission: scale
        the believed nrows of one of the query's tables (sorted order, so
        the pick is stream-independent)."""
        if not (self.enabled and self.p_corrupt > 0) or not tables \
                or not self._in_window(seq):
            return None
        rng = self._rng(_K_ADMIT, seq, 0)
        if rng.random() >= self.p_corrupt:
            return None
        table = sorted(tables)[int(rng.integers(len(tables)))]
        ev = FaultEvent("corrupt", seq, 0, factor=self.corrupt_factor,
                        table=table)
        self.log.append(ev)
        return ev

    # ------------------------------------------------------------- stats
    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.log:
            out[ev.kind] = out.get(ev.kind, 0) + 1
        return out


class ScriptedFaults(FaultInjector):
    """Deterministic test double: explicit events instead of sampling.

    `stage` maps (seq, attempt, charge_idx) -> "crash" | "transient";
    `slow` maps (seq, attempt) -> multiplier; `corrupt` maps seq ->
    (table, factor)."""

    def __init__(self, stage: Optional[dict] = None,
                 slow: Optional[dict] = None,
                 corrupt: Optional[dict] = None, fault_frac: float = 0.5):
        super().__init__(0, enabled=True, fault_frac=fault_frac)
        self._stage = dict(stage or {})
        self._slow = dict(slow or {})
        self._corrupt = dict(corrupt or {})

    @property
    def active(self) -> bool:
        return self.enabled and bool(self._stage or self._slow
                                     or self._corrupt)

    def run_slowdown(self, seq: int, attempt: int) -> float:
        return float(self._slow.get((seq, attempt), 1.0))

    def stage_fault(self, seq, attempt, k) -> Optional[FaultEvent]:
        kind = self._stage.get((seq, attempt, k))
        if kind is None:
            return None
        return FaultEvent(kind, seq, attempt, k, frac=self.fault_frac)

    def admit_corruption(self, seq, tables) -> Optional[FaultEvent]:
        hit = self._corrupt.get(seq)
        if hit is None:
            return None
        table, factor = hit
        ev = FaultEvent("corrupt", seq, 0, factor=factor, table=table)
        self.log.append(ev)
        return ev
