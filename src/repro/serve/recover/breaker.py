"""Post-swap circuit breaker on the PolicyStore.

The PR-3 gate is PRE-swap only: a candidate beats the incumbent on an
offline probe set, gets committed, and from then on nothing watches it.
A policy that probes well can still regress live — the probe set goes
stale under drift, and serving traffic exercises states the probe never
covered. The breaker closes that loop from LIVE completions:

  baseline   a rolling window of the last `window` completions (failure
             flags + latencies) is maintained at all times; when
             `store.serving_step` changes (a swap landed), the current
             window is frozen as the pre-swap baseline.
  watch      the next completions accumulate post-swap failure rate and
             mean latency; after at least `min_post` of them, the breaker
             TRIPS if post-swap failures exceed the baseline rate by
             `fail_margin` (absolute) or mean latency exceeds baseline x
             `latency_factor`.
  trip       `store.rollback(agent)` restores the newest version before
             the swapped step — the incumbent's exact params — and the
             store is forced into "shadow" mode for `cooldown`
             completions (candidates keep being scored but cannot swap),
             then restored to its prior mode. Trips are logged in
             `self.trips` as (completion seq, swapped step, restored
             step, reason).

Attached via the scheduler's `on_complete` hook (directly or through
`RecoveryManager(breaker=...)`), so detection and rollback land
deterministically between policy batches on the virtual clock.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional


class PolicyBreaker:
    def __init__(self, store, agent, *, window: int = 16,
                 min_post: int = 6, fail_margin: float = 0.2,
                 latency_factor: float = 2.0, cooldown: int = 32):
        self.store, self.agent = store, agent
        self.window, self.min_post = window, min_post
        self.fail_margin, self.latency_factor = fail_margin, latency_factor
        self.cooldown = cooldown
        self._hist: deque = deque(maxlen=window)   # (failed, latency)
        self._last_step = store.serving_step
        self._base: Optional[tuple] = None         # (fail_rate, mean_lat)
        self._watched_step = None
        self._post: List[tuple] = []
        self._cooldown_left = 0
        self._prior_mode: Optional[str] = None
        self.trips: List[tuple] = []
        self._sched = None

    # ------------------------------------------------------------- hooks
    def attach(self, scheduler) -> None:
        self._sched = scheduler
        if getattr(self.store, "obs", None) is None:
            self.store.obs = getattr(scheduler, "obs", None)
        scheduler.on_complete.append(self.on_complete)

    def _freeze_baseline(self) -> Optional[tuple]:
        if not self._hist:
            return None
        fails = sum(f for f, _ in self._hist)
        lats = [t for _, t in self._hist]
        return (fails / len(self._hist), sum(lats) / len(lats))

    def on_complete(self, comp) -> None:
        step = self.store.serving_step
        if step != self._last_step:
            # a swap (or an external rollback) landed since the last
            # completion: freeze the pre-swap window as the baseline and
            # start watching the new policy
            self._base = self._freeze_baseline()
            self._watched_step = step
            self._post = []
            self._last_step = step
        self._hist.append((bool(comp.result.failed), float(comp.latency)))
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
            if self._cooldown_left == 0 and self._prior_mode is not None:
                self.store.mode = self._prior_mode
                self._prior_mode = None
            return
        if self._base is None or self._watched_step is None:
            return
        self._post.append((bool(comp.result.failed), float(comp.latency)))
        if len(self._post) < self.min_post:
            return
        base_fail, base_lat = self._base
        post_fail = sum(f for f, _ in self._post) / len(self._post)
        post_lat = sum(t for _, t in self._post) / len(self._post)
        reason = None
        if post_fail > base_fail + self.fail_margin:
            reason = (f"failure rate {post_fail:.2f} > "
                      f"baseline {base_fail:.2f} + {self.fail_margin}")
        elif base_lat > 0 and post_lat > base_lat * self.latency_factor:
            reason = (f"mean latency {post_lat:.1f}s > "
                      f"{self.latency_factor}x baseline {base_lat:.1f}s")
        if reason is None:
            return
        self._trip(comp.seq, reason)

    def note_external_evidence(self, seq: int, reason: str) -> bool:
        """Opt-in alert path (serve.obs.AlertHooks): an external monitor
        attributed a live regression to the watched swap — trip NOW
        instead of waiting for `min_post` completions. Ignored (returns
        False) when no swap is under watch or a trip is already cooling
        down, so spurious alerts cannot roll back a policy the breaker
        is not even suspicious of."""
        if self._watched_step is None or self._cooldown_left > 0:
            return False
        self._trip(seq, f"external evidence: {reason}")
        return True

    def _trip(self, seq: int, reason: str) -> None:
        bad = self._watched_step
        obs = getattr(self._sched, "obs", None)
        if obs is not None:
            # emitted before the rollback so the flight-recorder dump
            # captures the pre-rollback record tail
            obs.event("breaker_trip", {"seq": seq, "step": bad,
                                       "reason": reason})
        restored = self.store.rollback(self.agent)
        self.trips.append((seq, bad, restored, reason))
        # cooldown: shadow mode — candidates keep being scored, no swaps
        if self._prior_mode is None:
            self._prior_mode = self.store.mode
        self.store.mode = "shadow"
        self._cooldown_left = self.cooldown
        # the rollback itself changes serving_step; don't treat it as a
        # fresh swap to watch
        self._last_step = self.store.serving_step
        self._base, self._watched_step, self._post = None, None, []
