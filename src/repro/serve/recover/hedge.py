"""Speculative execution (hedged requests) on the virtual clock.

When a suspended run's elapsed virtual seconds already exceed
`factor x` its predicted latency and an idle lane exists, the scheduler
launches a HEDGE: a fresh attempt of the same query, admitted on the idle
lane at the stage boundary where the overrun became observable. The two
attempts race; the first virtual finisher wins (a success beats an
earlier failure) and the loser is cancelled — its lane is charged until
the winner's finish and not a second longer, the honest virtual-clock
analogue of killing a speculative task.

Why it works under the fault model: straggler ("slow") faults are drawn
per ATTEMPT — a hedge rolls new dice, so a run stuck behind a 8-32x lane
multiplier is rescued by a healthy re-run at the cost of one idle lane.
Deterministic failures (a plan that OOMs) are NOT rescued — both
attempts hit them, which is the retry ladder's job, not the hedge's.

Predictions come from the admission-time estimate when one exists
(`Completion.predicted`, the PR-4 `LatencyPredictor` path) and otherwise
from this policy's own `predictor` (anything with
`predict_query(q) -> seconds | None`). No prediction = no hedge.
"""
from __future__ import annotations

from typing import Optional


class HedgePolicy:
    def __init__(self, *, factor: float = 3.0, predictor=None,
                 min_predicted: float = 0.0, hook_budget: Optional[int] = None,
                 max_hedges: Optional[int] = None):
        """`factor`: overrun multiple that triggers a hedge. `min_predicted`
        filters sub-second queries not worth a lane. `hook_budget`: policy
        steps for the hedge run (None = same as the primary). `max_hedges`
        caps speculative launches per scheduler run."""
        assert factor > 1.0
        self.factor = factor
        self.predictor = predictor
        self.min_predicted = min_predicted
        self.hook_budget = hook_budget
        self.max_hedges = max_hedges

    def predicted(self, lane) -> Optional[float]:
        if lane.predicted is not None:
            return lane.predicted
        if self.predictor is not None:
            return self.predictor.predict_query(lane.arrival.query)
        return None

    def should_hedge(self, lane, n_launched: int) -> bool:
        """Overrun test for one suspended lane (idleness and pair state are
        the manager's job)."""
        if self.max_hedges is not None and n_launched >= self.max_hedges:
            return False
        pred = self.predicted(lane)
        if pred is None or pred < self.min_predicted:
            return False
        return lane.state.elapsed >= self.factor * pred
