"""RecoveryManager: wires the failure-recovery control plane into one
`LaneScheduler` run.

The scheduler owns the virtual clock and the lanes; the manager owns the
recovery POLICY, hooked in at three seams:

  admission   `run_faults` hands each attempt its seeded fault profile
              (fresh dice per attempt); `on_admit` applies stats-corruption
              events to the believed catalog on first-attempt admissions.
  completion  `on_finish` intercepts every finished run BEFORE the
              scheduler emits it. A failed attempt is offered to the
              `RetryPolicy` — on a retry decision the arrival is requeued
              (with its `RetryTicket`: resume state, fallback plan,
              backoff floor) ahead of the next write barrier and the lane
              is freed at the failure time; the Completion is emitted only
              by the FINAL attempt, carrying `attempts`/`recovered`/
              `failure_kind`. Members of a hedge pair are stashed (their
              lane stays HELD — occupied on the virtual clock, invisible
              to admission and write barriers) until both finish, then the
              pair resolves: first virtual finisher wins (a success beats
              an earlier failure), the winner emits as the query's
              completion, and the loser's lane is charged only up to the
              winner's finish — cancellation priced honestly.
  tick        `maybe_hedge` runs after each admission pass: any suspended
              lane whose elapsed virtual seconds exceed `factor x
              predicted` gets a speculative re-run on an idle lane,
              admitted at the boundary where the overrun became
              observable.

Requeued retries keep their original `seq` (one Completion per query, in
stream order) and re-enter the pending queue ahead of the next delta, so
deltas remain STRICT write barriers: everything ahead of a delta in
stream order — including its retries — drains before the delta applies.

With the injector inert and retry/hedge/breaker unset the manager is a
no-op wrapper: every seam returns early and completions are bit-identical
to a scheduler without a recovery plane (pinned by tests/test_recover.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.serve.recover.faults import FaultInjector
from repro.serve.recover.hedge import HedgePolicy
from repro.serve.recover.retry import RetryPolicy, RetryTicket

# hedge attempts draw fault dice from a disjoint attempt namespace: a
# hedge of attempt k is keyed k + 1000, so it re-rolls everything (that is
# the point — a fresh executor) without colliding with retry attempts
_HEDGE_ATTEMPT_BASE = 1000


@dataclasses.dataclass
class RecoveryStats:
    n_failures: int = 0            # failed attempts observed
    n_retries: int = 0             # requeued attempts
    n_resumed: int = 0
    n_replanned: int = 0
    n_restarted: int = 0
    n_given_up: int = 0            # failures emitted after the ladder ended
    n_hedges: int = 0              # speculative runs launched
    n_hedge_wins: int = 0          # the hedge side finished first
    n_hedge_cancelled: int = 0     # loser cancelled before its own finish
    corruptions: int = 0           # stats-corruption events applied
    backoff_s: float = 0.0         # virtual seconds spent backing off
    by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _HedgePair:
    arr: object                    # the query's ORIGINAL arrival
    primary_idx: int
    hedge_idx: int
    primary: Optional[dict] = None   # stash: traj/res/finish_t/admit_t/...
    hedge: Optional[dict] = None


class RecoveryManager:
    def __init__(self, *, injector: Optional[FaultInjector] = None,
                 retry: Optional[RetryPolicy] = None,
                 hedge: Optional[HedgePolicy] = None,
                 breaker=None):
        self.injector = injector
        self.retry = retry
        self.hedge = hedge
        self.breaker = breaker
        self.sched = None
        self.stats = RecoveryStats()
        self._pairs: Dict[int, tuple] = {}     # lane idx -> (pair, role)
        self._hedged = set()                   # (seq, attempt) already hedged

    # ------------------------------------------------------------ attach
    def attach(self, scheduler) -> None:
        """Reset per-run state and bind to `scheduler` (the scheduler calls
        this from __init__ when constructed with recovery=...)."""
        self.sched = scheduler
        self.stats = RecoveryStats()
        self._pairs = {}
        self._hedged = set()
        if self.breaker is not None:
            self.breaker.attach(scheduler)

    # --------------------------------------------------------- admission
    def run_faults(self, arrival):
        """Fault profile for the attempt this admission starts."""
        if self.injector is None or not self.injector.active:
            return None
        t = arrival.ticket
        attempt = 1 if t is None else t.attempt
        if t is not None and t.hedge:
            attempt += _HEDGE_ATTEMPT_BASE
        return self.injector.run_faults(arrival.seq, attempt)

    def on_admit(self, arrival, admit_t: float) -> None:
        """Stats-corruption events land here (first attempts only): the
        believed nrows of one of the query's tables is scaled — the
        catalog starts lying to every later CBO/policy decision."""
        if self.injector is None or arrival.ticket is not None:
            return
        q = arrival.query
        tables = sorted({r.table for r in q.relations})
        ev = self.injector.admit_corruption(arrival.seq, tables)
        if ev is None:
            return
        seen = set()
        for stats in (self.sched.db.stats, self.sched.est.stats):
            if stats is None or id(stats) in seen:
                continue
            seen.add(id(stats))
            ts = stats.tables.get(ev.table)
            if ts is not None:
                ts.nrows = max(1, int(ts.nrows * ev.factor))
        self.stats.corruptions += 1

    # -------------------------------------------------------- completion
    def on_finish(self, lane, traj, res, finish_t: float) -> bool:
        """True = the manager consumed this finish (requeued or stashed);
        the scheduler must not emit a Completion for it."""
        pr = self._pairs.get(lane.idx)
        if pr is not None:
            pair, role = pr
            stash = {"traj": traj, "res": res, "finish_t": finish_t,
                     "admit_t": lane.admit_t, "lane": lane, "run": lane.run,
                     "hook_budget": lane.hook_budget,
                     "degraded": lane.degraded, "predicted": lane.predicted}
            setattr(pair, role, stash)
            lane.held = finish_t       # stays occupied until the pair resolves
            if pair.primary is not None and pair.hedge is not None:
                self._resolve(pair)
            return True
        if not res.failed:
            return False
        self.stats.n_failures += 1
        self.stats.by_kind[res.failure_kind] = \
            self.stats.by_kind.get(res.failure_kind, 0) + 1
        if self.retry is None:
            return False
        arr = lane.arrival
        dec = self.retry.decide(arr, arr.ticket, res, lane.run, finish_t,
                                lane.admit_t)
        if dec is None:
            self.stats.n_given_up += 1
            return False
        self._requeue(arr, dec, finish_t)
        self.sched._release(lane, finish_t)
        return True

    def _requeue(self, arr, dec, finish_t: float) -> None:
        t = dec.ticket
        obs = getattr(self.sched, "obs", None)
        if obs is not None:
            obs.on_retry(arr.seq, t.attempt, t.mode,
                         t.kinds[-1] if t.kinds else "", finish_t,
                         dec.delay)
        self.stats.n_retries += 1
        field = {"resume": "n_resumed", "replan": "n_replanned",
                 "restart": "n_restarted"}[t.mode]
        setattr(self.stats, field, getattr(self.stats, field) + 1)
        self.stats.backoff_s += dec.delay
        arr.ticket = t
        arr.not_before = max(arr.not_before, finish_t + dec.delay)
        # re-enter the pending queue ahead of the next write barrier,
        # positioned by effective ready time so the backoff never
        # head-of-line-blocks other admissions
        pending = self.sched._pending
        ready = max(arr.t, arr.not_before)
        idx = len(pending)
        for i, a in enumerate(pending):
            if a.delta is not None or max(a.t, a.not_before) > ready:
                idx = i
                break
        pending.insert(idx, arr)

    # ------------------------------------------------------------ hedging
    def maybe_hedge(self) -> None:
        """Called by the run loop after each admission pass: launch hedges
        for overrunning suspended lanes while idle lanes remain."""
        if self.hedge is None:
            return
        sched = self.sched
        idle = [l for l in sched.lanes if l.run is None]
        if not idle:
            return
        for lane in sched.lanes:
            if not idle:
                break
            if lane.run is None or lane.state is None:
                continue               # no run, or held/completed
            if lane.idx in self._pairs:
                continue               # already racing
            arr = lane.arrival
            att = 1 if arr.ticket is None else arr.ticket.attempt
            if (arr.seq, att) in self._hedged:
                continue
            if not self.hedge.should_hedge(lane, self.stats.n_hedges):
                continue
            h = min(idle, key=lambda l: (l.free_at, l.idx))
            idle.remove(h)
            self._hedged.add((arr.seq, att))
            self.stats.n_hedges += 1
            t_b = lane.next_event      # the boundary that revealed the overrun
            admit = max(t_b, h.free_at, sched._write_ts)
            budget = self.hedge.hook_budget if self.hedge.hook_budget \
                is not None else lane.hook_budget
            hedge_ticket = RetryTicket(
                attempt=att, mode="restart", kinds=(),
                spent_s=0.0 if arr.ticket is None else arr.ticket.spent_s,
                plan=None, mats=None, stages_done=0, hook_budget=budget,
                first_admit_t=(lane.admit_t if arr.ticket is None
                               else arr.ticket.first_admit_t),
                hedge=True)            # disjoint fault-dice namespace
            hedge_arr = dataclasses.replace(arr, ticket=hedge_ticket)
            pair = _HedgePair(arr=arr, primary_idx=lane.idx,
                              hedge_idx=h.idx)
            self._pairs[lane.idx] = (pair, "primary")
            self._pairs[h.idx] = (pair, "hedge")
            if getattr(sched, "obs", None) is not None:
                sched.obs.on_hedge_launch(arr.seq, att, lane.idx, h.idx,
                                          admit)
            sched._start(h, hedge_arr, admit,
                         hook_budget=budget, degraded=lane.degraded,
                         predicted=lane.predicted)

    def _resolve(self, pair: _HedgePair) -> None:
        sched = self.sched
        p, h = pair.primary, pair.hedge
        # winner: successes first, then earlier virtual finish, tie->primary
        winner, loser, hedge_won = (p, h, False) \
            if (p["res"].failed, p["finish_t"]) \
            <= (h["res"].failed, h["finish_t"]) else (h, p, True)
        # the loser is cancelled when the winner finishes: its lane is
        # charged min(own finish, winner finish) — never less than what it
        # actually ran, never more than the race took
        loser_free = min(loser["finish_t"], winner["finish_t"])
        if loser_free < loser["finish_t"]:
            self.stats.n_hedge_cancelled += 1
        del self._pairs[pair.primary_idx]
        del self._pairs[pair.hedge_idx]
        sched._release(loser["lane"], loser_free)
        sched._release(winner["lane"], winner["finish_t"])
        if getattr(sched, "obs", None) is not None:
            sched.obs.event("hedge_resolve",
                            {"seq": pair.arr.seq, "hedge_won": hedge_won,
                             "cancelled": loser_free < loser["finish_t"]},
                            t=winner["finish_t"])
        if hedge_won:
            self.stats.n_hedge_wins += 1
        arr = pair.arr
        res = winner["res"]
        if res.failed:
            self.stats.n_failures += 1
            self.stats.by_kind[res.failure_kind] = \
                self.stats.by_kind.get(res.failure_kind, 0) + 1
            if self.retry is not None:
                dec = self.retry.decide(arr, arr.ticket, res, winner["run"],
                                        winner["finish_t"],
                                        winner["admit_t"])
                if dec is not None:
                    self._requeue(arr, dec, winner["finish_t"])
                    return
                self.stats.n_given_up += 1
        first_admit = arr.ticket.first_admit_t if arr.ticket is not None \
            else min(p["admit_t"], h["admit_t"])
        comp = sched._build_comp(
            arr, winner["traj"], res, winner["admit_t"], winner["finish_t"],
            winner["lane"].idx, winner["hook_budget"], winner["degraded"],
            winner["predicted"], hedged=True, first_admit=first_admit)
        sched._emit(comp)
