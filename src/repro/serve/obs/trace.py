"""Deterministic per-query tracing on the serving virtual clock.

One `Tracer` observes one `LaneScheduler` run through (a) the existing
`on_complete` / `on_delta` hooks and (b) narrow emit points guarded by
`if scheduler.obs is not None` in the scheduler, executor, recovery
manager, drift controller, policy store, breaker and learner. Every
timestamp is VIRTUAL time, so two runs of the same seeded stream produce
identical traces — and with `obs=None` every emit point short-circuits,
keeping completions bit-identical to an untraced scheduler (pinned by
tests/test_obs.py).

Data model
----------
  Span    one timed interval [t0, t1] in a per-query tree. Categories:
            query    root, [arrival_t, finish_t]
            queue    arrival -> first lane admission
            execute  the attempt that produced the Completion
            retry    a failed earlier attempt, or a backoff interval
            hedge    the losing side of a speculative race
            stage    a scan or join inside an attempt (cache hit/miss,
                     actual vs estimated rows)
            hook     a policy decision at a stage boundary (zero virtual
                     width — decisions are free on this clock; the host
                     cost stays in Trajectory.hook_seconds)
  Event   an instant control-plane occurrence (delta_apply, barrier_task,
          retry_scheduled, hedge_launch, policy_commit/swap/rollback,
          gate_eval, breaker_trip, refit, re_analyze, learner_update,
          admission_reject, ...), timestamped on the virtual clock.

Attempt lifecycle. The scheduler opens a live attempt record at `_start`
(`on_admit`, which also returns the `RunTrace` sink the executor writes
scan/join/failure notes into), annotates it at `_decide` / `_finish`,
and archives it at `_release` — which runs BEFORE `on_complete` fires
and before a hedge pair `_resolve`s its emit, so by assembly time every
attempt of a query is closed. `_on_complete` then builds the span tree:
stage offsets (executor `state.elapsed` seconds) are rebased onto
`admit_t` and clamped into the attempt interval — a timeout's last
charge runs past the priced attempt end, and a cancelled hedge loser is
only charged to the winner's finish.

The flight recorder is a bounded ring of the most recent span/event
records; `Tracer.dump(reason)` snapshots it (on failed completions and
breaker/rollback events automatically), so a long run keeps post-mortem
context for the last N happenings without unbounded growth.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

from repro.serve.obs.metrics import (LATENCY_BOUNDS, MARGIN_BOUNDS,
                                     MetricsRegistry)

__all__ = ["SCHEMA_VERSION", "Span", "Event", "RunTrace", "FlightRecorder",
           "Tracer"]

SCHEMA_VERSION = 1

# control-plane event kinds that snapshot the flight recorder on arrival
_DUMP_KINDS = frozenset({"breaker_trip", "policy_rollback"})


@dataclasses.dataclass
class Span:
    span_id: int
    parent_id: int                 # -1 = root
    seq: int                       # query stream position (-1 = none)
    name: str
    cat: str                       # query|queue|execute|retry|hedge|stage|hook
    t0: float
    t1: float
    lane: int = -1
    attrs: Dict = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def as_dict(self) -> Dict:
        return {"type": "span", "id": self.span_id, "parent": self.parent_id,
                "seq": self.seq, "name": self.name, "cat": self.cat,
                "t0": round(self.t0, 9), "t1": round(self.t1, 9),
                "lane": self.lane, "attrs": self.attrs}


@dataclasses.dataclass
class Event:
    t: float
    kind: str
    attrs: Dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict:
        return {"type": "event", "t": round(self.t, 9), "kind": self.kind,
                "attrs": self.attrs}


class RunTrace:
    """Per-attempt sink the executor writes into (duck-typed: the executor
    never imports the obs package). Offsets are `state.elapsed` seconds —
    the tracer rebases them onto the attempt's admit time at assembly."""

    __slots__ = ("stages", "failure")

    def __init__(self):
        self.stages: List[Dict] = []
        self.failure: Optional[Dict] = None

    def scan(self, alias: str, e0: float, e1: float, rows: int,
             hit: bool) -> None:
        self.stages.append({"name": f"scan:{alias}", "e0": e0, "e1": e1,
                            "rows": int(rows), "hit": bool(hit)})

    def stage(self, tables, method: str, e0: float, e1: float, out_rows: int,
              est_rows: Optional[float], shuffles: int, hit: bool) -> None:
        self.stages.append({
            "name": f"join:{method}:" + "+".join(sorted(tables)),
            "e0": e0, "e1": e1, "rows": int(out_rows),
            "est_rows": None if est_rows is None else float(est_rows),
            "shuffles": int(shuffles), "hit": bool(hit)})

    def fail(self, kind: str, elapsed: float) -> None:
        self.failure = {"kind": kind, "elapsed": float(elapsed)}


class FlightRecorder:
    """Bounded ring over recent span/event dicts + snapshot-on-demand."""

    def __init__(self, capacity: int = 256, max_dumps: int = 16):
        self.capacity = int(capacity)
        self.max_dumps = int(max_dumps)
        self._ring: deque = deque(maxlen=self.capacity)
        self.dumps: List[Dict] = []

    def record(self, d: Dict) -> None:
        self._ring.append(d)

    def snapshot(self, reason: str, t: float) -> Optional[Dict]:
        if len(self.dumps) >= self.max_dumps:
            return None                 # bounded post-mortem state
        dump = {"type": "dump", "reason": reason, "t": round(t, 9),
                "n": len(self._ring), "records": list(self._ring)}
        self.dumps.append(dump)
        return dump

    def reset(self) -> None:
        self._ring.clear()
        self.dumps.clear()


@dataclasses.dataclass
class _Attempt:
    """Live (then archived) record of one lane admission of one query."""
    seq: int
    attempt: int                   # 1-based; hedges reuse the primary's
    lane: int
    admit_t: float
    hedge: bool
    tenant: str
    rtrace: RunTrace
    decisions: List[Dict] = dataclasses.field(default_factory=list)
    run_finish_t: Optional[float] = None
    failed: bool = False
    kind: str = ""
    end_t: Optional[float] = None  # lane free_at (cancel-aware)


class Tracer:
    """Assembles per-query span trees + control-plane event log + metrics
    from a scheduler run. Attach via `QueryService(obs=Tracer())` or
    `tracer.attach(scheduler)` directly."""

    def __init__(self, *, metrics: Optional[MetricsRegistry] = None,
                 flight_capacity: int = 256):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.flight = FlightRecorder(flight_capacity)
        self.spans: List[Span] = []
        self.events: List[Event] = []
        self.now = 0.0                 # high-water virtual time
        self._sched = None
        self._next_id = 0
        self._live: Dict[int, _Attempt] = {}      # lane idx -> open attempt
        self._closed: Dict[int, List[_Attempt]] = {}   # seq -> archived
        self._backoffs: Dict[int, List[Dict]] = {}     # seq -> retry waits
        self._by_seq: Dict[int, List[Span]] = {}       # seq -> its spans

    # -------------------------------------------------------------- attach
    def attach(self, scheduler) -> None:
        self._sched = scheduler
        scheduler.obs = self
        scheduler.on_complete.append(self._on_complete)
        scheduler.on_delta.append(self._on_delta)
        m = self.metrics
        m.gauge("lanes_busy",
                fn=lambda s=scheduler: sum(l.run is not None
                                           for l in s.lanes))
        m.gauge("queue_depth", fn=lambda s=scheduler: len(s._pending))
        m.gauge("cache_bytes",
                fn=lambda s=scheduler: float(getattr(
                    getattr(s.db, "_stage_cache", None), "bytes", 0) or 0))
        # give the policy store (if any hook installs one later) a path
        # back to this tracer: PolicyStore reads scheduler.obs lazily.

    # ---------------------------------------------------------- virtual now
    def _advance(self, t: float) -> None:
        if t > self.now:
            self.now = t
        self.metrics.advance(self.now)

    # --------------------------------------------------- scheduler emit API
    def on_admit(self, lane, arrival, admit_t: float) -> RunTrace:
        """A lane admission starts an attempt; returns the executor sink."""
        ticket = arrival.ticket
        att = 1 if ticket is None else ticket.attempt
        hedge = bool(ticket is not None and getattr(ticket, "hedge", False))
        rt = RunTrace()
        self._live[lane.idx] = _Attempt(
            seq=arrival.seq, attempt=att, lane=lane.idx, admit_t=admit_t,
            hedge=hedge, tenant=arrival.tenant, rtrace=rt)
        self.metrics.counter("attempts").inc()
        if hedge:
            self.metrics.counter("hedges").inc()
        self._advance(admit_t)
        return rt

    def on_decide(self, lane, t: float, decoded: str, reward: float) -> None:
        a = self._live.get(lane.idx)
        if a is not None:
            a.decisions.append({"t": t, "action": str(decoded),
                                "reward": float(reward)})
        self._advance(t)

    def on_run_finish(self, lane, res, finish_t: float) -> None:
        """The run produced its RunResult (BEFORE recovery interception)."""
        a = self._live.get(lane.idx)
        if a is not None:
            a.run_finish_t = finish_t
            a.failed = bool(res.failed)
            a.kind = res.failure_kind
        self._advance(finish_t)

    def on_release(self, lane, free_at: float) -> None:
        """The lane frees: archive its attempt, closed at `free_at` (for a
        cancelled hedge loser that is the winner's finish, not its own)."""
        a = self._live.pop(lane.idx, None)
        if a is None:
            return
        a.end_t = free_at
        self._closed.setdefault(a.seq, []).append(a)
        self._advance(free_at)

    def on_retry(self, seq: int, attempt: int, mode: str, kind: str,
                 t_fail: float, delay: float) -> None:
        self._backoffs.setdefault(seq, []).append(
            {"t0": t_fail, "t1": t_fail + delay, "mode": mode, "kind": kind,
             "attempt": attempt})
        self.event("retry_scheduled", {"seq": seq, "attempt": attempt,
                                       "mode": mode, "kind": kind,
                                       "delay": round(delay, 6)}, t=t_fail)
        self.metrics.counter("retries").inc()

    def on_hedge_launch(self, seq: int, attempt: int, primary_lane: int,
                        hedge_lane: int, t: float) -> None:
        self.event("hedge_launch", {"seq": seq, "attempt": attempt,
                                    "primary_lane": primary_lane,
                                    "hedge_lane": hedge_lane}, t=t)

    def on_tick(self, t: float) -> None:
        self._advance(t)

    def event(self, kind: str, attrs: Optional[Dict] = None,
              t: Optional[float] = None) -> None:
        """Generic control-plane event (drift/policy/breaker/learner emit
        points call this through `scheduler.obs` / `store.obs`)."""
        ts = self.now if t is None else float(t)
        ev = Event(ts, kind, dict(attrs or {}))
        self.events.append(ev)
        self.flight.record(ev.as_dict())
        self.metrics.counter(f"events[{kind}]").inc()
        self._advance(ts)
        if kind in _DUMP_KINDS:
            self.flight.snapshot(kind, ts)

    # ------------------------------------------------------------ assembly
    def _sid(self) -> int:
        self._next_id += 1
        return self._next_id

    def _add(self, span: Span) -> Span:
        self.spans.append(span)
        self._by_seq.setdefault(span.seq, []).append(span)
        self.flight.record(span.as_dict())
        return span

    def _on_complete(self, comp) -> None:
        attempts = self._closed.pop(comp.seq, [])
        backoffs = self._backoffs.pop(comp.seq, [])
        root = self._add(Span(
            self._sid(), -1, comp.seq, f"q{comp.seq}", "query",
            comp.arrival_t, comp.finish_t, lane=comp.lane, attrs={
                "tenant": comp.tenant, "attempts": comp.attempts,
                "failed": bool(comp.result.failed),
                "failure_kind": comp.failure_kind,
                "recovered": bool(comp.recovered),
                "hedged": bool(comp.hedged),
                "degraded": bool(comp.degraded),
                "queue_wait": round(comp.queue_wait, 9)}))
        first_admit = min([a.admit_t for a in attempts],
                          default=comp.admit_t)
        if first_admit > comp.arrival_t:
            self._add(Span(self._sid(), root.span_id, comp.seq, "queue",
                           "queue", comp.arrival_t, first_admit))
        # the attempt that produced the Completion is the execute span;
        # other hedge-flagged attempts are `hedge`, everything else `retry`
        n_real = 0
        for a in sorted(attempts, key=lambda x: (x.admit_t, x.lane)):
            if not a.hedge:
                n_real += 1
            final = (a.admit_t == comp.admit_t and a.lane == comp.lane)
            cat = "execute" if final else ("hedge" if a.hedge else "retry")
            end = a.end_t if a.end_t is not None else a.admit_t
            cancelled = (a.run_finish_t is not None and end < a.run_finish_t)
            sp = self._add(Span(
                self._sid(), root.span_id, comp.seq,
                f"attempt-{a.attempt}" + ("h" if a.hedge else ""), cat,
                a.admit_t, end, lane=a.lane, attrs={
                    "attempt": a.attempt, "hedge": a.hedge,
                    "failed": a.failed, "failure_kind": a.kind,
                    "cancelled": cancelled}))
            for st in a.rtrace.stages:
                # rebase executor elapsed-offsets onto the admit time and
                # clamp into the attempt: a timeout's final charge runs
                # past the priced end, a cancelled loser past its free_at
                t0 = min(max(a.admit_t + st["e0"], sp.t0), sp.t1)
                t1 = min(max(a.admit_t + st["e1"], sp.t0), sp.t1)
                attrs = {k: v for k, v in st.items()
                         if k not in ("name", "e0", "e1")}
                self._add(Span(self._sid(), sp.span_id, comp.seq,
                               st["name"], "stage", t0, t1, lane=a.lane,
                               attrs=attrs))
                if st.get("hit"):
                    self.metrics.counter("stage_cache_hits").inc()
            for dec in a.decisions:
                td = min(max(dec["t"], sp.t0), sp.t1)
                self._add(Span(self._sid(), sp.span_id, comp.seq, "hook",
                               "hook", td, td, lane=a.lane,
                               attrs={"action": dec["action"],
                                      "reward": round(dec["reward"], 6)}))
            if a.rtrace.failure is not None:
                sp.attrs["fail_elapsed"] = round(
                    a.rtrace.failure["elapsed"], 9)
        for b in backoffs:
            self._add(Span(self._sid(), root.span_id, comp.seq,
                           f"backoff-{b['attempt']}", "retry",
                           b["t0"], min(b["t1"], comp.finish_t),
                           attrs={"mode": b["mode"], "kind": b["kind"]}))
        # ---- metrics
        m = self.metrics
        m.counter("completions").inc()
        if comp.result.failed:
            m.counter("failures").inc()
            m.counter(f"failures[{comp.failure_kind or 'unknown'}]").inc()
        if comp.recovered:
            m.counter("recovered").inc()
        if comp.hedged:
            m.counter("hedged").inc()
        m.histogram("latency", LATENCY_BOUNDS).observe(comp.latency)
        m.histogram("queue_wait", LATENCY_BOUNDS).observe(comp.queue_wait)
        if comp.deadline is not None:
            m.histogram(f"slo_margin[{comp.tenant}]", MARGIN_BOUNDS) \
                .observe(comp.deadline - comp.finish_t)
            if comp.slo_miss:
                m.counter("slo_misses").inc()
        if n_real and n_real != comp.attempts:
            # never expected; surfaced as an event so tests can assert on it
            self.event("attempt_mismatch",
                       {"seq": comp.seq, "archived": n_real,
                        "attempts": comp.attempts}, t=comp.finish_t)
        self._advance(comp.finish_t)
        if comp.result.failed:
            self.flight.snapshot(
                f"query_failed:{comp.failure_kind or 'unknown'}",
                comp.finish_t)

    def _on_delta(self, t_apply: float, batch) -> None:
        self.event("delta_apply",
                   {"n_events": len(getattr(batch, "events", []) or [])},
                   t=t_apply)

    # ------------------------------------------------------------- queries
    def query_spans(self, seq: int) -> List[Span]:
        # indexed: the monitor reads every completion's span tree inline
        return list(self._by_seq.get(seq, ()))

    def roots(self) -> List[Span]:
        return [s for s in self.spans if s.cat == "query"]

    def children(self, span_id: int) -> List[Span]:
        return [s for s in self.spans if s.parent_id == span_id]

    def reset(self) -> None:
        """Drop all recorded state (QueryService.reset_stats calls this)."""
        self.spans.clear()
        self.events.clear()
        self._live.clear()
        self._closed.clear()
        self._backoffs.clear()
        self._by_seq.clear()
        self.flight.reset()
        self.metrics.reset()
        self.now = 0.0
        self._next_id = 0
