"""Trace-diff explainer: attribute latency deltas between two traced runs
of the same seeded stream to phases.

Every query's latency interval [arrival, finish] is partitioned into four
phases by an interval sweep over its span tree:

  execute  time covered by the attempt that produced the Completion;
  hedge    time covered (only) by losing speculative attempts;
  retry    time covered (only) by failed earlier attempts or backoffs;
  queue    the residual — admission-queue wait and any uncovered gap.

The sweep resolves overlap by priority (execute > hedge > retry), and
queue is defined as the residual, so the four phases sum to the query's
latency EXACTLY — which makes diff attribution exact too: summing the
per-phase deltas reproduces the observed total delta to float precision,
both for the mean and for the p99 (the p99 of run X is the standard
linear-interpolated percentile of its latency vector; its phase
decomposition blends the phase vectors of the two rank-adjacent queries
with the same interpolation weight, so the blended phases still sum to
the interpolated p99).

Policy-decision host cost (`hook`) is zero-width on the virtual clock, so
it is reported as a separate count, not a phase in the sum.

Queries are aligned by `seq` — two runs of the same seeded stream share
stream positions even when completion ORDER differs (different lane
counts, recovery arms, drift policies).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from repro.serve.obs.trace import Span, Tracer

__all__ = ["PHASES", "phases_for", "run_profile", "percentile_profile",
           "diff_profiles", "format_diff"]

PHASES = ("queue", "execute", "retry", "hedge")
_PRIORITY = {"execute": 0, "hedge": 1, "retry": 2}   # lower wins overlap


def phases_for(root: Span, children: List[Span]) -> Dict[str, float]:
    """Partition `root`'s interval among PHASES via a boundary sweep over
    its direct attempt/backoff children. Exact: values sum to root.dur."""
    ivals: List[Tuple[float, float, int]] = []
    for s in children:
        pr = _PRIORITY.get(s.cat)
        if pr is None:
            continue
        t0, t1 = max(s.t0, root.t0), min(s.t1, root.t1)
        if t1 > t0:
            ivals.append((t0, t1, pr))
    out = {p: 0.0 for p in PHASES}
    if not ivals:
        out["queue"] = root.dur
        return out
    cuts = sorted({root.t0, root.t1}
                  | {t for iv in ivals for t in (iv[0], iv[1])})
    covered = 0.0
    by_pr = ("execute", "hedge", "retry")
    for a, b in zip(cuts, cuts[1:]):
        best: Optional[int] = None
        for t0, t1, pr in ivals:
            if t0 <= a and b <= t1 and (best is None or pr < best):
                best = pr
        if best is not None:
            out[by_pr[best]] += b - a
            covered += b - a
    # queue as the residual keeps the partition exact under float error
    out["queue"] = root.dur - (out["execute"] + out["hedge"] + out["retry"])
    return out


def run_profile(tracer: Tracer) -> Dict[int, Dict]:
    """Per-query phase profile: {seq: {total, queue, execute, retry,
    hedge, hooks, failed, name}}."""
    kids: Dict[int, List[Span]] = {}
    for s in tracer.spans:
        kids.setdefault(s.parent_id, []).append(s)
    out: Dict[int, Dict] = {}
    for root in tracer.roots():
        ch = kids.get(root.span_id, [])
        prof = phases_for(root, ch)
        prof["total"] = root.dur
        prof["hooks"] = sum(1 for s in tracer.spans
                            if s.seq == root.seq and s.cat == "hook")
        prof["failed"] = bool(root.attrs.get("failed"))
        prof["name"] = root.name
        out[root.seq] = prof
    return out


def percentile_profile(profiles: List[Dict], q: float) -> Dict[str, float]:
    """Linear-interpolated percentile of `total` with a phase decomposition
    that sums to it exactly: blend the phase vectors of the rank-adjacent
    queries (sorted by total) with the interpolation fraction."""
    assert profiles
    ordered = sorted(profiles, key=lambda p: p["total"])
    rank = (len(ordered) - 1) * (q / 100.0)
    k = int(math.floor(rank))
    f = rank - k
    lo = ordered[k]
    hi = ordered[min(k + 1, len(ordered) - 1)]
    out = {"total": lo["total"] + f * (hi["total"] - lo["total"])}
    for p in PHASES:
        out[p] = lo[p] + f * (hi[p] - lo[p])
    return out


def _mean_profile(profiles: List[Dict]) -> Dict[str, float]:
    n = max(len(profiles), 1)
    out = {"total": sum(p["total"] for p in profiles) / n}
    for ph in PHASES:
        out[ph] = sum(p[ph] for p in profiles) / n
    return out


def diff_profiles(a: Dict[int, Dict], b: Dict[int, Dict], *,
                  label_a: str = "a", label_b: str = "b",
                  q: float = 99.0, top: int = 5) -> Dict:
    """Attribute the latency delta between two aligned runs to phases.
    Returns mean and p-`q` attributions (each with per-phase deltas that
    sum exactly to the total delta) plus the top individual movers."""
    common = sorted(set(a) & set(b))
    pa = [a[s] for s in common]
    pb = [b[s] for s in common]
    assert pa, "no common seqs between the two runs"
    mean_a, mean_b = _mean_profile(pa), _mean_profile(pb)
    pq_a = percentile_profile(pa, q)
    pq_b = percentile_profile(pb, q)
    movers = sorted(
        ({"seq": s, "name": b[s]["name"],
          "delta": b[s]["total"] - a[s]["total"],
          "phases": {p: b[s][p] - a[s][p] for p in PHASES}}
         for s in common),
        key=lambda m: -abs(m["delta"]))[:top]
    return {
        "label_a": label_a, "label_b": label_b,
        "n_common": len(common),
        "n_only_a": len(set(a) - set(b)),
        "n_only_b": len(set(b) - set(a)),
        "q": q,
        "mean": {"a": mean_a["total"], "b": mean_b["total"],
                 "delta": mean_b["total"] - mean_a["total"],
                 "phases": {p: mean_b[p] - mean_a[p] for p in PHASES}},
        "pq": {"a": pq_a["total"], "b": pq_b["total"],
               "delta": pq_b["total"] - pq_a["total"],
               "phases": {p: pq_b[p] - pq_a[p] for p in PHASES}},
        "hook_decisions": {"a": sum(p["hooks"] for p in pa),
                           "b": sum(p["hooks"] for p in pb)},
        "top_movers": movers,
    }


def format_diff(diff: Dict) -> str:
    """Human-readable rendering of a `diff_profiles` result."""
    la, lb = diff["label_a"], diff["label_b"]
    lines = [f"trace diff: {la} -> {lb} "
             f"({diff['n_common']} aligned queries)"]
    for key, title in (("mean", "mean"), ("pq", f"p{diff['q']:g}")):
        d = diff[key]
        lines.append(f"  {title}: {d['a']:.3f}s -> {d['b']:.3f}s "
                     f"(delta {d['delta']:+.3f}s)")
        for p in PHASES:
            dv = d["phases"][p]
            if abs(dv) > 1e-12:
                lines.append(f"    {p:<8}{dv:+10.3f}s")
    hk = diff["hook_decisions"]
    if hk["a"] != hk["b"]:
        lines.append(f"  hook decisions: {hk['a']} -> {hk['b']} "
                     "(host-cost only; zero-width on the virtual clock)")
    if diff["top_movers"]:
        lines.append("  top movers:")
        for m in diff["top_movers"]:
            dom = max(PHASES, key=lambda p: abs(m["phases"][p]))
            lines.append(f"    {m['name']:<12}{m['delta']:+10.3f}s "
                         f"(mostly {dom})")
    return "\n".join(lines)
