"""Online SLO watchdog over a traced scheduler run.

`SloMonitor` is a scheduler `on_complete` hook that rides the PR-7
observability plane: per completion it (a) appends a provenance record
(template, serving policy step, table-version band, exact phase split,
failure fields) and folds it into the `PlanLedger`; (b) feeds a bank of
streaming detectors (`serve.obs.anomaly`) with per-tenant windowed p99
and SLO margin, global queue depth, failure/retry rates and the stage-
cache hit rate; (c) on an anomaly, opens or extends an *incident*
(anomalies within `merge_gap` completions of each other are one
incident), snapshots the flight recorder, runs root-cause attribution
(`serve.obs.rca`) over the trailing window, and emits
`anomaly` / `incident_open` / `incident_rca` / `incident_close` events
into the tracer's event log — so the JSONL export alone is enough for
`serve.obs.report` to render the post-mortem.

Determinism and isolation. Everything the monitor consumes is virtual-
clock state; it never mutates the scheduler, so a monitor-on run with
alerts UNWIRED is completion-bit-identical to the same run without it
(pinned by tests/test_monitor.py and a tests/test_invariants.py
property test). `AlertHooks` is the opt-in actuation path: the top
hypothesis of a fresh incident can feed evidence to the `PolicyBreaker`
(immediate trip + rollback of a watched swap) and the `DriftController`
(alert-driven re-ANALYZE barrier) — once wired, the monitor is a
control plane and completions legitimately diverge.

Attach order matters: the monitor must observe completions AFTER the
tracer has assembled the query's span tree (it reads the exact phase
partition from it), so `QueryService` attaches it after `obs` and all
hooks.

Ledger keys use `band_width` to quantize table versions: band
`(table, version // band_width)` treats nearby versions as the same
data regime, which is what lets "same template, same band, older policy
step" serve as the counterfactual when blaming a swap.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.obs.anomaly import (Anomaly, CusumDetector, DetectorBank,
                                     EwmaDetector)
from repro.serve.obs.explain import PHASES, phases_for
from repro.serve.obs.rca import Hypothesis, attribute

__all__ = ["MonitorConfig", "PlanLedger", "Incident", "AlertHooks",
           "SloMonitor"]

_METRIC_LABELS = {"p99": "p99", "slo_margin": "SLO margin",
                  "queue_depth": "queue depth",
                  "failure_rate": "failure rate",
                  "retry_rate": "retry rate",
                  "cache_hit_rate": "cache hit rate"}

# monitor-emitted kinds, excluded from the event slice RCA joins over
_OWN_KINDS = frozenset({"anomaly", "incident_open", "incident_rca",
                        "incident_close"})


@dataclasses.dataclass
class MonitorConfig:
    window: int = 24          # rolling completions per windowed series
    min_warm: int = 6         # windowed series start after this many obs
    z: float = 4.0            # EWMA alert threshold (sigmas)
    min_n: int = 10           # detector warmup observations
    cooldown: int = 8         # observations muted after an alert
    cusum_k: float = 0.5      # CUSUM slack (sigmas per observation)
    cusum_h: float = 6.0      # CUSUM alert threshold
    merge_gap: int = 12       # completions: anomaly gap within one incident
    lookback: int = 24        # completions in the RCA anomaly window
    baseline_max: int = 96    # completions in the RCA baseline
    lead: float = 600.0       # virtual secs of event-log lead-in for RCA
    band_width: int = 1       # table-version quantum for ledger bands


class PlanLedger:
    """Plan-provenance ledger: (policy step, template, table-version band)
    -> streaming latency stats (Welford) + failure count. The RCA engine
    reads `regression` — current-step mean vs the best prior-step mean on
    the same template (preferring the same band) — as the counterfactual
    for blaming a policy swap."""

    def __init__(self, band_width: int = 1):
        self.band_width = max(int(band_width), 1)
        # key -> [n, mean, m2, fails, max]
        self._stats: Dict[Tuple, List] = {}

    @staticmethod
    def _step(step) -> int:
        return -1 if step is None else int(step)

    def observe(self, step, template: str, band: Tuple, latency: float,
                failed: bool) -> None:
        key = (self._step(step), template, band)
        st = self._stats.get(key)
        if st is None:
            st = self._stats[key] = [0, 0.0, 0.0, 0, 0.0]
        st[0] += 1
        d = latency - st[1]
        st[1] += d / st[0]
        st[2] += d * (latency - st[1])
        st[3] += int(failed)
        st[4] = max(st[4], latency)

    def mean(self, step, template: str, band: Tuple) -> Optional[float]:
        st = self._stats.get((self._step(step), template, band))
        return None if st is None else st[1]

    def regression(self, step, template: str, band: Tuple,
                   min_n: int = 2) -> Optional[Dict]:
        """Ratio of this (step, template, band) mean to the best mean of
        any PRIOR step on the same template (same band preferred), or
        None when there is no counterfactual to compare against."""
        step = self._step(step)
        cur = self._stats.get((step, template, band))
        if cur is None or cur[0] < 1:
            return None
        prior = [(k[2] != band, st[1], k[0]) for k, st in self._stats.items()
                 if k[1] == template and k[0] != step and k[0] >= 0
                 and st[0] >= min_n]
        if not prior:
            return None
        off_band, best, prior_step = min(prior)
        if best <= 0.0:
            return None
        return {"ratio": round(cur[1] / best, 4), "step": step,
                "prior_step": prior_step, "cur_mean": round(cur[1], 4),
                "prior_mean": round(best, 4),
                "same_band": not off_band}

    def rows(self) -> List[Dict]:
        out = []
        for (step, tmpl, band), st in sorted(self._stats.items()):
            var = st[2] / st[0] if st[0] > 1 else 0.0
            out.append({"step": step, "template": tmpl,
                        "band": [list(b) for b in band], "n": st[0],
                        "mean": round(st[1], 4),
                        "std": round(var ** 0.5, 4), "fails": st[3],
                        "max": round(st[4], 4)})
        return out

    def __len__(self) -> int:
        return len(self._stats)

    def reset(self) -> None:
        self._stats.clear()


@dataclasses.dataclass
class Incident:
    id: int
    tenant: str               # tenant of the opening anomaly ("" = global)
    metric: str               # metric of the opening anomaly
    t_open: float
    first_idx: int            # completion index of the opening anomaly
    t_last: float = 0.0
    last_idx: int = 0
    anomalies: List[Anomaly] = dataclasses.field(default_factory=list)
    hypotheses: List[Hypothesis] = dataclasses.field(default_factory=list)
    fired: set = dataclasses.field(default_factory=set)
    closed: bool = False

    @property
    def top(self) -> Optional[Hypothesis]:
        return self.hypotheses[0] if self.hypotheses else None

    def as_dict(self) -> Dict:
        top = self.top
        return {"id": self.id, "tenant": self.tenant, "metric": self.metric,
                "t_open": round(self.t_open, 6),
                "t_last": round(self.t_last, 6),
                "n_anomalies": len(self.anomalies),
                "top_cause": top.cause if top else None,
                "summary": top.summary if top else None,
                "hypotheses": [h.as_dict() for h in self.hypotheses]}


class AlertHooks:
    """Opt-in actuation: route a fresh incident's top hypothesis to the
    recovery/drift control planes. Each sink fires at most once per
    incident; `on_incident` (any callable) always fires on open."""

    def __init__(self, *, breaker=None, drift=None,
                 on_incident: Optional[Callable] = None,
                 min_score: float = 2.0):
        self.breaker = breaker
        self.drift = drift
        self.on_incident = on_incident
        self.min_score = min_score
        self.log: List[Dict] = []

    def fire(self, incident: Incident, comp) -> None:
        if self.on_incident is not None and "cb" not in incident.fired:
            incident.fired.add("cb")
            self.on_incident(incident)
        top = incident.top
        if top is None or top.score < self.min_score:
            return
        if (self.breaker is not None and top.cause == "policy_swap"
                and "breaker" not in incident.fired):
            incident.fired.add("breaker")
            tripped = self.breaker.note_external_evidence(
                comp.seq, top.summary)
            self.log.append({"sink": "breaker", "incident": incident.id,
                             "tripped": bool(tripped)})
        if (self.drift is not None and top.cause == "stats_drift"
                and "drift" not in incident.fired):
            incident.fired.add("drift")
            tables = top.evidence.get("tables") or ()
            scheduled = self.drift.note_external_evidence(
                tables, comp.finish_t, reason=top.summary)
            self.log.append({"sink": "drift", "incident": incident.id,
                             "tables": list(scheduled)})


class SloMonitor:
    """Streaming SLO watchdog; see module docstring. `store` (a
    `learn.PolicyStore`) keys ledger records by the live serving step;
    without one every record lands on step -1 and swap attribution is
    simply never available."""

    def __init__(self, *, config: Optional[MonitorConfig] = None,
                 store=None, alerts: Optional[AlertHooks] = None):
        self.cfg = config if config is not None else MonitorConfig()
        self.store = store
        self.alerts = alerts
        self.ledger = PlanLedger(self.cfg.band_width)
        self.bank = DetectorBank(self._factories())
        self.records: List[Dict] = []
        self.incidents: List[Incident] = []
        self._open: Optional[Incident] = None
        self._next_id = 1
        self._tlat: Dict[str, deque] = {}
        self._fails: deque = deque(maxlen=self.cfg.window)
        self._retries: deque = deque(maxlen=self.cfg.window)
        self._hits: deque = deque(maxlen=self.cfg.window)
        self._last_hits = 0
        self.n_anomalies: Dict[str, int] = {}   # tenant ("" = global) -> n
        self.n_incidents: Dict[str, int] = {}
        self._sched = None
        self._tracer = None

    def _factories(self) -> Dict[str, Callable]:
        c = self.cfg
        ew = dict(z=c.z, min_n=c.min_n, cooldown=c.cooldown)
        cs = dict(k=c.cusum_k, h=c.cusum_h, min_n=c.min_n,
                  cooldown=c.cooldown)
        return {
            "p99": lambda: EwmaDetector(direction="high", **ew),
            "slo_margin": lambda: EwmaDetector(direction="low", **ew),
            "queue_depth": lambda: EwmaDetector(direction="high", **ew),
            "failure_rate": lambda: CusumDetector(
                direction="high", min_sigma=0.05, **cs),
            "retry_rate": lambda: CusumDetector(
                direction="high", min_sigma=0.05, **cs),
            "cache_hit_rate": lambda: EwmaDetector(
                direction="low", min_sigma=0.25, **ew),
        }

    # ------------------------------------------------------------- attach
    def attach(self, scheduler) -> None:
        assert scheduler.obs is not None, \
            "SloMonitor needs a traced scheduler (attach a Tracer first)"
        self._sched = scheduler
        self._tracer = scheduler.obs
        scheduler.on_complete.append(self._on_complete)

    # --------------------------------------------------------- completion
    def _record(self, comp) -> Dict:
        spans = self._tracer.query_spans(comp.seq)
        root = next((s for s in spans if s.cat == "query"), None)
        if root is None:            # tracer hasn't seen it (never expected)
            phases = {p: 0.0 for p in PHASES}
            phases["queue"] = comp.queue_wait
            phases["execute"] = comp.latency - comp.queue_wait
        else:
            kids = [s for s in spans if s.parent_id == root.span_id]
            phases = phases_for(root, kids)
        # every failure kind the query saw, including RECOVERED attempts
        # (a retried transient leaves no mark on the Completion itself)
        kinds = {comp.failure_kind}
        kinds.update(s.attrs.get("failure_kind", "") for s in spans
                     if s.cat in ("execute", "retry", "hedge")
                     and s.attrs.get("failed"))
        step = self.store.serving_step if self.store is not None else None
        tables = tuple(sorted({r.table for r in comp.query.relations}))
        versions = getattr(self._sched.db, "versions", {}) or {}
        band = tuple((t, int(versions.get(t, 0)) // self.cfg.band_width)
                     for t in tables)
        return {"seq": comp.seq, "tenant": comp.tenant,
                "template": getattr(comp.query, "name", f"q{comp.seq}"),
                "t": comp.finish_t, "arrival_t": comp.arrival_t,
                "latency": comp.latency, "failed": bool(comp.result.failed),
                "failure_kind": comp.failure_kind,
                "fail_kinds": tuple(sorted(k for k in kinds if k)),
                "attempts": comp.attempts,
                "recovered": bool(comp.recovered),
                "step": step, "band": band, "phases": phases}

    def _on_complete(self, comp) -> None:
        idx = len(self.records)
        rec = self._record(comp)
        self.records.append(rec)
        self.ledger.observe(rec["step"], rec["template"], rec["band"],
                            rec["latency"], rec["failed"])
        anomalies = self._detect(comp, rec)
        if anomalies:
            self._ingest(anomalies, comp, idx)

    def _detect(self, comp, rec: Dict) -> List[Anomaly]:
        c, t = self.cfg, comp.finish_t
        out: List[Anomaly] = []

        def obs(metric: str, value: float) -> None:
            a = self.bank.observe(metric, t, value)
            if a is not None:
                out.append(a)

        tn = comp.tenant
        lat = self._tlat.get(tn)
        if lat is None:
            lat = self._tlat[tn] = deque(maxlen=c.window)
        lat.append(rec["latency"])
        if len(lat) >= c.min_warm:
            obs(f"p99[{tn}]", float(np.percentile(np.asarray(lat), 99)))
        if comp.deadline is not None:
            obs(f"slo_margin[{tn}]", comp.deadline - comp.finish_t)
        obs("queue_depth", float(len(self._sched._pending)))
        self._fails.append(float(rec["failed"]))
        self._retries.append(float(max(rec["attempts"] - 1, 0)))
        hits = self._tracer.metrics.counter("stage_cache_hits").value
        self._hits.append(float(hits - self._last_hits))
        self._last_hits = hits
        if len(self._fails) >= c.min_warm:
            obs("failure_rate", float(np.mean(self._fails)))
            obs("retry_rate", float(np.mean(self._retries)))
            obs("cache_hit_rate", float(np.mean(self._hits)))
        return out

    # ---------------------------------------------------------- incidents
    @staticmethod
    def _tenant_of(metric: str) -> str:
        return metric.split("[", 1)[1].rstrip("]") if "[" in metric else ""

    def _bump(self, table: Dict[str, int], tenant: str) -> None:
        table[tenant] = table.get(tenant, 0) + 1

    def _ingest(self, anomalies: List[Anomaly], comp, idx: int) -> None:
        t = comp.finish_t
        inc = self._open
        if inc is None or idx - inc.last_idx > self.cfg.merge_gap:
            self._close_open(t)
            first = anomalies[0]
            inc = Incident(self._next_id, self._tenant_of(first.metric),
                           first.metric, t, idx)
            self._next_id += 1
            self.incidents.append(inc)
            self._open = inc
            self._bump(self.n_incidents, inc.tenant)
            self._tracer.event("incident_open",
                               {"id": inc.id, "tenant": inc.tenant,
                                "metric": inc.metric}, t=t)
            self._tracer.flight.snapshot(f"incident:{inc.id}", t)
        inc.last_idx, inc.t_last = idx, t
        inc.anomalies.extend(anomalies)
        for a in anomalies:
            self._bump(self.n_anomalies, self._tenant_of(a.metric))
            self._tracer.event("anomaly",
                               {"incident": inc.id, **a.as_dict()}, t=t)
        inc.hypotheses = self._rca(inc, idx, t)
        top = inc.top
        self._tracer.event("incident_rca",
                           {"incident": inc.id, "top": top.cause,
                            "score": round(top.score, 4),
                            "summary": top.summary}, t=t)
        if self.alerts is not None:
            self.alerts.fire(inc, comp)

    def _rca(self, inc: Incident, idx: int, t: float) -> List[Hypothesis]:
        c = self.cfg
        cut = max(idx + 1 - c.lookback, 0)
        window = self.records[cut:idx + 1]
        baseline = self.records[max(cut - c.baseline_max, 0):cut]
        w0 = window[0]["t"] if window else t
        events = [e for e in self._tracer.events
                  if w0 - c.lead <= e.t <= t and e.kind not in _OWN_KINDS]
        return attribute(
            tenant=inc.tenant,
            metric_label=_METRIC_LABELS.get(
                inc.metric.split("[", 1)[0], inc.metric),
            window=window, baseline=baseline, events=events,
            ledger=self.ledger)

    def _close_open(self, t: float) -> None:
        inc = self._open
        if inc is None:
            return
        inc.closed = True
        self._open = None
        self._tracer.event("incident_close", {**inc.as_dict()}, t=t)

    def finalize(self) -> None:
        """Close any open incident (QueryService calls this at run end so
        the JSONL export always carries complete incident records)."""
        last_t = self.records[-1]["t"] if self.records else 0.0
        self._close_open(last_t)

    # ------------------------------------------------------------- stats
    def tenant_counts(self, tenant: str) -> Tuple[int, int]:
        return (self.n_anomalies.get(tenant, 0),
                self.n_incidents.get(tenant, 0))

    def totals(self) -> Tuple[int, int]:
        return (sum(self.n_anomalies.values()),
                sum(self.n_incidents.values()))

    def summary(self) -> Dict:
        n_anom, n_inc = self.totals()
        return {"n_records": len(self.records),
                "n_anomalies": n_anom, "n_incidents": n_inc,
                "ledger_keys": len(self.ledger),
                "incidents": [i.as_dict() for i in self.incidents]}

    def reset(self) -> None:
        """Drop all monitor state (QueryService.reset_stats calls this;
        the tracer resets itself separately)."""
        self.bank.reset()
        self.ledger.reset()
        self.records.clear()
        self.incidents.clear()
        self._open = None
        self._next_id = 1
        self._tlat.clear()
        self._fails.clear()
        self._retries.clear()
        self._hits.clear()
        self._last_hits = 0
        self.n_anomalies.clear()
        self.n_incidents.clear()
