"""Deterministic metrics registry for the serving plane.

Three instrument kinds, all pure host-side state driven from virtual-clock
observation points (never from wall time), so two runs of the same seeded
stream produce byte-identical metric state:

  Counter     monotone int (completions, retries, control-plane events);
  Gauge       last-written value OR a pull callback evaluated at sample
              time (lane occupancy, queue depth, cache bytes — the
              callback reads live scheduler state);
  Histogram   FIXED bucket bounds chosen at creation: observations land
              in the first bucket whose upper bound is >= value (last
              bucket is +inf). No adaptive resizing, no quantile sketches
              — determinism over fidelity.

Sampling. `advance(t)` is called by the tracer at its observation points
(scheduler ticks, completions, deltas) with the current virtual time;
whenever `t` crosses one or more `interval` boundaries since the last
sample, ONE row — counters + gauges evaluated now, stamped at the last
crossed boundary — is appended to `self.series`. At most one row per
observation point: a 300s straggler gap yields one row, not 300, keeping
the series bounded by the number of events while still being a pure
function of the event sequence. The series is the logged per-tenant /
per-resource time series the ROADMAP's forecast-driven autoscaling item
needs to forecast from.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LATENCY_BOUNDS", "MARGIN_BOUNDS"]

# fixed bucket menus (virtual seconds)
LATENCY_BOUNDS = (0.1, 0.5, 1.0, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0)
MARGIN_BOUNDS = (-300.0, -60.0, -10.0, -1.0, 0.0, 1.0, 10.0, 60.0, 300.0)


@dataclasses.dataclass
class Counter:
    value: int = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Set-style or pull-style: a callback wins over the stored value."""

    def __init__(self, fn: Optional[Callable[[], float]] = None):
        self.fn = fn
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def read(self) -> float:
        return float(self.fn()) if self.fn is not None else self.value


class Histogram:
    """Fixed-bucket histogram: counts[i] = observations with
    value <= bounds[i] (and counts[-1] the +inf overflow bucket)."""

    def __init__(self, bounds: Sequence[float]):
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        assert list(self.bounds) == sorted(set(self.bounds)), \
            "histogram bounds must be strictly increasing"
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.n = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        while i < len(self.bounds) and v > self.bounds[i]:
            i += 1
        self.counts[i] += 1
        self.n += 1
        self.total += v

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def as_dict(self) -> Dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "n": self.n, "sum": round(self.total, 6)}


class MetricsRegistry:
    def __init__(self, interval: float = 5.0):
        assert interval > 0.0
        self.interval = float(interval)
        self.series: List[Dict] = []
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._next: Optional[float] = None     # next sample boundary

    # ------------------------------------------------------- instruments
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(fn)
        elif fn is not None:
            g.fn = fn                           # rebind pull source
        return g

    def histogram(self, name: str,
                  bounds: Sequence[float] = LATENCY_BOUNDS) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(bounds)
        return h

    # ---------------------------------------------------------- sampling
    def advance(self, t: float) -> None:
        """Observe virtual time `t`; emit one sample row if one or more
        interval boundaries were crossed since the last row."""
        t = float(t)
        if self._next is None:
            # first observation anchors the grid at the NEXT boundary
            self._next = (math.floor(t / self.interval) + 1) * self.interval
            return
        if t < self._next:
            return
        # stamp at the last boundary <= t (one row per observation point)
        stamp = math.floor(t / self.interval) * self.interval
        self.series.append(self._row(stamp))
        self._next = stamp + self.interval

    def _row(self, t: float) -> Dict:
        row: Dict = {"t": round(t, 6)}
        for name, c in self._counters.items():
            row[name] = c.value
        for name, g in self._gauges.items():
            row[name] = round(g.read(), 6)
        return row

    # ---------------------------------------------------------- snapshot
    def snapshot(self) -> Dict:
        """Full registry state (counters, gauge reads, histograms) — the
        deterministic blob benchmarks persist."""
        return {
            "interval": self.interval,
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: round(g.read(), 6)
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.as_dict()
                           for k, h in sorted(self._hists.items())},
            "n_samples": len(self.series),
        }

    def reset(self) -> None:
        """Drop all instrument state and the sampled series (gauge pull
        callbacks are kept: they are wiring, not measurement)."""
        for c in self._counters.values():
            c.value = 0
        for g in self._gauges.values():
            g.value = 0.0
        self._hists.clear()
        self.series.clear()
        self._next = None
