"""Deterministic streaming anomaly detectors for the monitor.

Two classic detector shapes, both pure functions of the observation
sequence (value + virtual timestamp) — no wall clock, no RNG — so two
runs of one seeded stream raise byte-identical anomalies:

  EwmaDetector   keeps exponentially-weighted mean/variance of the
                 series; after a warmup of `min_n` observations an
                 observation whose residual exceeds `z` sigmas (in the
                 watched direction) is an anomaly. The anomalous value is
                 NOT folded into the baseline at the alerting step (a
                 spike must not teach the baseline it is normal), but
                 during the post-alert `cooldown` observations folding
                 resumes, so a durable level shift becomes the new
                 normal instead of alerting forever.

  CusumDetector  a one-sided CUSUM over the EWMA-standardized residual:
                 S <- max(0, S + |r| - k) in the watched direction, alert
                 when S > h. Catches slow drifts a per-point z-test never
                 sees; S resets on alert.

Both emit at most one `Anomaly` per `observe` call and respect a
cooldown (in observations) so one incident does not spray alerts at
every completion. `DetectorBank` is the monitor's keyed registry:
detectors are created lazily per metric name from a factory, so
per-tenant series get independent baselines.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

__all__ = ["Anomaly", "EwmaDetector", "CusumDetector", "DetectorBank"]


@dataclasses.dataclass(frozen=True)
class Anomaly:
    t: float                  # virtual time of the alerting observation
    metric: str               # series name (filled by the bank)
    kind: str                 # "ewma" | "cusum"
    direction: str            # "high" | "low"
    value: float              # the alerting observation
    baseline: float           # EWMA mean at alert time
    score: float              # z-score (ewma) or CUSUM statistic

    def as_dict(self) -> Dict:
        return {"t": round(self.t, 6), "metric": self.metric,
                "kind": self.kind, "direction": self.direction,
                "value": round(self.value, 6),
                "baseline": round(self.baseline, 6),
                "score": round(self.score, 4)}


class _EwmaBase:
    """Shared EWMA mean/variance state + warmup/cooldown bookkeeping."""

    def __init__(self, *, alpha: float, min_n: int, min_sigma: float,
                 direction: str, cooldown: int):
        assert 0.0 < alpha <= 1.0
        assert direction in ("high", "low", "both"), direction
        self.alpha = alpha
        self.min_n = max(int(min_n), 1)
        self.min_sigma = float(min_sigma)
        self.direction = direction
        self.cooldown = max(int(cooldown), 0)
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self._cool = 0
        self.n_alerts = 0

    def _fold(self, x: float) -> None:
        if self.n == 0:
            self.mean, self.var = x, 0.0
        else:
            d = x - self.mean
            self.mean += self.alpha * d
            # EW variance of the residual (West 1979 style, deterministic)
            self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1

    @property
    def sigma(self) -> float:
        return max(math.sqrt(max(self.var, 0.0)), self.min_sigma)

    def _watched(self, resid: float) -> bool:
        if self.direction == "high":
            return resid > 0
        if self.direction == "low":
            return resid < 0
        return True

    def reset(self) -> None:
        self.mean = self.var = 0.0
        self.n = self._cool = 0


class EwmaDetector(_EwmaBase):
    def __init__(self, *, alpha: float = 0.25, z: float = 4.0,
                 min_n: int = 8, min_sigma: float = 1e-3,
                 direction: str = "high", cooldown: int = 8):
        super().__init__(alpha=alpha, min_n=min_n, min_sigma=min_sigma,
                         direction=direction, cooldown=cooldown)
        self.z = float(z)

    def observe(self, t: float, x: float) -> Optional[Anomaly]:
        x = float(x)
        if self.n < self.min_n:
            self._fold(x)
            return None
        resid = x - self.mean
        score = abs(resid) / self.sigma
        if self._cool > 0:
            self._cool -= 1
            self._fold(x)
            return None
        if self._watched(resid) and score > self.z:
            out = Anomaly(t, "", "ewma",
                          "high" if resid > 0 else "low", x, self.mean,
                          score)
            self._cool = self.cooldown
            self.n_alerts += 1
            return out                  # spike not folded into the baseline
        self._fold(x)
        return None


class CusumDetector(_EwmaBase):
    def __init__(self, *, alpha: float = 0.1, k: float = 0.5,
                 h: float = 5.0, min_n: int = 8, min_sigma: float = 1e-3,
                 direction: str = "high", cooldown: int = 8):
        assert direction in ("high", "low"), "CUSUM is one-sided"
        super().__init__(alpha=alpha, min_n=min_n, min_sigma=min_sigma,
                         direction=direction, cooldown=cooldown)
        self.k, self.h = float(k), float(h)
        self.s = 0.0

    def observe(self, t: float, x: float) -> Optional[Anomaly]:
        x = float(x)
        if self.n < self.min_n:
            self._fold(x)
            return None
        resid = (x - self.mean) / self.sigma
        drift = resid if self.direction == "high" else -resid
        self.s = max(0.0, self.s + drift - self.k)
        baseline = self.mean
        self._fold(x)
        if self._cool > 0:
            self._cool -= 1
            return None
        if self.s > self.h:
            out = Anomaly(t, "", "cusum", self.direction, x, baseline,
                          self.s)
            self.s = 0.0
            self._cool = self.cooldown
            self.n_alerts += 1
            return out
        return None

    def reset(self) -> None:
        super().reset()
        self.s = 0.0


class DetectorBank:
    """Lazily-created detectors keyed by metric name. `factories` maps a
    metric PREFIX (everything before any "[") to a zero-arg detector
    factory; `observe` routes each sample to its metric's detector and
    stamps the metric name onto any anomaly raised."""

    def __init__(self, factories: Dict[str, Callable[[], object]]):
        self.factories = dict(factories)
        self.detectors: Dict[str, object] = {}
        self.anomalies: List[Anomaly] = []

    def _for(self, metric: str):
        det = self.detectors.get(metric)
        if det is None:
            prefix = metric.split("[", 1)[0]
            fac = self.factories.get(prefix)
            if fac is None:
                return None
            det = self.detectors[metric] = fac()
        return det

    def observe(self, metric: str, t: float, x: float) -> Optional[Anomaly]:
        det = self._for(metric)
        if det is None:
            return None
        a = det.observe(t, x)
        if a is not None:
            a = dataclasses.replace(a, metric=metric)
            self.anomalies.append(a)
        return a

    def reset(self) -> None:
        self.detectors.clear()
        self.anomalies.clear()
