"""Root-cause attribution for anomaly windows.

Given an anomaly window over the completion stream, `attribute` joins
three deterministic evidence sources into ranked causal hypotheses:

  1. the control-plane EVENT LOG (policy commits/swaps, delta barriers,
     re-ANALYZEs, retry/hedge scheduling, barrier maintenance) sliced to
     the window plus a lead-in — events GATE causes: no swap event means
     "policy_swap" scores zero, however suggestive the latency shape;
  2. the PHASE SHARES of the explainer's exact queue/execute/retry/hedge
     partition — window-vs-baseline share deltas say WHERE the latency
     went (queue-dominant regressions point at load, execute-dominant at
     planning, retry-dominant at faults);
  3. the per-template PLAN-PROVENANCE LEDGER (policy version x template
     x table-version band -> latency stats): a template whose mean under
     the serving step is a multiple of its mean under a prior step on
     the same data band is direct evidence against the swap, and a
     window whose records sit on a different band than their baseline
     modal band is direct evidence of drift.

Causes are kept SEPARABLE by their gates: a drift window with no swap
cannot blame the policy, a quiet event log leaves only load-shaped
causes (hot_tenant) and the `unknown` floor. Scores are heuristic but
deterministic and dimensionless (roughly 0-8); callers rank by score
and read `summary` / `evidence` for the human-facing claim, e.g.
"tenant B p99 regression caused by policy swap v12 on template q7".
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.serve.obs.explain import PHASES

__all__ = ["Hypothesis", "attribute", "CAUSES"]

CAUSES = ("policy_swap", "stats_drift", "fault_burst", "hot_tenant",
          "maintenance", "stale_memo", "unknown")

_SWAP_KINDS = frozenset({"policy_swap", "policy_commit"})
_INJECTED_KINDS = frozenset({"crash", "transient", "slow"})
_PRESSURE_KINDS = frozenset({"oom", "timeout"})


@dataclasses.dataclass
class Hypothesis:
    cause: str
    score: float
    summary: str
    evidence: Dict

    def as_dict(self) -> Dict:
        return {"cause": self.cause, "score": round(self.score, 4),
                "summary": self.summary, "evidence": self.evidence}


def _phase_shares(records: Sequence[Dict]) -> Dict[str, float]:
    tot = sum(r["latency"] for r in records)
    if tot <= 0.0:
        return {p: 0.0 for p in PHASES}
    return {p: sum(r["phases"][p] for r in records) / tot for p in PHASES}


def _share_deltas(window: Sequence[Dict],
                  baseline: Sequence[Dict]) -> Dict[str, float]:
    """Positive part of the window-vs-baseline phase-share shift."""
    win = _phase_shares(window)
    base = _phase_shares(baseline) if baseline else {p: 0.0 for p in PHASES}
    return {p: max(win[p] - base[p], 0.0) for p in PHASES}


def _modal_bands(baseline: Sequence[Dict]) -> Dict[str, tuple]:
    counts: Dict[str, Dict[tuple, int]] = {}
    for r in baseline:
        by = counts.setdefault(r["template"], {})
        by[r["band"]] = by.get(r["band"], 0) + 1
    return {tmpl: max(by.items(), key=lambda kv: (kv[1], kv[0]))[0]
            for tmpl, by in counts.items()}


def _tenant_rates(records: Sequence[Dict]) -> Dict[str, float]:
    if not records:
        return {}
    ts = [r["arrival_t"] for r in records]
    dt = max(max(ts) - min(ts), 1e-9)
    out: Dict[str, float] = {}
    for r in records:
        out[r["tenant"]] = out.get(r["tenant"], 0.0) + 1.0
    return {tn: n / dt for tn, n in out.items()}


def _worst_regression(window: Sequence[Dict], ledger) -> Optional[Dict]:
    """Largest serving-step-vs-prior-step ledger latency ratio over the
    window's (step, template, band) triples."""
    if ledger is None:
        return None
    worst = None
    for key in sorted({(r["step"], r["template"], r["band"])
                       for r in window}):
        step, tmpl, band = key
        reg = ledger.regression(step, tmpl, band)
        if reg is None:
            continue
        if worst is None or reg["ratio"] > worst["ratio"]:
            worst = {"template": tmpl, "band": band, **reg}
    return worst


def attribute(*, tenant: str, metric_label: str,
              window: Sequence[Dict], baseline: Sequence[Dict],
              events: Sequence, ledger=None) -> List[Hypothesis]:
    """Rank causal hypotheses for one anomaly window.

    `window` / `baseline` are the monitor's per-completion records (dicts
    with template/band/step/phases/failure fields); `events` is the
    control-plane event slice covering the window plus its lead-in.
    Always returns at least the `unknown` floor hypothesis."""
    n_win = max(len(window), 1)
    who = f"tenant {tenant}" if tenant else "service"
    shares = _share_deltas(window, baseline)
    exec_share, queue_share = shares["execute"], shares["queue"]
    retry_share = shares["retry"] + shares["hedge"]
    by_kind: Dict[str, List] = {}
    for e in events:
        by_kind.setdefault(e.kind, []).append(e)
    out: List[Hypothesis] = []

    # ---- policy swap: gated on a swap/commit event in the lead-in
    swaps = sorted((e for k in _SWAP_KINDS for e in by_kind.get(k, [])),
                   key=lambda e: e.t)
    if swaps:
        last = swaps[-1]
        step = last.attrs.get("to_step", last.attrs.get("step"))
        reg = _worst_regression(window, ledger)
        reg_score = 0.0
        on_tmpl = ""
        if reg is not None:
            reg_score = min(max(math.log2(max(reg["ratio"], 1.0)), 0.0),
                            3.0) / 3.0
            on_tmpl = f" on template {reg['template']}"
        out.append(Hypothesis(
            "policy_swap",
            2.0 + 3.0 * reg_score + 2.0 * exec_share,
            f"{who} {metric_label} regression caused by policy swap "
            f"v{step}{on_tmpl}",
            {"step": step, "t_swap": round(last.t, 6),
             "ledger_regression": reg,
             "execute_share_delta": round(exec_share, 4)}))

    # ---- stats drift: gated on a delta barrier in the lead-in
    deltas = by_kind.get("delta_apply", [])
    if deltas:
        modal = _modal_bands(baseline)
        shifted_tables: List[str] = []
        n_shifted = 0
        for r in window:
            base_band = modal.get(r["template"])
            if base_band is None or r["band"] == base_band:
                continue
            n_shifted += 1
            before = dict(base_band)
            shifted_tables.extend(t for t, b in r["band"]
                                  if before.get(t) != b)
        band_shift = n_shifted / n_win
        oom_frac = sum(r["failed"] and r["failure_kind"] in _PRESSURE_KINDS
                       for r in window) / n_win
        tables = sorted(set(shifted_tables))
        out.append(Hypothesis(
            "stats_drift",
            1.5 + 1.5 * band_shift + 2.0 * oom_frac + 1.5 * exec_share,
            f"{who} {metric_label} regression caused by data drift on "
            f"{','.join(tables) if tables else 'recently-written tables'} "
            f"(stale stats after delta at t={deltas[-1].t:.0f}s)",
            {"t_delta": round(deltas[-1].t, 6), "tables": tables,
             "band_shift": round(band_shift, 4),
             "oom_frac": round(oom_frac, 4),
             "execute_share_delta": round(exec_share, 4)}))

    # ---- fault burst: gated on injected failure kinds / retry traffic
    # (fail_kinds covers RECOVERED attempts, so a burst the retry ladder
    # absorbs is still attributable)
    injected = sum(any(k in _INJECTED_KINDS for k in r["fail_kinds"])
                   for r in window)
    n_retry_ev = len(by_kind.get("retry_scheduled", []))
    if injected or n_retry_ev:
        kinds = sorted({k for r in window for k in r["fail_kinds"]
                        if k in _INJECTED_KINDS})
        out.append(Hypothesis(
            "fault_burst",
            4.0 * injected / n_win + 1.5 * min(n_retry_ev / n_win, 1.0)
            + 1.0 * retry_share,
            f"{who} {metric_label} regression caused by a fault burst "
            f"({','.join(kinds) if kinds else 'retried transients'})",
            {"injected_frac": round(injected / n_win, 4),
             "retry_events": n_retry_ev, "kinds": kinds,
             "retry_share_delta": round(retry_share, 4)}))

    # ---- hot tenant: arrival-rate blowup + queue-dominant shape
    win_rates = _tenant_rates(window)
    base_rates = _tenant_rates(baseline)
    hot, hot_ratio = "", 0.0
    for tn in sorted(win_rates):
        base = base_rates.get(tn)
        if base is None or base <= 0.0:
            continue
        ratio = win_rates[tn] / base
        if ratio > hot_ratio:
            hot, hot_ratio = tn, ratio
    if hot_ratio > 1.5 and queue_share > 0.15:
        out.append(Hypothesis(
            "hot_tenant",
            2.0 * min(math.log2(hot_ratio) / 3.0, 1.5)
            + 3.0 * queue_share,
            f"{who} {metric_label} regression caused by hot tenant "
            f"{hot} flood (arrival rate x{hot_ratio:.1f})",
            {"hot_tenant": hot, "rate_ratio": round(hot_ratio, 3),
             "queue_share_delta": round(queue_share, 4)}))

    # ---- maintenance: a charged barrier window stalls admissions
    charged = [e for e in by_kind.get("barrier_task", [])
               if e.attrs.get("charge_s", 0) > 0]
    charged += by_kind.get("re_analyze", [])
    if charged and queue_share > 0.0:
        out.append(Hypothesis(
            "maintenance",
            0.5 + 1.0 * queue_share,
            f"{who} {metric_label} regression caused by a maintenance "
            f"barrier (re-ANALYZE / barrier task at "
            f"t={charged[-1].t:.0f}s)",
            {"n_tasks": len(charged),
             "queue_share_delta": round(queue_share, 4)}))

    # ---- stale memo: gated on plan-memory fence events in the window —
    # memoized replays whose band went stale (delta / re-ANALYZE / replay
    # failure) served degraded plans until the fence landed. Execute-
    # dominant shape (the replayed plan, not queueing, burned the time).
    fences = by_kind.get("plan_memory_fenced", [])
    if fences:
        reasons = sorted({e.attrs.get("reason", "") for e in fences})
        out.append(Hypothesis(
            "stale_memo",
            1.0 + 2.0 * exec_share + 1.0 * min(len(fences) / n_win, 1.0),
            f"{who} {metric_label} regression caused by stale memoized "
            f"plans (plan memory fenced {len(fences)} entr"
            f"{'y' if len(fences) == 1 else 'ies'}: "
            f"{','.join(r for r in reasons if r)})",
            {"n_fenced": len(fences), "reasons": reasons,
             "t_last_fence": round(fences[-1].t, 6),
             "execute_share_delta": round(exec_share, 4)}))

    out.append(Hypothesis(
        "unknown", 0.3,
        f"{who} {metric_label} regression: no attributable control-plane "
        f"cause in the window",
        {"phase_share_deltas": {p: round(shares[p], 4) for p in PHASES}}))
    out.sort(key=lambda h: (-h.score, h.cause))
    return out
