"""Deterministic observability plane for the serving stack.

  trace    Tracer: per-query span trees + control-plane event log +
           flight recorder, all on the virtual clock
  metrics  MetricsRegistry: fixed-bucket counters/gauges/histograms
           sampled into a time series at virtual-clock intervals
  export   Chrome trace-event / versioned JSONL export + validator/loader
  explain  trace-diff: attribute latency deltas to phases exactly
  anomaly  streaming EWMA/CUSUM detectors (deterministic, virtual-clock)
  monitor  SloMonitor: online SLO watchdog — detectors over the live
           completion stream, plan-provenance ledger, incident lifecycle,
           opt-in alert hooks into breaker/drift control planes
  rca      root-cause attribution: event log x phase shares x ledger
           -> ranked causal hypotheses
  report   incident-report renderer: JSONL export -> markdown timeline

Attach with `QueryService(..., obs=Tracer())`; obs=None keeps every emit
point short-circuited and completions bit-identical to an untraced run.
Add `monitor=SloMonitor()` for the watchdog — monitor-on with alerts
unwired is still completion-bit-identical.
"""
from repro.serve.obs.anomaly import (Anomaly, CusumDetector, DetectorBank,
                                     EwmaDetector)
from repro.serve.obs.explain import diff_profiles, format_diff, run_profile
from repro.serve.obs.export import (chrome_trace, load_trace_jsonl,
                                    validate_trace_jsonl,
                                    write_chrome_trace, write_trace_jsonl)
from repro.serve.obs.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry)
from repro.serve.obs.monitor import (AlertHooks, Incident, MonitorConfig,
                                     PlanLedger, SloMonitor)
from repro.serve.obs.rca import CAUSES, Hypothesis, attribute
from repro.serve.obs.report import render_incident_report
from repro.serve.obs.trace import (SCHEMA_VERSION, Event, FlightRecorder,
                                   RunTrace, Span, Tracer)

__all__ = [
    "SCHEMA_VERSION", "Tracer", "Span", "Event", "RunTrace",
    "FlightRecorder", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "chrome_trace", "write_chrome_trace", "write_trace_jsonl",
    "load_trace_jsonl", "validate_trace_jsonl", "run_profile",
    "diff_profiles", "format_diff",
    "Anomaly", "EwmaDetector", "CusumDetector", "DetectorBank",
    "MonitorConfig", "PlanLedger", "Incident", "AlertHooks", "SloMonitor",
    "CAUSES", "Hypothesis", "attribute", "render_incident_report",
]
