"""Deterministic observability plane for the serving stack.

  trace    Tracer: per-query span trees + control-plane event log +
           flight recorder, all on the virtual clock
  metrics  MetricsRegistry: fixed-bucket counters/gauges/histograms
           sampled into a time series at virtual-clock intervals
  export   Chrome trace-event / versioned JSONL export + validator
  explain  trace-diff: attribute latency deltas to phases exactly

Attach with `QueryService(..., obs=Tracer())`; obs=None keeps every emit
point short-circuited and completions bit-identical to an untraced run.
"""
from repro.serve.obs.explain import diff_profiles, format_diff, run_profile
from repro.serve.obs.export import (chrome_trace, validate_trace_jsonl,
                                    write_chrome_trace, write_trace_jsonl)
from repro.serve.obs.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry)
from repro.serve.obs.trace import (SCHEMA_VERSION, Event, FlightRecorder,
                                   RunTrace, Span, Tracer)

__all__ = [
    "SCHEMA_VERSION", "Tracer", "Span", "Event", "RunTrace",
    "FlightRecorder", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "chrome_trace", "write_chrome_trace", "write_trace_jsonl",
    "validate_trace_jsonl", "run_profile", "diff_profiles", "format_diff",
]
