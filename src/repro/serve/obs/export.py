"""Trace export: Chrome trace-event JSON (Perfetto-loadable) and a
versioned JSONL format with a validator.

JSONL schema (one JSON object per line; `schema_version` gates readers):

  line type   required fields
  ---------   -----------------------------------------------------------
  header      type, schema_version, n_spans, n_events, n_samples, n_dumps
  span        type, id, parent, seq, name, cat, t0, t1, lane, attrs
  event       type, t, kind, attrs
  sample      type, t, ... (one column per counter/gauge)
  hist        type, name, bounds, counts, n, sum
  dump        type, reason, t, n, records   (flight-recorder snapshots)

`validate_trace_jsonl` is the CI gate: it checks the header version, the
per-line required fields, interval sanity (t1 >= t0) and span parent
references, returning a list of error strings (empty = valid).

CLI:
  python -m repro.serve.obs.export --validate PATH   # gate an export
  python -m repro.serve.obs.export --selftest [PATH] # tiny traced serve
                                                     # -> export -> validate
"""
from __future__ import annotations

import json
from typing import Dict, List

from repro.serve.obs.trace import SCHEMA_VERSION, Tracer

__all__ = ["chrome_trace", "write_chrome_trace", "write_trace_jsonl",
           "load_trace_jsonl", "validate_trace_jsonl", "SCHEMA_VERSION"]

_LINE_FIELDS = {
    "header": ("schema_version", "n_spans", "n_events", "n_samples",
               "n_dumps"),
    "span": ("id", "parent", "seq", "name", "cat", "t0", "t1", "lane",
             "attrs"),
    "event": ("t", "kind", "attrs"),
    "sample": ("t",),
    "hist": ("name", "bounds", "counts", "n", "sum"),
    "dump": ("reason", "t", "n", "records"),
}
_SPAN_CATS = frozenset({"query", "queue", "execute", "retry", "hedge",
                        "stage", "hook"})
# instant events / control-plane track live on a tid above any lane index
_CTRL_TID = 10_000


def chrome_trace(tracer: Tracer) -> Dict:
    """Chrome trace-event JSON (load in ui.perfetto.dev or
    chrome://tracing). Spans become complete events ("X", microsecond
    ts/dur) on tid = lane (control/queue spans on a meta track); events
    become instants ("i")."""
    ev: List[Dict] = []
    tids = set()
    for s in tracer.spans:
        tid = s.lane if s.lane >= 0 else _CTRL_TID
        tids.add(tid)
        ev.append({"name": s.name, "cat": s.cat, "ph": "X",
                   "ts": round(s.t0 * 1e6, 3),
                   "dur": round(max(s.t1 - s.t0, 0.0) * 1e6, 3),
                   "pid": 0, "tid": tid,
                   "args": {"seq": s.seq, **s.attrs}})
    for e in tracer.events:
        tids.add(_CTRL_TID)
        ev.append({"name": e.kind, "cat": "control", "ph": "i",
                   "ts": round(e.t * 1e6, 3), "pid": 0, "tid": _CTRL_TID,
                   "s": "g", "args": dict(e.attrs)})
    meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": t,
             "args": {"name": "control-plane" if t == _CTRL_TID
                      else f"lane-{t}"}} for t in sorted(tids)]
    return {"traceEvents": meta + ev,
            "displayTimeUnit": "ms",
            "otherData": {"schema_version": SCHEMA_VERSION,
                          "clock": "virtual-seconds"}}


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f)
    return path


def write_trace_jsonl(tracer: Tracer, path: str) -> str:
    hists = tracer.metrics.snapshot()["histograms"]
    with open(path, "w") as f:
        f.write(json.dumps({
            "type": "header", "schema_version": SCHEMA_VERSION,
            "n_spans": len(tracer.spans), "n_events": len(tracer.events),
            "n_samples": len(tracer.metrics.series),
            "n_dumps": len(tracer.flight.dumps)}) + "\n")
        for s in tracer.spans:
            f.write(json.dumps(s.as_dict()) + "\n")
        for e in tracer.events:
            f.write(json.dumps(e.as_dict()) + "\n")
        for row in tracer.metrics.series:
            f.write(json.dumps({"type": "sample", **row}) + "\n")
        for name, h in hists.items():
            f.write(json.dumps({"type": "hist", "name": name, **h}) + "\n")
        for d in tracer.flight.dumps:
            f.write(json.dumps(d) + "\n")
    return path


def load_trace_jsonl(path: str) -> Dict:
    """Inverse of `write_trace_jsonl`: parse an export back into
    {"header", "spans", "events", "samples", "hists", "dumps"} of raw
    dicts (the "type" tag stripped). Values survive bit-exact: the writer
    rounds before serializing, so load(write(x)) == the in-memory rows —
    pinned by tests/test_obs.py."""
    out: Dict = {"header": None, "spans": [], "events": [], "samples": [],
                 "hists": [], "dumps": []}
    with open(path) as f:
        for raw in f:
            raw = raw.strip()
            if not raw:
                continue
            obj = json.loads(raw)
            t = obj.pop("type", None)
            if t == "header":
                out["header"] = obj
            elif t in ("span", "event", "sample", "hist", "dump"):
                out[t + "s"].append(obj)
    return out


def validate_trace_jsonl(path: str) -> List[str]:
    """Validate a JSONL export; returns error strings (empty = valid)."""
    errors: List[str] = []
    header = None
    counts = {"span": 0, "event": 0, "sample": 0, "dump": 0}
    span_ids = set()
    parents: List[tuple] = []
    with open(path) as f:
        for ln, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                obj = json.loads(raw)
            except json.JSONDecodeError as e:
                errors.append(f"line {ln}: invalid JSON ({e})")
                continue
            t = obj.get("type")
            if t not in _LINE_FIELDS:
                errors.append(f"line {ln}: unknown line type {t!r}")
                continue
            missing = [k for k in _LINE_FIELDS[t] if k not in obj]
            if missing:
                errors.append(f"line {ln}: {t} missing fields {missing}")
                continue
            if ln == 1:
                if t != "header":
                    errors.append("line 1: first line must be the header")
                else:
                    header = obj
                    if obj["schema_version"] != SCHEMA_VERSION:
                        errors.append(
                            f"header: schema_version {obj['schema_version']}"
                            f" != supported {SCHEMA_VERSION}")
            elif t == "header":
                errors.append(f"line {ln}: duplicate header")
            if t == "span":
                counts["span"] += 1
                span_ids.add(obj["id"])
                parents.append((ln, obj["parent"]))
                if obj["t1"] < obj["t0"]:
                    errors.append(f"line {ln}: span t1 < t0")
                if obj["cat"] not in _SPAN_CATS:
                    errors.append(f"line {ln}: unknown span cat "
                                  f"{obj['cat']!r}")
            elif t in counts:
                counts[t] += 1
    if header is None:
        errors.append("missing header line")
    else:
        for key, n in (("n_spans", counts["span"]),
                       ("n_events", counts["event"]),
                       ("n_samples", counts["sample"]),
                       ("n_dumps", counts["dump"])):
            if header.get(key) != n:
                errors.append(f"header {key}={header.get(key)} but file "
                              f"has {n}")
    for ln, p in parents:
        if p != -1 and p not in span_ids:
            errors.append(f"line {ln}: span parent {p} not in file")
    return errors


# ---------------------------------------------------------------- selftest
def _selftest(path: str) -> int:
    """Serve a tiny traced stream, export it, validate the export — the
    gating CI trace-schema check."""
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    from repro.serve.scheduler import Arrival
    from repro.serve.service import QueryService
    from repro.sql import datagen
    from repro.sql.workloads import make_workload

    db = datagen.make_job_like(scale=0.03, seed=0)
    wl = make_workload("job", n_train=8, n_test_per_template=1, seed=7)
    agent = AqoraAgent(WorkloadMeta.from_workload(wl),
                       AgentConfig(max_steps=2), seed=0)
    tracer = Tracer()
    svc = QueryService(db, agent, n_lanes=2, obs=tracer)
    stream = [Arrival(0.4 * i, query=q, seed=i)
              for i, q in enumerate(wl.train[:6])]
    comps, _ = svc.run(stream)
    write_trace_jsonl(tracer, path)
    errs = validate_trace_jsonl(path)
    ok = not errs and len(comps) == len(stream) and tracer.roots()
    print(f"selftest: {len(comps)} completions, {len(tracer.spans)} spans, "
          f"{len(tracer.events)} events -> {path}: "
          f"{'OK' if ok else 'FAIL'}")
    for e in errs:
        print(f"  {e}")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.serve.obs.export")
    ap.add_argument("--validate", metavar="PATH",
                    help="validate a trace JSONL export")
    ap.add_argument("--selftest", nargs="?", const="/tmp/obs_selftest.jsonl",
                    metavar="PATH", help="trace a tiny serve run, export "
                    "and validate it")
    args = ap.parse_args(argv)
    if args.validate:
        errs = validate_trace_jsonl(args.validate)
        for e in errs:
            print(e)
        print(f"{args.validate}: {'OK' if not errs else f'{len(errs)} errors'}")
        return 0 if not errs else 1
    if args.selftest:
        return _selftest(args.selftest)
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
