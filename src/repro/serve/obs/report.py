"""Incident-report renderer: a run's JSONL export -> markdown post-mortem.

Pure function of the export file — the monitor writes everything the
report needs (anomaly / incident_open / incident_rca / incident_close
events with ranked hypotheses in their attrs) into the tracer's event
log, so rendering needs no live objects: archive the JSONL, render the
post-mortem anywhere.

Report layout:
  # <title>
  ## Run summary        counts from the span/event/sample lines
  ## Timeline           every control-plane event + anomaly + flight
                        dump, one markdown table row each, in virtual-
                        time order
  ## Incidents          one section per incident: its anomaly list and
                        the RCA engine's ranked hypotheses
  ## Metrics            final counter values + histogram summaries

CLI:
  python -m repro.serve.obs.report --render PATH [--out OUT]
  python -m repro.serve.obs.report --selftest [PATH]   # CI gate: serve a
      monitored stream with a seeded queue flood, export, validate,
      check the loader round-trip, render, check the sections
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.serve.obs.export import load_trace_jsonl, validate_trace_jsonl

__all__ = ["render_incident_report", "main"]

_TIMELINE_CAP = 250


def _fmt_val(v) -> str:
    if isinstance(v, float):
        return f"{v:.3f}"
    if isinstance(v, (list, tuple)):
        return ",".join(str(x) for x in v) if v else "-"
    return str(v)


def _detail(kind: str, attrs: Dict) -> str:
    skip = {"hypotheses"}                     # rendered in their own section
    parts = [f"{k}={_fmt_val(v)}" for k, v in attrs.items()
             if k not in skip and not isinstance(v, dict)]
    s = " ".join(parts)
    return s[:117] + "..." if len(s) > 120 else s


def _md_escape(s: str) -> str:
    return s.replace("|", "\\|")


def render_incident_report(trace: Dict, *,
                           title: str = "Incident report") -> str:
    spans = trace.get("spans", [])
    events = trace.get("events", [])
    samples = trace.get("samples", [])
    hists = trace.get("hists", [])
    dumps = trace.get("dumps", [])
    roots = [s for s in spans if s.get("cat") == "query"]
    n_failed = sum(bool(s.get("attrs", {}).get("failed")) for s in roots)
    opens = [e for e in events if e["kind"] == "incident_open"]
    closes = {e["attrs"].get("id"): e for e in events
              if e["kind"] == "incident_close"}
    anomalies = [e for e in events if e["kind"] == "anomaly"]
    makespan = max((s["t1"] for s in roots), default=0.0) - \
        min((s["t0"] for s in roots), default=0.0)

    lines: List[str] = [f"# {title}", ""]
    lines += ["## Run summary", "",
              f"- queries completed: **{len(roots)}** "
              f"({n_failed} failed), makespan {makespan:.1f}s "
              "(virtual clock)",
              f"- control-plane events: **{len(events)}**, metric "
              f"samples: {len(samples)}, flight dumps: {len(dumps)}",
              f"- anomalies: **{len(anomalies)}**, incidents: "
              f"**{len(opens)}**", ""]

    # ------------------------------------------------------------ timeline
    rows = [(e["t"], e["kind"], _detail(e["kind"], e.get("attrs", {})))
            for e in events]
    rows += [(d["t"], "flight_dump",
              f"reason={d['reason']} records={d['n']}") for d in dumps]
    rows.sort(key=lambda r: (r[0], r[1]))
    lines += ["## Timeline", ""]
    if rows:
        lines += ["| t (virtual s) | kind | detail |",
                  "|---:|---|---|"]
        for t, kind, detail in rows[:_TIMELINE_CAP]:
            lines.append(f"| {t:.3f} | {kind} | {_md_escape(detail)} |")
        if len(rows) > _TIMELINE_CAP:
            lines.append(f"| ... | ... | {len(rows) - _TIMELINE_CAP} more "
                         "rows elided |")
    else:
        lines.append("(no control-plane events recorded)")
    lines.append("")

    # ----------------------------------------------------------- incidents
    lines += ["## Incidents", ""]
    if not opens:
        lines.append("No incidents detected.")
    for op in opens:
        iid = op["attrs"].get("id")
        tenant = op["attrs"].get("tenant") or "(global)"
        close = closes.get(iid)
        info = close["attrs"] if close is not None else dict(op["attrs"])
        t0 = info.get("t_open", op["t"])
        t1 = info.get("t_last", op["t"])
        lines.append(f"### Incident {iid} — tenant {tenant}, "
                     f"t={t0:.1f}s..{t1:.1f}s")
        lines.append("")
        if info.get("summary"):
            lines.append(f"**{info['summary']}**")
            lines.append("")
        mine = [a for a in anomalies if a["attrs"].get("incident") == iid]
        if mine:
            lines.append(f"Anomalies ({len(mine)}):")
            for a in mine:
                at = a["attrs"]
                lines.append(
                    f"- t={a['t']:.1f}s `{at.get('metric')}` "
                    f"{at.get('kind')}/{at.get('direction')}: value "
                    f"{_fmt_val(at.get('value'))} vs baseline "
                    f"{_fmt_val(at.get('baseline'))} "
                    f"(score {_fmt_val(at.get('score'))})")
            lines.append("")
        hyps = info.get("hypotheses") or []
        if hyps:
            lines.append("Ranked hypotheses:")
            for i, h in enumerate(hyps, 1):
                lines.append(f"{i}. **{h['cause']}** "
                             f"(score {h['score']:.2f}) — {h['summary']}")
            lines.append("")
        if close is None:
            lines.append("(incident still open at export time)")
            lines.append("")

    # ------------------------------------------------------------- metrics
    lines += ["## Metrics", ""]
    if samples:
        last = samples[-1]
        keys = [k for k in last if k != "t"]
        lines += [f"Final sample (t={last['t']:.1f}s):", "",
                  "| metric | value |", "|---|---:|"]
        for k in sorted(keys):
            lines.append(f"| {_md_escape(k)} | {_fmt_val(last[k])} |")
        lines.append("")
    if hists:
        lines += ["| histogram | n | mean |", "|---|---:|---:|"]
        for h in hists:
            mean = h["sum"] / h["n"] if h["n"] else 0.0
            lines.append(f"| {_md_escape(h['name'])} | {h['n']} | "
                         f"{mean:.3f} |")
        lines.append("")
    return "\n".join(lines)


# ---------------------------------------------------------------- selftest
def _selftest(path: str) -> int:
    """Serve a small monitored stream with a seeded queue flood, export,
    validate, round-trip the loader, render, and check the report — the
    gating CI check for the whole monitor->report pipeline."""
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    from repro.serve.obs.monitor import MonitorConfig, SloMonitor
    from repro.serve.obs.trace import Tracer
    from repro.serve.obs.export import write_trace_jsonl
    from repro.serve.scheduler import Arrival
    from repro.serve.service import QueryService
    from repro.sql import datagen
    from repro.sql.workloads import make_workload

    db = datagen.make_job_like(scale=0.03, seed=0)
    wl = make_workload("job", n_train=8, n_test_per_template=1, seed=7)
    agent = AqoraAgent(WorkloadMeta.from_workload(wl),
                       AgentConfig(max_steps=2), seed=0)
    tracer = Tracer()
    monitor = SloMonitor(config=MonitorConfig(window=8, min_warm=4,
                                              min_n=6, cooldown=4))
    svc = QueryService(db, agent, n_lanes=2, obs=tracer, monitor=monitor)
    qs = [wl.train[i % len(wl.train)] for i in range(20)]
    # 12 paced arrivals warm the detectors, then an 8-query flood at one
    # instant starves the 2 lanes: queue depth + p99 must alert
    stream = [Arrival(3.0 * i if i < 12 else 36.0, query=q, seed=i)
              for i, q in enumerate(qs)]
    comps, stats = svc.run(stream)
    write_trace_jsonl(tracer, path)
    errs = validate_trace_jsonl(path)
    trace = load_trace_jsonl(path)
    roundtrip_ok = (trace["samples"] ==
                    [json.loads(json.dumps(r)) for r in tracer.metrics.series])
    md = render_incident_report(trace, title="report selftest")
    out = path + ".md"
    with open(out, "w") as f:
        f.write(md)
    checks = {
        "completions": len(comps) == len(stream),
        "export_valid": not errs,
        "loader_roundtrip": roundtrip_ok,
        "incident_detected": len(monitor.incidents) >= 1,
        "sections": all(s in md for s in
                        ("## Run summary", "## Timeline", "## Incidents",
                         "## Metrics", "### Incident")),
        "hypotheses_rendered": "Ranked hypotheses:" in md,
    }
    ok = all(checks.values())
    print(f"selftest: {len(comps)} completions, "
          f"{len(monitor.incidents)} incidents, "
          f"{sum(len(i.anomalies) for i in monitor.incidents)} anomalies "
          f"-> {path} + .md: {'OK' if ok else 'FAIL'}")
    for name, good in checks.items():
        if not good:
            print(f"  FAIL: {name}")
    for e in errs:
        print(f"  {e}")
    return 0 if ok else 1


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.serve.obs.report")
    ap.add_argument("--render", metavar="PATH",
                    help="render a trace JSONL export as markdown")
    ap.add_argument("--out", metavar="OUT",
                    help="write the rendered report here (default stdout)")
    ap.add_argument("--title", default="Incident report")
    ap.add_argument("--selftest", nargs="?",
                    const="/tmp/obs_report_selftest.jsonl", metavar="PATH",
                    help="serve a monitored stream with a seeded incident, "
                    "export, validate and render it")
    args = ap.parse_args(argv)
    if args.render:
        md = render_incident_report(load_trace_jsonl(args.render),
                                    title=args.title)
        if args.out:
            with open(args.out, "w") as f:
                f.write(md)
            print(f"wrote {args.out}")
        else:
            print(md)
        return 0
    if args.selftest:
        return _selftest(args.selftest)
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
