"""Lifelong-learning benchmark: online-adapted vs frozen policy under a
drifting delta workload — feeds results/BENCH_online.json.

The drifting workload interleaves two query populations over a JOB-like
database: fast hub-shaped dimension joins, and "trap" templates written
fact-fact first (cast_info x movie_info, then a filtered title) whose
syntactic/lead(fact) orders are fine pre-drift but blow past the
materialize cap — 300s timeout — once a mid-stream delta grows cast_info
~9x. The safe orders (cbo(1), lead(title)) stay seconds at all times, and
optimizer statistics are deliberately stale (the paper's premise), so
only EXECUTION feedback can reveal the trap. Churn deltas keep bumping
table versions afterwards, exercising the replay buffer's freshness
prioritization.

Three serving passes over the SAME stream on identical fresh databases:

  frozen   the PR-2 configuration: greedy serving, no learning;
  shadow   learning runs at full cost (harvest, prioritized replay, PPO
           updates, probe gates) but the PolicyStore is in shadow mode —
           completions must be bit-identical to frozen, so the host-time
           delta prices the learning overhead exactly;
  online   the full loop: exploring lanes under the adaptive curriculum,
           background PPO, gated hot-swap with rollback on regression.

Gates (full run): online strictly beats frozen on p99 and is no worse on
p50 (both on the post-drift segment and the whole stream for p99); shadow
completions == frozen completions, so reported qps — virtual-clock, the
serving metric every bench in this repo uses — stays within 5% of
learning-off (identically 1.0 by construction); and the shadow pass's
SERVE-PATH host cost (total host minus the learner's own accounted host
seconds, which in a real deployment run on spare cycles/a second device)
stays within a 15% band of frozen — wall timings of ~15s quantities on
the shared 2-core container carry ~10% run-to-run noise, so this gate is
deliberately looser than the deterministic qps gate. The learner's raw
host cost and the unadjusted host-qps ratio are reported alongside —
nothing is netted out silently.

  PYTHONPATH=src python -m benchmarks.bench_online [--smoke]
"""
import tempfile
import time

import numpy as np

from benchmarks.common import (bench_args, bench_logger, csv_line,
                               emit_bench_json)

log = bench_logger("online")


# ------------------------------------------------------------ workload
def _trap_query(i: int, year: int):
    """Fact-fact-first join: syntactic order is (ci x mi) then the
    filtered title — safe pre-drift, OOM once cast_info grows."""
    from repro.sql.query import Filter, JoinCond, Query, Relation
    return Query(f"trap_{i}",
                 (Relation("ci", "cast_info", ()),
                  Relation("mi", "movie_info", ()),
                  Relation("t", "title",
                           (Filter("production_year", "<=", (year,)),))),
                 (JoinCond("ci", "movie_id", "mi", "movie_id"),
                  JoinCond("t", "id", "ci", "movie_id")))


def drifting_stream(wl, db, *, n_queries: int, rate: float, seed: int,
                    drift_at: int, growth: int, churn_every: int):
    """Open-loop arrivals; one big cast_info growth delta after
    `drift_at` queries, then append/delete churn on movie_info."""
    from repro.serve.deltas import DeltaBatch
    from repro.serve.scheduler import Arrival

    rng = np.random.default_rng(seed)
    # heavier multi-join background traffic: serving work dominates the
    # host clock, so the learning-overhead ratio measures something real
    fast = [q for q in wl.train if q.n_relations <= 10][:12] or wl.train[:12]
    # year band calibrated so EVERY variant stays fixable post-drift: the
    # safe order's final join must remain under the materialize cap while
    # the fact-fact-first order blows past it
    traps = [_trap_query(i, 1935 + 3 * i) for i in range(6)]
    ci_rows = db.table("cast_info").nrows
    mk_rows = db.table("movie_keyword").nrows
    t, out, since_churn = 0.0, [], 0
    for i in range(n_queries):
        t += float(rng.exponential(1.0 / rate))
        q = traps[(i // 6) % len(traps)] if i % 6 == 0 \
            else fast[i % len(fast)]
        out.append(Arrival(t, query=q, seed=int(rng.integers(2 ** 31))))
        if i + 1 == drift_at:
            out.append(Arrival(t, delta=DeltaBatch(
                "cast_info", n_append=growth * ci_rows, seed=999)))
        elif i + 1 > drift_at:
            since_churn += 1
            if since_churn >= churn_every:
                # churn a table OUTSIDE the trap join (movie_keyword):
                # versions keep bumping (freshness reprioritization +
                # cache invalidation) without re-deriving the trap stages
                since_churn = 0
                out.append(Arrival(t, delta=DeltaBatch(
                    "movie_keyword", n_append=mk_rows // 50,
                    delete_frac=0.02, seed=1000 + i)))
    return out


def _pcts(comps):
    lat = np.asarray([c.latency for c in comps])
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def _post_drift(comps, stream):
    drift_t = next(a.t for a in stream if a.delta is not None)
    return [c for c in comps if c.arrival_t > drift_t]


# ------------------------------------------------------------ passes
def _fresh_env(scale: float):
    """Identical database + stale estimator per pass (deltas mutate)."""
    from repro.sql import datagen
    from repro.sql.cbo import Estimator
    db = datagen.make_job_like(scale=scale, seed=0)
    return db, Estimator(db, db.stats)


def _serve(db, est, agent, stream, *, n_lanes, explore, hooks):
    from repro.serve.service import QueryService
    svc = QueryService(db, agent, est=est, n_lanes=n_lanes, policy="async",
                       explore=explore, hooks=hooks)
    t0 = time.perf_counter()
    comps, stats = svc.run(stream)
    return comps, stats, time.perf_counter() - t0


def main(argv=None):
    args = bench_args(argv, lanes=6)

    from repro.checkpoint import agent_state, copy_tree, install_agent_state
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    from repro.learn import (AdaptiveCurriculum, PolicyStore, ReplayBuffer,
                             make_online_loop)
    from repro.sql import workloads

    scale = 0.05 if args.smoke else 0.2
    n_queries = 24 if args.smoke else 144
    drift_at = 8 if args.smoke else 24
    rate, growth, churn_every = 2.0, 8, 16
    update_every, sample_size, gate_every = 3, 8, 2

    wl = workloads.make_workload("job", n_train=48, n_test_per_template=1,
                                 seed=7)
    meta = WorkloadMeta.from_workload(wl)
    serving_agent = AqoraAgent(meta, AgentConfig(), seed=0)
    learner_agent = AqoraAgent(meta, AgentConfig(), seed=1)
    init_s = copy_tree(agent_state(serving_agent))
    init_l = copy_tree(agent_state(learner_agent))
    probe = [_trap_query(100, 1938), _trap_query(101, 1944),
             _trap_query(102, 1950), wl.test[0]]

    db0, _ = _fresh_env(scale)
    stream = drifting_stream(wl, db0, n_queries=n_queries, rate=rate,
                             seed=17, drift_at=drift_at, growth=growth,
                             churn_every=churn_every)
    n_deltas = sum(a.delta is not None for a in stream)
    log.info(f"== online learning under drift: {n_queries} queries "
          f"({sum(q.query is not None and q.query.name.startswith('trap') for q in stream)} trap), "
          f"{n_deltas} deltas, {args.lanes} lanes, open-loop {rate} qps ==")

    # one run-scoped temp root for every pass's PolicyStore; the
    # TemporaryDirectory finalizer removes it at interpreter exit even if
    # a pass raises mid-benchmark
    tmp_root = tempfile.TemporaryDirectory(prefix="bench_online_ps_")
    n_stores = [0]

    def loop_hooks(mode, curriculum):
        n_stores[0] += 1
        store = PolicyStore(f"{tmp_root.name}/store{n_stores[0]}", probe,
                            mode=mode)
        # regret keeps post-drift trap FAILURES prominent in the sample,
        # but fail_boost stays mild: the critic quickly learns a failing
        # state is "worth" -sqrt(300), so the unlearning gradient comes
        # from the rare SAFE successes beating that baseline — they must
        # keep getting sampled alongside the failures
        return make_online_loop(
            serving_agent, store=store, curriculum=curriculum,
            replay=ReplayBuffer(capacity=256, regret_scale=2.0,
                                regret_cap=8.0, fail_boost=1.5),
            update_every=update_every, sample_size=sample_size,
            gate_every=gate_every, seed=3, learner_agent=learner_agent)

    def reset_agents():
        install_agent_state(serving_agent, init_s, copy=True)
        install_agent_state(learner_agent, init_l, copy=True)

    # -- warmup pass: same stream, full loop; only compiles jit caches
    #    (params are restored afterwards, timings discarded)
    reset_agents()
    db, est = _fresh_env(scale)
    h, l = loop_hooks("gate", AdaptiveCurriculum(window=8, min_dwell=8))
    _serve(db, est, serving_agent, stream, n_lanes=args.lanes,
           explore=True, hooks=[h, l])
    log.info("warmup pass done (jit caches hot)")

    # -- frozen: the PR-2 serving configuration
    reset_agents()
    db, est = _fresh_env(scale)
    fr_comps, fr_stats, fr_host = _serve(db, est, serving_agent, stream,
                                         n_lanes=args.lanes, explore=False,
                                         hooks=[])

    # -- shadow: full learning cost, zero behavior change
    reset_agents()
    db, est = _fresh_env(scale)
    sh_h, sh_l = loop_hooks("shadow", None)
    sh_comps, sh_stats, sh_host = _serve(db, est, serving_agent, stream,
                                         n_lanes=args.lanes, explore=False,
                                         hooks=[sh_h, sh_l])
    shadow_identical = (
        [c.traj.actions for c in sh_comps] ==
        [c.traj.actions for c in fr_comps] and
        [c.finish_t for c in sh_comps] == [c.finish_t for c in fr_comps])

    # -- online: exploring lanes, adaptive curriculum, gated hot-swap
    reset_agents()
    db, est = _fresh_env(scale)
    on_h, on_l = loop_hooks("gate", AdaptiveCurriculum(window=8, min_dwell=8))
    on_comps, on_stats, on_host = _serve(db, est, serving_agent, stream,
                                         n_lanes=args.lanes, explore=True,
                                         hooks=[on_h, on_l])

    # ------------------------------------------------------------ report
    rows = {}
    for name, comps, stats, host, learn_host in (
            ("frozen", fr_comps, fr_stats, fr_host, 0.0),
            ("shadow", sh_comps, sh_stats, sh_host,
             sh_l.stats.host_seconds),
            ("online", on_comps, on_stats, on_host,
             on_l.stats.host_seconds)):
        p50, p99 = _pcts(comps)
        dp50, dp99 = _pcts(_post_drift(comps, stream))
        n_failed = sum(c.result.failed for c in comps)
        serve_host = host - learn_host
        rows[name] = {
            "p50": round(p50, 3), "p99": round(p99, 3),
            "post_drift_p50": round(dp50, 3),
            "post_drift_p99": round(dp99, 3),
            "failed": n_failed, "qps_virtual": stats.as_dict()["qps"],
            "host_seconds": round(host, 2),
            "learn_host_seconds": round(learn_host, 2),
            "serve_path_host_seconds": round(serve_host, 2),
            "host_qps": round(len(comps) / host, 3),
        }
        log.info(f"{name:7s} p50={p50:7.2f}s p99={p99:7.2f}s | post-drift "
              f"p50={dp50:7.2f}s p99={dp99:7.2f}s | fails={n_failed:3d} "
              f"host={host:6.1f}s (learn {learn_host:5.1f}s, serve-path "
              f"{serve_host:5.1f}s)")

    # serving throughput with learning on: virtual qps is bit-identical by
    # construction (checked below); the serve-path host ratio checks that
    # harvesting/callbacks don't tax the serving loop itself. The raw
    # host-qps ratio (learning cost included) is reported, not gated — in
    # a deployment the updates run on spare cycles / a second device.
    qps_ratio = rows["shadow"]["qps_virtual"] / \
        max(rows["frozen"]["qps_virtual"], 1e-9)
    serve_ratio = rows["frozen"]["serve_path_host_seconds"] / \
        max(rows["shadow"]["serve_path_host_seconds"], 1e-9)
    raw_ratio = rows["shadow"]["host_qps"] / rows["frozen"]["host_qps"]
    log.info(f"shadow==frozen completions: {shadow_identical};  qps ratio "
          f"{qps_ratio:.3f};  serve-path host ratio {serve_ratio:.3f};  "
          f"raw host-qps ratio {raw_ratio:.3f}")
    log.info(f"online learner: {on_l.stats.as_dict()}")
    log.info(f"online store:   {on_l.store.stats()}")
    log.info(f"curriculum:     {on_l.curriculum.stats()}")

    ok_tail = (rows["online"]["post_drift_p99"] <
               rows["frozen"]["post_drift_p99"]) and \
              (rows["online"]["p99"] < rows["frozen"]["p99"]) and \
              (rows["online"]["post_drift_p50"] <=
               rows["frozen"]["post_drift_p50"])
    # the qps gate is deterministic (virtual clock); the serve-path host
    # gate gets a wider band because ~15s wall quantities on the shared
    # 2-core container carry ~10% run-to-run noise
    ok_overhead = 0.95 <= qps_ratio <= 1.05 and serve_ratio >= 0.85
    ok = bool(ok_tail and shadow_identical and ok_overhead) \
        if not args.smoke else bool(shadow_identical)

    csv_line("online_post_drift_p99_s", 0, rows["online"]["post_drift_p99"])
    csv_line("frozen_post_drift_p99_s", 0, rows["frozen"]["post_drift_p99"])
    csv_line("learning_qps_ratio", 0, f"{qps_ratio:.3f}")
    csv_line("learning_serve_path_host_ratio", 0, f"{serve_ratio:.3f}")
    emit_bench_json({
        "smoke": args.smoke, "scale": scale, "n_queries": n_queries,
        "n_lanes": args.lanes, "rate_qps": rate, "drift_at": drift_at,
        "growth_x": growth, "update_every": update_every,
        "sample_size": sample_size, "gate_every": gate_every,
        **rows,
        "shadow_identical_to_frozen": shadow_identical,
        "overhead_qps_ratio": round(qps_ratio, 3),
        "overhead_serve_path_host_ratio": round(serve_ratio, 3),
        "overhead_raw_host_qps_ratio": round(raw_ratio, 3),
        "online_learner": on_l.stats.as_dict(),
        "online_store": on_l.store.stats(),
        "online_curriculum": on_l.curriculum.stats(),
        "gates_ok": ok,
    }, name="BENCH_online.json")
    tmp_root.cleanup()
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
