"""Paper §VII-D4: action-space ablation — default (cbo+lead+noop) vs
+broadcast (instability), +swap, -lead (no join-order power), -cbo (no
escape from the syntactic plan family)."""
import json

from benchmarks.common import AQORA, bench_logger, csv_line

log = bench_logger("ablation_actions")


def main():
    p = AQORA / "ablations.json"
    if not p.exists():
        log.info("bench_ablation_actions: missing results")
        return False
    d = json.loads(p.read_text())
    log.info("\n== §VII-D4: action-space subsets (ExtJOB) ==")
    for key, label in (("rl_ppo", "default: {cbo, lead, noop}"),
                       ("act_plus_broadcast", "+ broadcast hints"),
                       ("act_plus_swap", "+ swap"),
                       ("act_no_lead", "- lead"),
                       ("act_no_cbo", "- cbo")):
        if key not in d:
            continue
        r = d[key]
        log.info(f"{label:30s} test C={r['total']:8.1f}s exec={r['exec']:8.1f}s "
              f"fails={r['fails']}")
        csv_line(f"actions_{key}", 0, f"{r['total']:.1f}")
    return True


if __name__ == "__main__":
    main()
