"""Paper Tab. II: improvement/regression distribution of each learned
method vs Spark default — delta = (C_spark - C_method)/C_spark bucketed
into (0,0.2), (0.2,inf), (-0.2,0), (-inf,-0.2) — plus the failure row."""
from benchmarks.common import METHODS, bench_logger, csv_line, load

log = bench_logger("delta_table")

BUCKETS = (("(0.2,+inf)", lambda d: d > 0.2),
           ("(0,0.2)", lambda d: 0 < d <= 0.2),
           ("(-0.2,0)", lambda d: -0.2 <= d <= 0),
           ("(-inf,-0.2)", lambda d: d < -0.2))


def main():
    log.info("\n== Tab. II: per-query delta vs Spark default ==")
    any_ok = False
    for bench in ("job", "extjob", "stack"):
        d = load(bench)
        if d is None:
            continue
        any_ok = True
        sp = {r["query"]: r["total"] for r in d["spark"]}
        sp_fail = sum(r["failed"] for r in d["spark"])
        n = len(d["spark"])
        log.info(f"\n[{bench}] (spark failures: {sp_fail}/{n} = {sp_fail/n:.1%})")
        log.info(f"  {'delta bucket':14s} " + " ".join(f"{m:>10s}" for m in METHODS[1:]))
        rows = {m: {r['query']: r for r in d[m]} for m in METHODS[1:]}
        for bname, pred in BUCKETS:
            counts = []
            for m in METHODS[1:]:
                c = sum(1 for q in sp
                        if pred((sp[q] - rows[m][q]["total"]) / max(sp[q], 1e-9)))
                counts.append(c)
            log.info(f"  {bname:14s} " + " ".join(f"{c:10d}" for c in counts))
        fails = [sum(r["failed"] for r in d[m]) for m in METHODS[1:]]
        log.info(f"  {'Failure':14s} " + " ".join(f"{c:10d}" for c in fails))
        csv_line(f"tab2_{bench}_aqora_failures", 0, fails[-1])
    return any_ok


if __name__ == "__main__":
    main()
