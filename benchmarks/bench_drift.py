"""Drift control-plane benchmark: re-ANALYZE policies x online predictor
refresh under a drifting delta workload — feeds results/BENCH_drift.json.

The world starts with a YOUNG movie_info table (90% of its rows deleted
before ANALYZE runs), so the catalog honestly describes a small fact
table. Serving uses the classical CBO re-plan policy
(`baselines.CboReplanAgent`: re-optimize every query at admission against
the CURRENT statistics) — the natural probe for stats quality, with no RL
confound. Mid-stream, one growth delta multiplies movie_info ~25x: the
cost-based order that was right for the small table (fact-fact first,
cast_info x movie_info) now blows past the materialize cap, so every
"stats-trap" template OOMs into the 45s timeout under STALE statistics,
while fresh statistics flip the join order to go through the filtered
title first (sub-second). Churn deltas on movie_keyword keep bumping
versions afterwards.

The SAME stream is replayed through 8 arms: RefreshPolicy in
{never, always, threshold, budgeted} x predictor-refresh in {off, on},
all under EDF + QoS admission (deadline-aware, latency predictor
calibrated ONE-SHOT pre-serve from a harvested calibration pass):

  never      the paper's stale-stats premise (and PR-4's behavior):
             bit-identical to a run with no drift control plane at all
             (checked against a 9th plain pass);
  always /   auto re-ANALYZE at the delta barrier (the controller reacts
  threshold  on_delta, so the refresh costs zero extra drain); traps
             never fail because the first post-delta query already plans
             on fresh stats;
  budgeted   same, under a hard modeled-cost ceiling: the one big
             movie_info refresh fits, the churn-table scans do not;
  refresh-on `LatencyPredictor.refit_on_drift` from the live replay
             buffer: under "never" the ONLY defense — after the first
             trap burns a lane for the full timeout, the refit teaches
             admission to REJECT hopeless traps, protecting the lane
             pool (online adaptation vs re-ANALYZE, priced head to head).

Per arm: p50/p99 (whole stream + post-drift), failures, SLO-miss rate,
rejections, goodput, and the EXPLICIT re-ANALYZE cost charge (modeled
virtual seconds — also pushed onto the clock via charge_virtual — plus
measured wall seconds) and refit count. All latencies are virtual-clock,
so every comparison except wall times is deterministic.

  PYTHONPATH=src python -m benchmarks.bench_drift [--smoke]
"""
import time

import numpy as np

from benchmarks.common import (bench_args, bench_logger, csv_line,
                               emit_bench_json)

log = bench_logger("drift")

SLO = 10.0                      # per-query deadline (virtual seconds)
TIMEOUT = 45.0                  # shortened so failures complete mid-stream
TRAP_EVERY = 5
SHRINK_SEED = 7                 # the young-movie_info world build
GROWTH_X = 24                   # append 24x current rows at the drift point


# ------------------------------------------------------------------ world
def _build_world(scale: float):
    """JOB-like db whose movie_info is young and small, with statistics
    taken THEN: the catalog is in sync at serve start and goes stale the
    moment the growth delta lands."""
    from repro.serve.deltas import DeltaBatch, apply_delta
    from repro.sql import datagen
    from repro.sql.catalog import analyze
    from repro.sql.cbo import Estimator

    db = datagen.make_job_like(scale=scale, seed=0)
    apply_delta(db, DeltaBatch("movie_info", delete_frac=0.9,
                               seed=SHRINK_SEED))
    # analyze() stamps the versions it saw, so the shrink above is part
    # of the catalog's baseline — only LATER deltas count as drift
    db.stats = analyze(db, rng=np.random.default_rng(0))
    return db, Estimator(db, db.stats)


def _trap(i: int, year: int):
    """Fact-fact-first syntactically; the CBO order depends on |movie_info|:
    small => (ci x mi) first (cheapest by C_out), grown => through the
    filtered title. The stale catalog keeps saying 'small'."""
    from repro.sql.query import Filter, JoinCond, Query, Relation
    return Query(f"statstrap_{i}",
                 (Relation("ci", "cast_info", ()),
                  Relation("mi", "movie_info", ()),
                  Relation("t", "title",
                           (Filter("production_year", "<=", (year,)),))),
                 (JoinCond("ci", "movie_id", "mi", "movie_id"),
                  JoinCond("t", "id", "ci", "movie_id")))


def _stream(wl, db, *, n_queries, rate, seed, drift_at, churn_every):
    from repro.serve.deltas import DeltaBatch
    from repro.serve.scheduler import Arrival
    from benchmarks.bench_serve import fast_subset

    rng = np.random.default_rng(seed)
    fast = fast_subset(wl)[:10]
    traps = [_trap(i, 1940 + 5 * i) for i in range(5)]
    mi_rows = db.table("movie_info").nrows      # post-shrink
    mk_rows = db.table("movie_keyword").nrows
    t, out, since_churn = 0.0, [], 0
    for i in range(n_queries):
        t += float(rng.exponential(1.0 / rate))
        q = traps[(i // TRAP_EVERY) % len(traps)] if i % TRAP_EVERY == 0 \
            else fast[i % len(fast)]
        out.append(Arrival(t, query=q, seed=int(rng.integers(2 ** 31)),
                           deadline=t + SLO))
        if i + 1 == drift_at:
            out.append(Arrival(t, delta=DeltaBatch(
                "movie_info", n_append=GROWTH_X * mi_rows, seed=999)))
        elif i + 1 > drift_at:
            since_churn += 1
            if since_churn >= churn_every:
                since_churn = 0
                out.append(Arrival(t, delta=DeltaBatch(
                    "movie_keyword", n_append=mk_rows // 50,
                    delete_frac=0.02, seed=1000 + i)))
    return out


# ------------------------------------------------------------- calibration
def _calibrate_replay(wl, meta, *, scale, n_lanes, cluster, smoke):
    """Pre-serve calibration pass: serve a pre-drift mix (traps included —
    they are sub-second on the young table) and harvest latencies into a
    replay buffer every arm's one-shot predictor fit draws from."""
    from repro.learn import ReplayBuffer, TrajectoryHarvester
    from repro.serve.scheduler import Arrival
    from repro.serve.service import QueryService
    from benchmarks.bench_serve import fast_subset
    from repro.baselines import CboReplanAgent

    db, est = _build_world(scale)
    fast = fast_subset(wl)[:10]
    traps = [_trap(i, 1940 + 5 * i) for i in range(5)]
    rng = np.random.default_rng(29)
    n_cal = 20 if smoke else 50
    t, stream = 0.0, []
    for i in range(n_cal):
        t += float(rng.exponential(0.5))
        q = traps[i % len(traps)] if i % 4 == 0 else fast[i % len(fast)]
        stream.append(Arrival(t, query=q, seed=int(rng.integers(2 ** 31))))
    rb = ReplayBuffer(capacity=256)
    QueryService(db, CboReplanAgent(meta), est=est, n_lanes=n_lanes,
                 cluster=cluster,
                 hooks=[TrajectoryHarvester(rb)]).run(stream)
    return rb


def _one_shot_predictor(meta, cal_replay, *, smoke):
    """The PR-4 style calibration: fit once pre-serve, never again
    (unless an arm's controller refits it on drift)."""
    from repro.serve.qos import LatencyPredictor
    pred = LatencyPredictor(meta, seed=5, lr=5e-3)
    rng = np.random.default_rng(7)
    for _ in range(6 if smoke else 12):
        pred.fit_from_replay(cal_replay, rng, n_samples=48, batch_size=16,
                             epochs=3)
    return pred


# ------------------------------------------------------------------- arms
def _make_policy(kind, analyze_cost_s):
    from repro.serve.drift import RefreshPolicy
    if kind == "budgeted":
        # room for the one big movie_info refresh, not for churn scans
        return RefreshPolicy("budgeted", threshold=0.25,
                             budget_s=1.5 * analyze_cost_s)
    if kind == "threshold":
        return RefreshPolicy("threshold", threshold=1.0)
    return RefreshPolicy(kind)


def _serve_arm(kind, refresh_on, *, stream, meta, cal_replay, scale,
               n_lanes, cluster, analyze_cost_s, smoke):
    from repro.learn import ReplayBuffer, TrajectoryHarvester
    from repro.serve.drift import DriftController, DriftDetector
    from repro.serve.qos import (DegradationLadder, QoSAdmission,
                                 TenantRegistry)
    from repro.serve.service import QueryService
    from repro.baselines import CboReplanAgent

    db, est = _build_world(scale)
    pred = _one_shot_predictor(meta, cal_replay, smoke=smoke)
    adm = QoSAdmission(
        TenantRegistry(), predictor=pred,
        ladder=DegradationLadder(rungs=((1.0, None), (1.5, 1)),
                                 reject_above=2.0))
    rb = ReplayBuffer(capacity=512, fail_boost=4.0)
    # w_pred=0 removes the DIRECT predictor-error term from refresh
    # scores (refits still shift completions, and with them the regret
    # evidence — in this workload the on_delta-timed decisions come out
    # identical across the predictor axis). Refit batches stay
    # SMALL on purpose: weighted-without-replacement sampling only biases
    # toward the (few, high-priority) post-drift failures when k is well
    # under the buffer size — sampling the whole buffer would drown the
    # 45s timeouts in sub-second fast-query targets
    ctl = DriftController(
        detector=DriftDetector(w_pred=0.0),
        policy=_make_policy(kind, analyze_cost_s), replay=rb,
        predictor=pred if refresh_on else None,
        refit_threshold=0.5, refit_every=2, refit_samples=24,
        refit_epochs=8, charge_virtual=True, seed=13)
    svc = QueryService(db, CboReplanAgent(meta), est=est, n_lanes=n_lanes,
                       policy="edf", cluster=cluster, admission=adm,
                       hooks=[TrajectoryHarvester(rb), ctl])
    t0 = time.perf_counter()
    comps, stats = svc.run(stream)
    host = time.perf_counter() - t0
    return comps, stats, svc, ctl, host


def _metrics(comps, stats, svc, ctl, host, stream, n_queries):
    drift_t = next(a.t for a in stream if a.delta is not None)
    post = [c for c in comps if c.arrival_t > drift_t]
    pcts = lambda cs: (
        float(np.percentile([c.latency for c in cs], 50)) if cs else 0.0,
        float(np.percentile([c.latency for c in cs], 99)) if cs else 0.0)
    p50, p99 = pcts(comps)
    dp50, dp99 = pcts(post)
    on_time = sum(not c.slo_miss for c in comps)
    out = {
        "p50": round(p50, 3), "p99": round(p99, 3),
        "post_drift_p50": round(dp50, 3), "post_drift_p99": round(dp99, 3),
        "failed": sum(c.result.failed for c in comps),
        "slo_miss_rate": stats.slo_miss_rate,
        "rejected": len(svc.scheduler.rejections),
        "goodput": round(on_time / n_queries, 4),
        "reanalyze_events": ctl.stats.refresh_events,
        "reanalyze_tables": ctl.stats.tables_refreshed,
        "reanalyze_modeled_s": round(ctl.stats.analyze_modeled_s, 4),
        "reanalyze_wall_s": round(ctl.stats.analyze_wall_s, 4),
        "predictor_refits": ctl.stats.refits,
        "host_seconds": round(host, 2),
    }
    return out


# ------------------------------------------------------------------- main
def main(argv=None):
    args = bench_args(argv, lanes=6)
    from repro.core.encoding import WorkloadMeta
    from repro.sql import workloads
    from repro.sql.cluster import ClusterModel

    scale = 0.06 if args.smoke else 0.2
    n_queries = 30 if args.smoke else 150
    drift_at = 10 if args.smoke else 40
    rate, churn_every = 1.0, 12
    cluster = ClusterModel(timeout=TIMEOUT)

    wl = workloads.make_workload("job", n_train=48, n_test_per_template=1,
                                 seed=7)
    meta = WorkloadMeta.from_workload(wl)

    db0, _ = _build_world(scale)
    stream = _stream(wl, db0, n_queries=n_queries, rate=rate, seed=17,
                     drift_at=drift_at, churn_every=churn_every)
    # deterministic price of the one big refresh (for the budgeted arm):
    # the post-growth movie_info sampled-scan cost
    mi = db0.table("movie_info")
    post_bytes = (1 + GROWTH_X) * mi.bytes()
    analyze_cost_s = cluster.scan_time(post_bytes * 0.05) + \
        cluster.stage_overhead
    n_traps = sum(a.query is not None and
                  a.query.name.startswith("statstrap") for a in stream)
    n_deltas = sum(a.delta is not None for a in stream)
    log.info(f"== drift control plane: {n_queries} queries ({n_traps} stats-"
          f"trap), {n_deltas} deltas (movie_info x{GROWTH_X + 1} at query "
          f"{drift_at}), {args.lanes} lanes, SLO {SLO:.0f}s, timeout "
          f"{TIMEOUT:.0f}s ==")

    cal_replay = _calibrate_replay(wl, meta, scale=scale,
                                   n_lanes=args.lanes, cluster=cluster,
                                   smoke=args.smoke)

    arms = {}
    comps_by_arm = {}
    for kind in ("never", "always", "threshold", "budgeted"):
        for refresh_on in (False, True):
            name = f"{kind}+{'refresh' if refresh_on else 'oneshot'}"
            comps, stats, svc, ctl, host = _serve_arm(
                kind, refresh_on, stream=stream, meta=meta,
                cal_replay=cal_replay, scale=scale, n_lanes=args.lanes,
                cluster=cluster, analyze_cost_s=analyze_cost_s,
                smoke=args.smoke)
            arms[name] = _metrics(comps, stats, svc, ctl, host, stream,
                                  n_queries)
            comps_by_arm[name] = comps
            m = arms[name]
            log.info(f"{name:19s} p99={m['p99']:6.2f}s post-p99="
                  f"{m['post_drift_p99']:6.2f}s fails={m['failed']:3d} "
                  f"miss={m['slo_miss_rate']:.2f} rej={m['rejected']:3d} "
                  f"goodput={m['goodput']:.2f} reANALYZE="
                  f"{m['reanalyze_tables']:2d}x ({m['reanalyze_modeled_s']:.2f}s) "
                  f"refits={m['predictor_refits']}")

    # 9th pass: the PR-4 path (no drift control plane at all) — the
    # "never+oneshot" arm must be completion-bit-identical to it
    from repro.learn import ReplayBuffer, TrajectoryHarvester
    from repro.serve.qos import (DegradationLadder, QoSAdmission,
                                 TenantRegistry)
    from repro.serve.service import QueryService
    from repro.baselines import CboReplanAgent
    db, est = _build_world(scale)
    pred = _one_shot_predictor(meta, cal_replay, smoke=args.smoke)
    adm = QoSAdmission(TenantRegistry(), predictor=pred,
                       ladder=DegradationLadder(rungs=((1.0, None),
                                                       (1.5, 1)),
                                                reject_above=2.0))
    svc = QueryService(db, CboReplanAgent(meta), est=est,
                       n_lanes=args.lanes, policy="edf", cluster=cluster,
                       admission=adm,
                       hooks=[TrajectoryHarvester(ReplayBuffer())])
    pr4_comps, _ = svc.run(stream)
    base = comps_by_arm["never+oneshot"]
    never_identical = (
        [c.seq for c in base] == [c.seq for c in pr4_comps] and
        [c.finish_t for c in base] == [c.finish_t for c in pr4_comps] and
        [c.traj.actions for c in base] ==
        [c.traj.actions for c in pr4_comps])
    log.info(f"never+oneshot == PR-4 path (no control plane): "
          f"{never_identical}")

    # ------------------------------------------------------------- gates
    nv, th = arms["never+oneshot"], arms["threshold+oneshot"]
    al, bg = arms["always+oneshot"], arms["budgeted+oneshot"]
    ad = arms["never+refresh"]
    trap_armed = nv["failed"] > 0 and nv["post_drift_p99"] >= TIMEOUT - 1
    refresh_fixes = (th["failed"] == 0 and al["failed"] == 0 and
                     th["post_drift_p99"] < nv["post_drift_p99"] / 5)
    budget_cheaper = (bg["reanalyze_modeled_s"] < al["reanalyze_modeled_s"]
                      and bg["post_drift_p99"] < nv["post_drift_p99"] / 5)
    adaptation_helps = (ad["slo_miss_rate"] < nv["slo_miss_rate"] and
                        ad["failed"] < nv["failed"] and
                        ad["goodput"] > nv["goodput"])
    ok = bool(never_identical) if args.smoke else bool(
        trap_armed and refresh_fixes and budget_cheaper and
        adaptation_helps and never_identical)
    log.info(f"gates: trap_armed={trap_armed} refresh_fixes={refresh_fixes} "
          f"budget_cheaper={budget_cheaper} "
          f"adaptation_helps={adaptation_helps} "
          f"never_identical={never_identical} -> ok={ok}")

    csv_line("drift_never_post_p99_s", 0, nv["post_drift_p99"])
    csv_line("drift_threshold_post_p99_s", 0, th["post_drift_p99"])
    csv_line("drift_adapt_miss_rate", 0, f"{ad['slo_miss_rate']:.3f}")
    csv_line("drift_budget_modeled_s", 0, bg["reanalyze_modeled_s"])
    emit_bench_json({
        "smoke": args.smoke, "scale": scale, "n_queries": n_queries,
        "n_lanes": args.lanes, "rate_qps": rate, "drift_at": drift_at,
        "growth_x": GROWTH_X, "slo_s": SLO, "timeout_s": TIMEOUT,
        "trap_every": TRAP_EVERY, "churn_every": churn_every,
        "analyze_cost_model_s": round(analyze_cost_s, 4),
        "arms": arms,
        "never_identical_to_pr4": never_identical,
        "gates": {"trap_armed": trap_armed,
                  "refresh_fixes": refresh_fixes,
                  "budget_cheaper": budget_cheaper,
                  "adaptation_helps": adaptation_helps,
                  "ok": ok},
    }, name="BENCH_drift.json")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
