"""SLO-watchdog benchmark: seeded ground-truth incidents scored on
detection latency and top-1 root-cause attribution — feeds
results/BENCH_monitor.json.

Four scenarios, each with a KNOWN injected cause at a KNOWN virtual
time, run with the `SloMonitor` attached (alerts unwired):

  bad_swap     bench_faults' scripted outage: an incumbent pinned to
               cbo-replan is hot-swapped mid-stream for a candidate
               pinned to noop — every post-swap stats-trap OOMs. Truth:
               policy_swap (the plan-provenance ledger holds the
               prior-step counterfactual for the same template+band).
  drift_trap   bench_drift's stale-stats world: a growth delta lands
               mid-stream and arms the trap queries; no recovery plane,
               so post-drift traps fail with OOM. Truth: stats_drift
               (delta_apply event + table-version band shift).
  fault_burst  the same world with stats in sync (no delta) and a
               `FaultInjector` confined to a seq window — a seeded
               outage with a start and an end; the retry ladder absorbs
               most of it, so the signature is retry traffic, not
               failures. Truth: fault_burst.
  hot_tenant   two-tenant stream on a 2-lane scheduler: tenant b's
               arrival rate jumps ~x40 at a known time and the queue
               backs up. No control-plane events at all — the quiet
               event log plus queue-dominant phase shift is the
               attribution. Truth: hot_tenant.

Scoring (per scenario): detection = first anomaly at/after the
injection time; detection lag in COMPLETIONS (virtual ticks — the
monitor observes once per completion) and virtual seconds; top-1 = the
detected incident's highest-scored hypothesis vs the ground truth.
Each scenario also re-runs with the monitor off (no tracer either):
completions must be BIT-IDENTICAL — the watchdog watches, it does not
steer. Gates: >= 3 of 4 detected, top-1 accuracy >= 2/3 among detected,
every detection lag <= 24 completions, every identity arm exact.

  PYTHONPATH=src python -m benchmarks.bench_monitor [--smoke]
"""
import bisect
import tempfile
import time

import numpy as np

from benchmarks.common import (bench_args, bench_logger, csv_line,
                               emit_bench_json)
from benchmarks.bench_faults import (CHAOS_SEED, DEMO_CAP, DEMO_GROWTH_ROWS,
                                     DEMO_SCALE, SLO, _build_world, _cluster,
                                     _force_head_action, _stream, _trap)

log = bench_logger("monitor")

SCALE = 0.06                   # bench_faults' smoke world (trap armed
CAP = 1_500_000                # under this materialize cap)
N_QUERIES = 64
DRIFT_AT = 24
BURST = (28, 44)               # fault_burst injector seq window
P_BURST_CRASH, P_BURST_TRANSIENT, P_BURST_SLOW = 0.02, 0.3, 0.1
T_FLOOD = 55.0                 # hot_tenant: virtual time the flood starts
LAG_BOUND = 24                 # max completions injection -> detection


def _monitor_cfg():
    from repro.serve.obs import MonitorConfig
    # one config for every scenario: windows sized so detectors are warm
    # well before each injection (earliest at completion 24) and the RCA
    # baseline is non-empty at detection (lookback < warm stream prefix)
    return MonitorConfig(window=12, min_warm=6, min_n=8, cooldown=6,
                         merge_gap=10, lookback=16, baseline_max=96)


def _sig(comps):
    return tuple((c.seq, round(c.finish_t, 9), round(c.latency, 9),
                  bool(c.result.failed), c.failure_kind, c.attempts)
                 for c in comps)


# -------------------------------------------------------------- scenarios
def _scn_bad_swap(meta, wl, *, lanes, monitored):
    """bench_faults' scripted bad swap, watched instead of broken."""
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.learn.policy_store import PolicyStore
    from repro.serve.deltas import DeltaBatch, apply_delta
    from repro.serve.obs import SloMonitor, Tracer
    from repro.serve.scheduler import Arrival, LaneScheduler
    from repro.sql import datagen
    from repro.sql.catalog import analyze
    from repro.sql.cbo import Estimator
    from benchmarks.bench_serve import fast_subset

    db = datagen.make_job_like(scale=DEMO_SCALE, seed=0)
    apply_delta(db, DeltaBatch("cast_info", n_append=DEMO_GROWTH_ROWS,
                               seed=999))
    db.stats = analyze(db, rng=np.random.default_rng(0))
    est = Estimator(db, db.stats)

    agent = AqoraAgent(meta, AgentConfig(max_steps=1), seed=0)
    _force_head_action(agent, 0)                 # action 0 == cbo(1)
    store = PolicyStore(tempfile.mkdtemp(prefix="bench_monitor_ps_"),
                        probe=[], mode="gate")
    store.commit(agent, 1)

    sched = LaneScheduler(db, est, agent, n_lanes=lanes,
                          cluster=_cluster(cap=DEMO_CAP))
    monitor = None
    if monitored:
        tracer = Tracer()
        tracer.attach(sched)
        store.obs = tracer                       # commits land in the log
        monitor = SloMonitor(config=_monitor_cfg(), store=store)
        monitor.attach(sched)

    n, swap_at = 60, 24
    traps = [_trap(i, 1896 + i) for i in range(5)]
    fast = fast_subset(wl)[:6]
    rng = np.random.default_rng(41)
    t, stream = 0.0, []
    for i in range(n):
        t += float(rng.exponential(0.5))
        q = traps[(i // 2) % 5] if i % 2 == 0 else fast[i % 6]
        stream.append(Arrival(t, query=q, seed=int(rng.integers(2 ** 31)),
                              deadline=t + SLO))

    def swapper(comp):
        if comp.seq == swap_at - 1 and store.serving_step == 1:
            _force_head_action(agent, agent.space.noop_idx)
            store.commit(agent, 2)
    sched.on_complete.insert(0, swapper)
    comps = sched.run(stream)
    if monitor is not None:
        monitor.finalize()
    inject_t = next(c.finish_t for c in comps if c.seq == swap_at - 1)
    return {"comps": comps, "monitor": monitor, "inject_t": inject_t,
            "truth": "policy_swap"}


def _scn_drift_trap(meta, wl, *, lanes, monitored):
    """bench_drift's stale-stats outage with NO recovery plane: every
    post-delta trap OOMs until the catalog is refreshed (it never is)."""
    from repro.baselines import CboReplanAgent
    from repro.serve.obs import SloMonitor
    from repro.serve.service import QueryService

    db, est = _build_world(SCALE)
    stream = _stream(wl, db, n_queries=N_QUERIES, rate=1.0, seed=31,
                     drift_at=DRIFT_AT)
    monitor = SloMonitor(config=_monitor_cfg()) if monitored else None
    svc = QueryService(db, CboReplanAgent(meta, max_steps=3), est=est,
                       n_lanes=lanes, cluster=_cluster(cap=CAP),
                       monitor=monitor)
    comps, _ = svc.run(stream)
    inject_t = next(a.t for a in stream if a.delta is not None)
    return {"comps": comps, "monitor": monitor, "inject_t": inject_t,
            "truth": "stats_drift"}


def _scn_fault_burst(meta, wl, *, lanes, monitored):
    """Same world, stats in sync (drift never lands), injector confined
    to a seq window; the retry ladder absorbs most of the burst."""
    from repro.baselines import CboReplanAgent
    from repro.serve.obs import SloMonitor
    from repro.serve.recover import (FaultInjector, RecoveryManager,
                                     RetryPolicy)
    from repro.serve.service import QueryService

    db, est = _build_world(SCALE)
    stream = _stream(wl, db, n_queries=N_QUERIES, rate=1.0, seed=31,
                     drift_at=10 ** 9)
    injector = FaultInjector(seed=CHAOS_SEED, p_crash=P_BURST_CRASH,
                             p_transient=P_BURST_TRANSIENT,
                             p_slow=P_BURST_SLOW, slow_factor=(8.0, 48.0),
                             window=BURST)
    mgr = RecoveryManager(injector=injector,
                          retry=RetryPolicy(max_attempts=3, backoff=0.5))
    monitor = SloMonitor(config=_monitor_cfg()) if monitored else None
    svc = QueryService(db, CboReplanAgent(meta, max_steps=3), est=est,
                       n_lanes=lanes, cluster=_cluster(cap=CAP),
                       recovery=mgr, monitor=monitor)
    comps, _ = svc.run(stream)
    inject_t = stream[BURST[0]].t        # no delta arrival: seq == index
    return {"comps": comps, "monitor": monitor, "inject_t": inject_t,
            "truth": "fault_burst"}


def _scn_hot_tenant(meta, wl, *, lanes, monitored):
    """Two tenants on TWO lanes: tenant b idles at 0.1 qps until the
    flood (24 arrivals at ~6 qps) backs the queue up. `lanes` is ignored
    on purpose — the scenario needs scarce capacity to show load."""
    del lanes
    from repro.baselines import CboReplanAgent
    from repro.serve.obs import SloMonitor
    from repro.serve.scheduler import Arrival
    from repro.serve.service import QueryService
    from benchmarks.bench_serve import fast_subset

    db, est = _build_world(SCALE)
    fast = fast_subset(wl)[:8]
    rng = np.random.default_rng(17)
    stream = []
    t, i = 0.0, 0
    while t < 90.0:                              # tenant a: steady 0.8 qps
        t += float(rng.exponential(1.0 / 0.8))
        stream.append(Arrival(t, query=fast[i % 8],
                              seed=int(rng.integers(2 ** 31)),
                              deadline=t + SLO, tenant="a"))
        i += 1
    t = 0.0
    while True:                                  # tenant b: trickle ...
        t += float(rng.exponential(1.0 / 0.1))
        if t >= T_FLOOD:
            break
        stream.append(Arrival(t, query=fast[(i + 3) % 8],
                              seed=int(rng.integers(2 ** 31)),
                              deadline=t + SLO, tenant="b"))
        i += 1
    t = T_FLOOD
    for j in range(24):                          # ... then the flood
        t += float(rng.exponential(1.0 / 6.0))
        stream.append(Arrival(t, query=fast[j % 8],
                              seed=int(rng.integers(2 ** 31)),
                              deadline=t + SLO, tenant="b"))
    stream.sort(key=lambda a: a.t)

    monitor = SloMonitor(config=_monitor_cfg()) if monitored else None
    svc = QueryService(db, CboReplanAgent(meta, max_steps=3), est=est,
                       n_lanes=2, cluster=_cluster(), monitor=monitor)
    comps, _ = svc.run(stream)
    return {"comps": comps, "monitor": monitor, "inject_t": T_FLOOD,
            "truth": "hot_tenant"}


# ---------------------------------------------------------------- scoring
def _grade(monitor, inject_t, truth):
    """Detection = first anomaly at/after the injection; lag counted in
    completions (the monitor's virtual tick). An incident opened BEFORE
    the injection is a false positive but does not mask detection — the
    flood anomaly may extend it, so grading is anomaly-level."""
    recs_t = [r["t"] for r in monitor.records]
    inject_idx = bisect.bisect_left(recs_t, inject_t)
    hit = None
    for inc in monitor.incidents:
        for a in inc.anomalies:
            if a.t >= inject_t - 1e-9:
                hit = (inc, a)
                break
        if hit:
            break
    out = {"truth": truth,
           "n_incidents": len(monitor.incidents),
           "false_incidents": sum(i.t_open < inject_t - 1e-9
                                  for i in monitor.incidents),
           "n_anomalies": monitor.totals()[0],
           "ledger_keys": len(monitor.ledger)}
    if hit is None:
        out.update({"detected": False, "correct": False,
                    "lag_bounded": False})
        return out
    inc, a = hit
    detect_idx = bisect.bisect_right(recs_t, a.t)
    lag = detect_idx - inject_idx
    top = inc.top
    out.update({
        "detected": True,
        "detected_metric": a.metric,
        "detect_lag_completions": lag,
        "detect_lag_virtual_s": round(a.t - inject_t, 3),
        "lag_bounded": lag <= LAG_BOUND,
        "top1": top.cause if top else None,
        "correct": bool(top and top.cause == truth),
        "summary": top.summary if top else "",
        "incident": inc.as_dict(),
    })
    return out


# ------------------------------------------------------------------- main
def main(argv=None):
    args = bench_args(argv, lanes=4)
    from repro.core.encoding import WorkloadMeta
    from repro.sql import workloads

    wl = workloads.make_workload("job", n_train=48, n_test_per_template=1,
                                 seed=7)
    meta = WorkloadMeta.from_workload(wl)

    scenarios = (("bad_swap", _scn_bad_swap),
                 ("drift_trap", _scn_drift_trap),
                 ("fault_burst", _scn_fault_burst),
                 ("hot_tenant", _scn_hot_tenant))
    log.info(f"== SLO watchdog: {len(scenarios)} seeded incidents "
             f"(swap/drift/burst/flood), lag bound {LAG_BOUND} completions, "
             f"identity arms {'drift_trap only (smoke)' if args.smoke else 'all'} ==")

    results = {}
    for name, fn in scenarios:
        t0 = time.perf_counter()
        on = fn(meta, wl, lanes=args.lanes, monitored=True)
        g = _grade(on["monitor"], on["inject_t"], on["truth"])
        # the identity arm re-runs the WHOLE scenario untraced and
        # unmonitored: completions must match the watched run bit-exactly
        if not args.smoke or name == "drift_trap":
            off = fn(meta, wl, lanes=args.lanes, monitored=False)
            g["bit_identical"] = _sig(on["comps"]) == _sig(off["comps"])
        g["host_seconds"] = round(time.perf_counter() - t0, 2)
        results[name] = g
        ident = g.get("bit_identical")
        log.info(
            f"{name:12s} detected={str(g['detected']):5s} "
            f"top1={g.get('top1') or '-':12s} correct={g['correct']} "
            f"lag={g.get('detect_lag_completions', '-')} completions "
            f"({g.get('detect_lag_virtual_s', '-')}s virtual) "
            f"false={g['false_incidents']} "
            f"identity={'-' if ident is None else ident} "
            f"[{g['host_seconds']:.1f}s host]")
        if g["detected"]:
            log.info(f"{'':12s} -> {g['summary']}")

    # ------------------------------------------------------------- gates
    n_det = sum(g["detected"] for g in results.values())
    n_cor = sum(g["correct"] for g in results.values())
    top1_acc = n_cor / max(n_det, 1)
    lags_ok = all(g["lag_bounded"] for g in results.values()
                  if g["detected"])
    ident_ok = all(g.get("bit_identical", True) for g in results.values())
    ok = bool(n_det >= 3 and n_cor >= 2 and top1_acc >= 2 / 3
              and lags_ok and ident_ok)
    log.info(f"gates: detected={n_det}/{len(scenarios)} "
             f"top1_acc={top1_acc:.2f} lags_bounded={lags_ok} "
             f"bit_identical={ident_ok} -> ok={ok}")

    csv_line("monitor_detected", 0, n_det)
    csv_line("monitor_top1_acc", 0, round(top1_acc, 4))
    emit_bench_json({
        "smoke": args.smoke, "n_lanes": args.lanes,
        "lag_bound_completions": LAG_BOUND,
        "monitor_config": {"window": 12, "min_warm": 6, "min_n": 8,
                           "cooldown": 6, "merge_gap": 10, "lookback": 16,
                           "baseline_max": 96},
        "scenarios": results,
        "gates": {"n_detected": n_det, "n_correct": n_cor,
                  "top1_acc": round(top1_acc, 4), "lags_bounded": lags_ok,
                  "bit_identical": ident_ok, "ok": ok},
    }, name="BENCH_monitor.json")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
