"""Shared helpers for the benchmark suite: result loading + formatting."""
from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]
AQORA = ROOT / "results" / "aqora"
DRYRUN = ROOT / "results" / "dryrun"
PERF = ROOT / "results" / "perf"

METHODS = ("spark", "lero", "autosteer", "aqora")


def load(name: str):
    p = AQORA / f"{name}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def totals(rows):
    return {"total": sum(r["total"] for r in rows),
            "exec": sum(r["latency"] for r in rows),
            "plan": sum(r["plan_time"] for r in rows),
            "fails": sum(r["failed"] for r in rows)}


def pct(rows, q):
    import numpy as np
    xs = sorted(r["total"] for r in rows)
    return float(np.percentile(xs, q))


def csv_line(name, us_per_call, derived):
    print(f"CSV,{name},{us_per_call},{derived}")
