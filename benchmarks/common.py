"""Shared helpers for the benchmark suite: result loading + formatting,
plus the serving benchmarks' common CLI (--smoke/--lanes) and JSON-result
emission (bench_serve / bench_online / bench_qos all go through
`bench_args` + `emit_bench_json`)."""
from __future__ import annotations

import argparse
import json
import logging
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
AQORA = ROOT / "results" / "aqora"
DRYRUN = ROOT / "results" / "dryrun"
PERF = ROOT / "results" / "perf"

METHODS = ("spark", "lero", "autosteer", "aqora")


def load(name: str):
    p = AQORA / f"{name}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def totals(rows):
    return {"total": sum(r["total"] for r in rows),
            "exec": sum(r["latency"] for r in rows),
            "plan": sum(r["plan_time"] for r in rows),
            "fails": sum(r["failed"] for r in rows)}


def pct(rows, q):
    import numpy as np
    xs = sorted(r["total"] for r in rows)
    return float(np.percentile(xs, q))


def bench_logger(name: str = "") -> logging.Logger:
    """The benchmark suite's logger under the `repro.bench` hierarchy:
    message-only stdout lines (same surface the prints produced), root
    configured once, children share it. Mirrors the `repro.train`
    hierarchy PR 3 set up for the training drivers."""
    root = logging.getLogger("repro.bench")
    if not root.handlers:
        h = logging.StreamHandler(sys.stdout)
        h.setFormatter(logging.Formatter("%(message)s"))
        root.addHandler(h)
        root.setLevel(logging.INFO)
        root.propagate = False
    return root.getChild(name) if name else root


def csv_line(name, us_per_call, derived):
    bench_logger().info(f"CSV,{name},{us_per_call},{derived}")


def update_bench_json(entries: dict, name: str = "BENCH_rollout.json"):
    """Merge `entries` into results/<name> so perf trajectories accumulate
    across benchmark modules (bench_kernels + bench_query_perf both feed
    BENCH_rollout.json)."""
    p = ROOT / "results" / name
    p.parent.mkdir(parents=True, exist_ok=True)
    data = json.loads(p.read_text()) if p.exists() else {}
    data.update(entries)
    p.write_text(json.dumps(data, indent=2, sort_keys=True))
    return p


def bench_args(argv=None, *, lanes: int = 8, extra=None):
    """The serving benchmarks' shared CLI: `--smoke` (tiny scale for CI)
    and `--lanes`. `extra(parser)` may add benchmark-specific flags."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale for CI (seconds, not minutes)")
    ap.add_argument("--lanes", type=int, default=lanes)
    if extra is not None:
        extra(ap)
    return ap.parse_args(argv)


def emit_bench_json(entries: dict, name: str):
    """Persist one serving benchmark's result blob and announce the path."""
    p = update_bench_json(entries, name=name)
    bench_logger().info(f"wrote {p}")
    return p
