"""Paper Fig. 11(b) + Tab. III: encoder architectures — parameters,
optimization overhead (mean per-query plan time at evaluation), final cost."""
import json

from benchmarks.common import AQORA, bench_logger, csv_line

log = bench_logger("ablation_net")


def _params(net: str) -> int:
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    meta = WorkloadMeta(table_index={f"t{i}": i for i in range(21)},
                        n_tables_max=17)
    return AqoraAgent(meta, AgentConfig(net=net), seed=0).param_count()


def main():
    p = AQORA / "ablations.json"
    if not p.exists():
        log.info("bench_ablation_net: missing results")
        return False
    d = json.loads(p.read_text())
    log.info("\n== Fig. 11(b)/Tab. III: decision-model architectures (ExtJOB) ==")
    log.info(f"{'model':12s} {'params':>9s} {'opt overhead/query':>19s} "
          f"{'test C (s)':>11s} {'fails':>5s}")
    for net, key in (("treecnn", "rl_ppo"), ("lstm", "net_lstm"),
                     ("fcnn", "net_fcnn"), ("queryformer", "net_queryformer")):
        if key not in d:
            continue
        r = d[key]
        n = len(r["per_query"])
        ovh = r["plan"] / max(n, 1)
        log.info(f"{net:12s} {_params(net):9d} {ovh * 1000:16.0f} ms "
              f"{r['total']:11.1f} {r['fails']:5d}")
        csv_line(f"tab3_{net}_overhead_ms", f"{ovh * 1e6:.0f}", f"{r['total']:.1f}")
    return True


if __name__ == "__main__":
    main()
