"""Observability benchmark: prices the tracer and demos the explainer —
feeds results/BENCH_obs.json.

Segment A (overhead): bench_serve's straggler-heavy mix replayed twice
through the SAME service configuration — obs off, then obs on with a
full `Tracer` (span trees + metrics sampling + flight recorder). The
virtual-clock completions must be BIT-IDENTICAL (the tracer only
observes; every emit point short-circuits to the untraced code path on
the off run), so the host-seconds delta is pure tracing cost, reported
as a percent and as microseconds per query.

Segment B (explainer): bench_faults' seeded chaos storm served through
its "none" (faults fire, nothing recovers) and "full" (retry ladder +
hedges) recovery arms, each with a tracer attached. The trace-diff
explainer aligns the two runs by stream seq and attributes the p99 gap
to phases (queue / execute / retry / hedge). Gate: the per-phase deltas
sum EXACTLY to the p99 delta, and that delta matches the independently
computed np.percentile gap. The full arm's trace is also exported to
JSONL and schema-validated end to end.

  PYTHONPATH=src python -m benchmarks.bench_obs [--smoke]
"""
import pathlib
import time

import numpy as np

from benchmarks.common import (ROOT, bench_args, bench_logger, csv_line,
                               emit_bench_json)

log = bench_logger("obs")


def _sig(comps):
    """Completion identity tuple: any tracing side effect on scheduling,
    executor charging or recovery shows up here."""
    return [(c.seq, c.admit_t, c.finish_t, c.lane, c.attempts,
             bool(c.result.failed)) for c in comps]


# ------------------------------------------------------------ segment A
def bench_overhead(args):
    from repro.serve.obs import Tracer
    from repro.serve.service import QueryService
    from benchmarks.bench_serve import STRAG_EVERY, _build, _mix_stream

    scale = 0.04 if args.smoke else 0.1
    n_queries = 24 if args.smoke else 96
    rate = 4.0
    reps = 1 if args.smoke else 3

    db, wl, est, agent = _build(scale)
    # warm the jit caches so host timings reflect steady state
    QueryService(db, agent, est=est, n_lanes=args.lanes).run_queries(
        wl.train[:args.lanes])

    log.info(f"\n== obs overhead: {n_queries} queries "
             f"(1 straggler per {STRAG_EVERY}), {args.lanes} lanes, "
             f"best of {reps} ==")
    host = {}
    sigs = {}
    tracer = None
    for mode in ("off", "on"):
        best = float("inf")
        for _ in range(reps):
            obs = Tracer() if mode == "on" else None
            stream = _mix_stream(wl, n_queries, rate, seed=11)
            svc = QueryService(db, agent, est=est, n_lanes=args.lanes,
                               obs=obs)
            t0 = time.perf_counter()
            comps, _ = svc.run(stream)
            best = min(best, time.perf_counter() - t0)
        host[mode] = best
        sigs[mode] = _sig(comps)
        if mode == "on":
            tracer = obs

    identical = sigs["off"] == sigs["on"]
    delta = host["on"] - host["off"]
    pct = 100.0 * delta / max(host["off"], 1e-9)
    us_q = 1e6 * delta / n_queries
    snap = tracer.metrics.snapshot()
    out = {
        "scale": scale, "n_queries": n_queries, "rate_qps": rate,
        "reps": reps,
        "host_off_s": round(host["off"], 4),
        "host_on_s": round(host["on"], 4),
        "overhead_pct": round(pct, 2),
        "us_per_query": round(us_q, 1),
        "n_spans": len(tracer.spans),
        "n_events": len(tracer.events),
        "n_metric_samples": snap["n_samples"],
        "completions_identical": identical,
    }
    log.info(f"off={host['off']:.3f}s on={host['on']:.3f}s "
             f"overhead={pct:+.1f}% ({us_q:+.0f}us/query)  "
             f"spans={out['n_spans']} events={out['n_events']} "
             f"samples={out['n_metric_samples']}  "
             f"completions bit-identical: "
             f"{'OK' if identical else 'MISMATCH'}")
    return out, identical


# ------------------------------------------------------------ segment B
def bench_explainer(args):
    from repro.baselines import CboReplanAgent
    from repro.core.encoding import WorkloadMeta
    from repro.serve.obs import Tracer
    from repro.serve.obs.explain import (diff_profiles, format_diff,
                                         run_profile)
    from repro.serve.obs.export import (validate_trace_jsonl,
                                        write_trace_jsonl)
    from repro.serve.service import QueryService
    from repro.sql import workloads
    from benchmarks.bench_faults import (CHAOS_SEED, _build_world, _cluster,
                                         _hedge_predictor, _recovery,
                                         _stream)

    scale = 0.06 if args.smoke else 0.2
    n_queries = 40 if args.smoke else 150
    drift_at = 10 if args.smoke else 25
    cap = 1_500_000 if args.smoke else None

    wl = workloads.make_workload("job", n_train=48, n_test_per_template=1,
                                 seed=7)
    meta = WorkloadMeta.from_workload(wl)
    db0, _ = _build_world(scale)
    stream = _stream(wl, db0, n_queries=n_queries, rate=1.0, seed=31,
                     drift_at=drift_at)
    log.info(f"\n== obs explainer: bench_faults chaos storm "
             f"(seed {CHAOS_SEED}), {n_queries} queries, {args.lanes} "
             f"lanes, arms none vs full ==")
    predictor = _hedge_predictor(meta, stream, scale=scale, cap=cap,
                                 n_lanes=args.lanes, smoke=args.smoke)

    profiles, p99, tracers = {}, {}, {}
    for arm in ("none", "full"):
        # bench_faults._serve_arm, plus a tracer on the service
        db, est = _build_world(scale)
        tracer = Tracer()
        svc = QueryService(db, CboReplanAgent(meta, max_steps=3), est=est,
                           n_lanes=args.lanes, cluster=_cluster(cap=cap),
                           recovery=_recovery(arm, predictor), obs=tracer)
        comps, _ = svc.run(stream)
        profiles[arm] = run_profile(tracer)
        p99[arm] = float(np.percentile([c.latency for c in comps], 99))
        tracers[arm] = tracer

    diff = diff_profiles(profiles["none"], profiles["full"],
                         label_a="none", label_b="full", q=99.0)
    log.info(format_diff(diff))

    # the attribution gates: phase deltas sum exactly to the explainer's
    # p99 delta, and that delta IS the observed np.percentile gap
    phase_sum = sum(diff["pq"]["phases"].values())
    exact = abs(phase_sum - diff["pq"]["delta"]) < 1e-9
    observed_gap = p99["full"] - p99["none"]
    matches = abs(diff["pq"]["delta"] - observed_gap) < 1e-6
    log.info(f"p99 gap: observed {observed_gap:+.3f}s, attributed "
             f"{phase_sum:+.3f}s -> exact_sum={exact} "
             f"matches_observed={matches}")

    # export the full arm's trace and validate the schema end to end
    out_dir = ROOT / "results"
    out_dir.mkdir(parents=True, exist_ok=True)
    jsonl = str(out_dir / "trace_faults_full.jsonl")
    write_trace_jsonl(tracers["full"], jsonl)
    errors = validate_trace_jsonl(jsonl)
    n_lines = sum(1 for _ in open(jsonl))
    log.info(f"exported {jsonl} ({n_lines} lines) -> "
             f"{len(errors)} schema errors")
    for e in errors[:5]:
        log.info(f"  {e}")

    n_dumps = len(tracers["none"].flight.dumps)
    out = {
        "scale": scale, "n_queries": n_queries, "drift_at": drift_at,
        "chaos_seed": CHAOS_SEED,
        "p99_none": p99["none"], "p99_full": p99["full"],
        "observed_p99_gap": observed_gap,
        "attributed_p99_gap": phase_sum,
        "diff": diff,
        "n_events_none": len(tracers["none"].events),
        "n_events_full": len(tracers["full"].events),
        "n_flight_dumps_none": n_dumps,
        "export": {"path": str(pathlib.Path(jsonl).relative_to(ROOT)),
                   "n_lines": n_lines, "n_errors": len(errors)},
    }
    ok = exact and matches and not errors
    return out, {"attribution_exact": exact,
                 "attribution_matches_observed": matches,
                 "export_valid": not errors, "ok": ok}


# ----------------------------------------------------------------- main
def main(argv=None):
    args = bench_args(argv, lanes=6)
    overhead, identical = bench_overhead(args)
    explainer, gates = bench_explainer(args)

    ok = bool(identical and gates["ok"])
    log.info(f"gates: completions_identical={identical} "
             f"attribution_exact={gates['attribution_exact']} "
             f"matches_observed={gates['attribution_matches_observed']} "
             f"export_valid={gates['export_valid']} -> ok={ok}")

    csv_line("obs_overhead_pct", 0, overhead["overhead_pct"])
    csv_line("obs_us_per_query", 0, overhead["us_per_query"])
    csv_line("obs_p99_gap_attributed_s",
             0, round(explainer["attributed_p99_gap"], 3))
    emit_bench_json({
        "smoke": args.smoke, "n_lanes": args.lanes,
        "overhead": overhead, "explainer": explainer,
        "gates": {"completions_identical": identical, **gates, "ok": ok},
    }, name="BENCH_obs.json")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
