"""Paper Fig. 8: p30/p60/p90/p99 end-to-end latencies per method."""
from benchmarks.common import METHODS, bench_logger, csv_line, load, pct

log = bench_logger("tails")


def main():
    log.info("\n== Fig. 8: percentile end-to-end latencies (s) ==")
    ok = False
    for bench in ("job", "extjob", "stack"):
        d = load(bench)
        if d is None:
            continue
        ok = True
        log.info(f"\n[{bench}]  {'method':10s} " +
              " ".join(f"{f'p{q}':>8s}" for q in (30, 60, 90, 99)))
        for m in METHODS:
            ps = [pct(d[m], q) for q in (30, 60, 90, 99)]
            log.info(f"          {m:10s} " + " ".join(f"{p:8.2f}" for p in ps))
        csv_line(f"fig8_{bench}_aqora_p99", 0, f"{pct(d['aqora'], 99):.2f}")
    return ok


if __name__ == "__main__":
    main()
