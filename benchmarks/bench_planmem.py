"""Plan-memory + superoptimization benchmark: repeated-template serving
under drift — feeds results/BENCH_planmem.json.

Production streams repeat templates; LQRS re-decides every arrival from
scratch. This benchmark prices the PR-10 alternative — memoize the best
known action sequence per (template x table-version band), replay it
ahead of the agent, and spend idle completion cadence on a background
beam-search superoptimizer — against the strongest memory-less arm the
repo has (the PR-3 lifelong-learning loop).

Workload: the PR-3 drifting trap stream over a JOB-like database.
Trap templates are written fact-fact first; their syntactic order is
mediocre pre-drift (CBO reordering is strictly better under the stale
stats) and catastrophic post-drift (a cast_info growth delta pushes the
fact-fact join past the materialize cap: 300s timeout). Safe orders
stay seconds at all times, so plan quality — not caching — dominates
the percentiles, and the mid-stream delta exercises the memory's
fencing + re-promotion path. Four arms on fresh identical databases:

  frozen   cold policy, argmax, no memory (served twice: determinism).
  memoff   frozen + ATTACHED but empty PlanMemory, serving ingest off —
           must be completion-bit-identical to `frozen` (the memory's
           off-switch pin, same discipline as obs/qos).
  online   the full PR-3 loop (harvest, prioritized replay, background
           PPO, gated hot-swap, curriculum) with exploring lanes.
  memo     plan memory (serving ingest on) + background superoptimizer:
           hits replay with ZERO act_batch participation; the
           superoptimizer beam-searches hot templates and promotes only
           candidates that beat the re-simulated incumbent — finding
           the safe trap orders by deterministic search instead of
           stochastic exploration + gradient steps.

Reported per arm: p50/p99 virtual latency (whole stream + post-drift),
failures, host seconds, act calls per query (sum of decide-batch sizes
/ queries — the host-side policy load a memo hit removes). Gates (full
run): frozen bit-deterministic, memoff bit-identical to frozen, memo
beats online on p50 AND on act calls per query. Smoke gates determinism
+ bit-identity + the act-call win.

  PYTHONPATH=src python -m benchmarks.bench_planmem [--smoke]
"""
import tempfile
import time

import numpy as np

from benchmarks.bench_online import _trap_query
from benchmarks.common import (bench_args, bench_logger, csv_line,
                               emit_bench_json)

log = bench_logger("planmem")


def _stream(wl, db, *, n_queries: int, rate: float, seed: int,
            drift_at: int, growth: int, churn_every: int):
    """Open-loop trap-heavy repeated-template arrivals: two of every
    three queries cycle six trap templates, the rest cycle fast
    dimension joins; one cast_info growth delta after `drift_at`
    queries (fences every trap entry), then movie_keyword churn
    (version bumps outside the trap band)."""
    from repro.serve.deltas import DeltaBatch
    from repro.serve.scheduler import Arrival

    rng = np.random.default_rng(seed)
    fast = [q for q in wl.train if q.n_relations <= 10][:8] or wl.train[:8]
    traps = [_trap_query(i, 1935 + 3 * i) for i in range(6)]
    ci_rows = db.table("cast_info").nrows
    mk_rows = db.table("movie_keyword").nrows
    t, out, since_churn = 0.0, [], 0
    for i in range(n_queries):
        t += float(rng.exponential(1.0 / rate))
        q = fast[i % len(fast)] if i % 3 == 2 else traps[i % len(traps)]
        out.append(Arrival(t, query=q, seed=int(rng.integers(2 ** 31))))
        if i + 1 == drift_at:
            out.append(Arrival(t, delta=DeltaBatch(
                "cast_info", n_append=growth * ci_rows, seed=999)))
        elif i + 1 > drift_at:
            since_churn += 1
            if since_churn >= churn_every:
                since_churn = 0
                out.append(Arrival(t, delta=DeltaBatch(
                    "movie_keyword", n_append=mk_rows // 50,
                    delete_frac=0.02, seed=1000 + i)))
    return out


def _fresh_env(scale: float):
    from repro.sql import datagen
    from repro.sql.cbo import Estimator
    db = datagen.make_job_like(scale=scale, seed=0)
    return db, Estimator(db, db.stats)


def _serve(db, est, agent, stream, *, lanes, explore=False, hooks=(),
           plan_memory=None):
    from repro.serve.service import QueryService
    svc = QueryService(db, agent, est=est, n_lanes=lanes, policy="async",
                       explore=explore, hooks=list(hooks),
                       plan_memory=plan_memory)
    t0 = time.perf_counter()
    comps, stats = svc.run(stream)
    host = time.perf_counter() - t0
    act_per_q = sum(svc.scheduler.decide_sizes) / max(len(comps), 1)
    return comps, stats, host, act_per_q


def _sig(comps):
    return [(c.seq, c.admit_t, c.finish_t, tuple(c.traj.actions))
            for c in comps]


def _pcts(comps):
    lat = np.asarray([c.latency for c in comps])
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def _row(comps, stream, host, act_per_q):
    p50, p99 = _pcts(comps)
    drift_t = next(a.t for a in stream if a.delta is not None)
    dp50, dp99 = _pcts([c for c in comps if c.arrival_t > drift_t])
    return {"p50": round(p50, 3), "p99": round(p99, 3),
            "post_drift_p50": round(dp50, 3),
            "post_drift_p99": round(dp99, 3),
            "failed": int(sum(c.result.failed for c in comps)),
            "host_seconds": round(host, 2),
            "act_calls_per_query": round(act_per_q, 3)}


def main(argv=None):
    args = bench_args(argv, lanes=6)

    from repro.checkpoint import agent_state, copy_tree, install_agent_state
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    from repro.learn import (AdaptiveCurriculum, PolicyStore, ReplayBuffer,
                             make_online_loop)
    from repro.serve.plans import PlanMemory, Superoptimizer
    from repro.sql import workloads

    scale = 0.05 if args.smoke else 0.2
    n_queries = 24 if args.smoke else 96
    drift_at = 8 if args.smoke else 24
    rate, growth, churn_every = 2.0, 8, 16

    wl = workloads.make_workload("job", n_train=48, n_test_per_template=1,
                                 seed=7)
    meta = WorkloadMeta.from_workload(wl)
    serving_agent = AqoraAgent(meta, AgentConfig(), seed=0)
    learner_agent = AqoraAgent(meta, AgentConfig(), seed=1)
    init_s = copy_tree(agent_state(serving_agent))
    init_l = copy_tree(agent_state(learner_agent))
    probe = [_trap_query(100, 1938), _trap_query(101, 1944), wl.test[0]]

    db0, _ = _fresh_env(scale)
    stream = _stream(wl, db0, n_queries=n_queries, rate=rate, seed=17,
                     drift_at=drift_at, growth=growth,
                     churn_every=churn_every)
    n_traps = sum(a.query is not None and a.query.name.startswith("trap")
                  for a in stream)
    n_deltas = sum(a.delta is not None for a in stream)
    log.info(f"== plan memory + superopt under drift: {n_queries} queries "
             f"({n_traps} trap), {n_deltas} deltas, {args.lanes} lanes, "
             f"open-loop {rate} qps ==")

    tmp_root = tempfile.TemporaryDirectory(prefix="bench_planmem_ps_")
    rows = {}

    def reset_agents():
        install_agent_state(serving_agent, init_s, copy=True)
        install_agent_state(learner_agent, init_l, copy=True)

    # -- frozen (twice: determinism pin) ------------------------------
    def frozen_pass():
        reset_agents()
        db, est = _fresh_env(scale)
        return _serve(db, est, serving_agent, stream, lanes=args.lanes)

    fr_comps, _, fr_host, fr_act = frozen_pass()
    fr2_comps, _, _, _ = frozen_pass()
    deterministic = _sig(fr_comps) == _sig(fr2_comps)
    rows["frozen"] = _row(fr_comps, stream, fr_host, fr_act)

    # -- memoff: attached-but-empty memory must not perturb anything --
    reset_agents()
    db, est = _fresh_env(scale)
    mem_off = PlanMemory(ingest_serving=False)
    mo_comps, _, mo_host, mo_act = _serve(db, est, serving_agent, stream,
                                          lanes=args.lanes,
                                          plan_memory=mem_off)
    memoff_identical = _sig(mo_comps) == _sig(fr_comps)
    rows["memoff"] = _row(mo_comps, stream, mo_host, mo_act)

    # -- online: the full PR-3 lifelong loop, no memory ---------------
    reset_agents()
    db, est = _fresh_env(scale)
    store = PolicyStore(f"{tmp_root.name}/store", probe, mode="gate")
    on_hooks = make_online_loop(
        serving_agent, store=store,
        curriculum=AdaptiveCurriculum(window=8, min_dwell=8),
        replay=ReplayBuffer(capacity=256, regret_scale=2.0,
                            regret_cap=8.0, fail_boost=1.5),
        update_every=3, sample_size=8, gate_every=2, seed=3,
        learner_agent=learner_agent)
    on_comps, _, on_host, on_act = _serve(db, est, serving_agent, stream,
                                          lanes=args.lanes, explore=True,
                                          hooks=on_hooks)
    rows["online"] = _row(on_comps, stream, on_host, on_act)

    # -- memo: plan memory + background superoptimizer ----------------
    reset_agents()
    db, est = _fresh_env(scale)
    memory = PlanMemory()
    superopt = Superoptimizer(memory, opt_every=4, sim_budget=24)
    me_comps, me_stats, me_host, me_act = _serve(
        db, est, serving_agent, stream, lanes=args.lanes,
        hooks=[superopt], plan_memory=memory)
    rows["memo"] = _row(me_comps, stream, me_host, me_act)
    rows["memo"]["memory"] = memory.stats()
    so = superopt.summary()
    rows["memo"]["superopt"] = {k: so[k] for k in
                               ("rounds", "sims", "promotions",
                                "skipped_no_gain", "host_seconds")}

    for name in ("frozen", "memoff", "online", "memo"):
        r = rows[name]
        log.info(f"{name:7s} p50={r['p50']:7.2f}s p99={r['p99']:7.2f}s | "
                 f"post-drift p50={r['post_drift_p50']:7.2f}s "
                 f"p99={r['post_drift_p99']:7.2f}s | fails={r['failed']:3d} "
                 f"act/q={r['act_calls_per_query']:5.2f} "
                 f"host={r['host_seconds']:6.1f}s")
    log.info(f"frozen deterministic: {deterministic};  memoff "
             f"bit-identical: {memoff_identical};  memoized "
             f"{me_stats.n_memoized}/{len(me_comps)} completions, "
             f"{rows['memo']['superopt']['promotions']} superopt "
             f"promotions, {memory.stats()['fenced']} fences")

    ok_p50 = rows["memo"]["p50"] <= rows["online"]["p50"]
    ok_act = rows["memo"]["act_calls_per_query"] \
        < rows["online"]["act_calls_per_query"]
    ok = bool(deterministic and memoff_identical and ok_act
              and (args.smoke or ok_p50))

    csv_line("planmem_memo_p50", 0, rows["memo"]["p50"])
    csv_line("planmem_online_p50", 0, rows["online"]["p50"])
    csv_line("planmem_act_per_query", 0,
             rows["memo"]["act_calls_per_query"])
    emit_bench_json({
        "smoke": args.smoke,
        "world": {"scale": scale, "n_queries": n_queries,
                  "n_traps": n_traps, "n_deltas": n_deltas,
                  "drift_at": drift_at},
        **rows,
        "frozen_deterministic": deterministic,
        "memoff_bit_identical": memoff_identical,
        "memo_beats_online_p50": ok_p50,
        "memo_beats_online_act": ok_act,
        "gates_ok": ok,
    }, name="BENCH_planmem.json")
    tmp_root.cleanup()
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
