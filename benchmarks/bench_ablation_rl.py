"""Paper Fig. 11(a): PPO vs DQN — training convergence + final test cost."""
import json

from benchmarks.common import AQORA, bench_logger, csv_line

log = bench_logger("ablation_rl")


def main():
    p = AQORA / "ablations.json"
    if not p.exists():
        log.info("bench_ablation_rl: missing results")
        return False
    d = json.loads(p.read_text())
    log.info("\n== Fig. 11(a): PPO vs DQN on ExtJOB ==")
    for k, label in (("rl_ppo", "AQORA (PPO)"), ("rl_dqn", "DQN variant")):
        if k not in d:
            continue
        r = d[k]
        curve = " ".join(f"{c:6.1f}" for c in r.get("curve", [])[:10])
        log.info(f"{label:14s} test C={r['total']:8.1f}s fails={r['fails']}  "
              f"train-latency curve (30-ep means): {curve}")
    if "rl_ppo" in d and "rl_dqn" in d:
        csv_line("fig11a_ppo_vs_dqn", 0,
                 f"{d['rl_dqn']['total'] / max(d['rl_ppo']['total'], 1e-9):.3f}")
    return True


if __name__ == "__main__":
    main()
