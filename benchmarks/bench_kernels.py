"""Kernel microbenchmarks: wall time of the jnp reference paths on CPU
(the Pallas kernels execute only under interpret=True here, which measures
Python emulation, not TPU perf — the roofline table is the TPU-side
evidence; these numbers track the *reference* implementations)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_logger, csv_line, update_bench_json

log = bench_logger("kernels")


def _time(fn, *args, iters=5):
    # single warmup call (jax.block_until_ready handles tuples/pytrees);
    # calling fn twice here used to double-compile and double-run setup work
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    from repro.kernels.ref import flash_attention_ref, mamba_scan_ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v))
    us = _time(fa, q, k, v)
    log.info(f"\n== kernel reference microbenchmarks (CPU) ==")
    log.info(f"attention_ref 8x512x64:   {us:10.0f} us/call")
    csv_line("attention_ref_8x512x64", f"{us:.0f}", "oracle")

    x = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((2, 256, 64))) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal((64, 16))), jnp.float32)
    Bs = jnp.asarray(rng.standard_normal((2, 256, 16)), jnp.float32)
    Cs = jnp.asarray(rng.standard_normal((2, 256, 16)), jnp.float32)
    ms = jax.jit(lambda *a: mamba_scan_ref(*a)[0])
    us = _time(ms, x, dt, A, Bs, Cs)
    log.info(f"mamba_scan_ref 2x256x64:  {us:10.0f} us/call")
    csv_line("mamba_scan_ref_2x256x64", f"{us:.0f}", "oracle")

    # TreeCNN inference latency (the per-stage decision cost, Tab. III)
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import MAX_NODES, WorkloadMeta
    meta = WorkloadMeta(table_index={f"t{i}": i for i in range(21)},
                        n_tables_max=17)
    agent = AqoraAgent(meta, AgentConfig(), seed=0)
    feat = np.zeros((MAX_NODES, meta.feat_dim), np.float32)
    li = np.zeros(MAX_NODES, np.int32)
    ri = np.zeros(MAX_NODES, np.int32)
    mask = np.ones(MAX_NODES, np.float32)
    t0 = time.perf_counter()
    for _ in range(20):
        agent.policy_probs((feat, li, ri, mask), np.ones(agent.space.d, np.float32))
    us = (time.perf_counter() - t0) / 20 * 1e6
    log.info(f"treecnn policy inference: {us:10.0f} us/call "
          f"(paper Tab. III: 317 ms/query incl. engine round-trips)")
    csv_line("treecnn_policy_inference", f"{us:.0f}", "per-stage decision")

    # fused VMEM-resident TreeCNN encoder vs the vmapped jnp reference.
    # On CPU the fused kernel runs under interpret=True (Python emulation,
    # not TPU perf) — the unfused number is the meaningful CPU datum; both
    # are recorded so the TPU-side trajectory has a baseline row.
    from repro.core import nets
    from repro.kernels.tree_conv import tree_cnn_fused
    rng2 = np.random.default_rng(1)
    B, N, F, H = 8, 64, meta.feat_dim, 96
    tfeat = jnp.asarray(rng2.standard_normal((B, N, F)), jnp.float32)
    tleft = jnp.asarray(rng2.integers(0, N, (B, N)), jnp.int32)
    tright = jnp.asarray(rng2.integers(0, N, (B, N)), jnp.int32)
    tmask = jnp.asarray((rng2.random((B, N)) > 0.4), jnp.float32)
    params = agent.actor["enc"]
    unfused = jax.jit(lambda *a: nets.apply_encoder(params, "treecnn", *a))
    us_unfused = _time(unfused, tfeat, tleft, tright, tmask)
    log.info(f"treecnn batch-8 unfused:  {us_unfused:10.0f} us/call (jnp vmap)")
    csv_line("treecnn_b8_unfused", f"{us_unfused:.0f}", "vmap reference")
    on_tpu = jax.default_backend() == "tpu"
    us_fused = _time(lambda *a: tree_cnn_fused(*a, params), tfeat, tleft,
                     tright, tmask, iters=5 if on_tpu else 1)
    mode = "pallas" if on_tpu else "pallas-interpret"
    log.info(f"treecnn batch-8 fused:    {us_fused:10.0f} us/call ({mode})")
    csv_line("treecnn_b8_fused", f"{us_fused:.0f}", mode)
    update_bench_json({"treecnn_b8_unfused_us": round(us_unfused, 1),
                       "treecnn_b8_fused_us": round(us_fused, 1),
                       "treecnn_fused_mode": mode})
    return True


if __name__ == "__main__":
    main()
