"""Paper Fig. 7 (end-to-end / optimization / raw execution time per method
per benchmark), Fig. 10 (top-10 improved queries), §VII-C3 (bushy-plan
proportion) — plus the batched-rollout-engine throughput benchmark
(episodes/sec, serial vs lockstep batch_size=8), which feeds
results/BENCH_rollout.json so the perf trajectory is tracked per PR."""
import time

from benchmarks.common import (METHODS, bench_logger, csv_line, load, totals,
                               update_bench_json)
log = bench_logger("query_perf")



def fig7():
    log.info("\n== Fig. 7: query performance on three benchmarks (seconds) ==")
    log.info(f"{'bench':8s} {'method':10s} {'C (e2e)':>10s} {'C_exec':>10s} "
          f"{'C_plan':>9s} {'fails':>5s}")
    ok = False
    for bench in ("job", "extjob", "stack"):
        d = load(bench)
        if d is None:
            log.info(f"{bench:8s} -- missing (run repro.experiments.main_experiment)")
            continue
        ok = True
        base = totals(d["spark"])["total"]
        for m in METHODS:
            t = totals(d[m])
            log.info(f"{bench:8s} {m:10s} {t['total']:10.1f} {t['exec']:10.1f} "
                  f"{t['plan']:9.1f} {t['fails']:5d}"
                  + (f"   ({(base - t['total']) / base:+.1%} vs spark)"
                     if m != "spark" else ""))
        aq = totals(d["aqora"])["total"]
        csv_line(f"fig7_{bench}_aqora_vs_spark", 0, f"{(base - aq) / base:.3f}")
    return ok


def fig10_top10():
    log.info("\n== Fig. 10: top-10 queries improved by AQORA vs Spark default ==")
    for bench in ("job", "extjob", "stack"):
        d = load(bench)
        if d is None:
            continue
        sp = {r["query"]: r["total"] for r in d["spark"]}
        aq = {r["query"]: r["total"] for r in d["aqora"]}
        imp = sorted(((sp[q] - aq[q]) / sp[q], q) for q in sp)[::-1][:10]
        tops = ", ".join(f"{q.split('/')[-1]}:{d_:.0%}" for d_, q in imp)
        log.info(f"{bench:8s} {tops}")
        csv_line(f"fig10_{bench}_best_improvement", 0, f"{imp[0][0]:.3f}")


def bushy_proportion():
    log.info("\n== §VII-C3: proportion of test queries executed as bushy plans ==")
    for bench in ("job", "extjob", "stack"):
        d = load(bench)
        if d is None:
            continue
        n = len(d["aqora"])
        b = sum(r.get("bushy", False) for r in d["aqora"])
        log.info(f"{bench:8s} {b}/{n} ({b / n:.1%}) bushy under AQORA "
              f"(spark default: {sum(r.get('bushy', 0) for r in d['spark'])})")
        csv_line(f"bushy_{bench}", 0, f"{b / n:.3f}")


def bench_rollout(episodes: int = 48, batch: int = 8):
    """Lockstep rollout engine vs the serial path, same episode stream.

    Two readings: the rollout engine alone (encode -> ONE act_batch ->
    scatter/resume, vs per-state policy_probs + per-act sampling), and
    end-to-end training (rollouts + PPO replay). The PPO update's FLOPs
    scale with episodes regardless of batching, so the training ratio is
    compute-bound below the pure engine ratio on CPU."""
    import numpy as np
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    from repro.core.rollout import rollout
    from repro.core.train_loop import train_agent
    from repro.core.vec_rollout import rollout_batch
    from repro.sql import datagen, workloads
    from repro.sql.cbo import Estimator

    log.info(f"\n== batched rollout engine: serial vs lockstep batch={batch} ==")
    db = datagen.make_job_like(scale=0.04, seed=0)
    wl = workloads.make_workload("job", n_train=8, n_test_per_template=1,
                                 seed=7)
    est = Estimator(db, db.stats)
    meta = WorkloadMeta.from_workload(wl)
    agent = AqoraAgent(meta, AgentConfig(), seed=0)
    rng = np.random.default_rng(0)
    qs = [wl.train[int(rng.integers(len(wl.train)))] for _ in range(episodes)]

    # ---- rollout-engine throughput (no learning)
    for q in qs[:4]:                                  # warm jits + caches
        rollout(db, q, est, agent)
    rollout_batch(db, qs[:batch], est, agent, seeds=list(range(batch)))
    t0 = time.perf_counter()
    for q in qs:
        rollout(db, q, est, agent)
    ser_eps = episodes / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    for i in range(0, episodes, batch):
        rollout_batch(db, qs[i:i + batch], est, agent,
                      seeds=list(range(batch)))
    bat_eps = episodes / (time.perf_counter() - t0)
    log.info(f"rollout  serial: {ser_eps:7.1f} eps/s   batched: {bat_eps:7.1f} "
          f"eps/s   ({bat_eps / ser_eps:.2f}x)")

    # ---- end-to-end training throughput (rollout + PPO replay)
    def timed_train(bsz):
        a = AqoraAgent(meta, AgentConfig(), seed=0)
        # warm pass compiles every shape the timed pass will hit
        train_agent(db, wl, episodes=episodes, seed=2, est=est, agent=a,
                    batch_size=bsz, use_curriculum=False)
        t0 = time.perf_counter()
        train_agent(db, wl, episodes=episodes, seed=2, est=est, agent=a,
                    batch_size=bsz, use_curriculum=False)
        return episodes / (time.perf_counter() - t0)

    ser_train = timed_train(1)
    bat_train = timed_train(batch)
    log.info(f"train    serial: {ser_train:7.1f} eps/s   batched: {bat_train:7.1f} "
          f"eps/s   ({bat_train / ser_train:.2f}x)")
    csv_line("rollout_serial_eps_per_s", 0, f"{ser_eps:.1f}")
    csv_line("rollout_batched_eps_per_s", 0, f"{bat_eps:.1f}")
    csv_line("train_batched_speedup", 0, f"{bat_train / ser_train:.2f}")
    p = update_bench_json({
        "batch_size": batch,
        "rollout_serial_eps_per_s": round(ser_eps, 1),
        "rollout_batched_eps_per_s": round(bat_eps, 1),
        "rollout_speedup": round(bat_eps / ser_eps, 2),
        "train_serial_eps_per_s": round(ser_train, 1),
        "train_batched_eps_per_s": round(bat_train, 1),
        "train_speedup": round(bat_train / ser_train, 2),
    })
    log.info(f"wrote {p}")
    return True


def main():
    bench_rollout()
    ok = fig7()
    if ok:
        fig10_top10()
        bushy_proportion()
    return ok


if __name__ == "__main__":
    main()
