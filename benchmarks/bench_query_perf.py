"""Paper Fig. 7 (end-to-end / optimization / raw execution time per method
per benchmark), Fig. 10 (top-10 improved queries), §VII-C3 (bushy-plan
proportion)."""
from benchmarks.common import METHODS, csv_line, load, totals


def fig7():
    print("\n== Fig. 7: query performance on three benchmarks (seconds) ==")
    print(f"{'bench':8s} {'method':10s} {'C (e2e)':>10s} {'C_exec':>10s} "
          f"{'C_plan':>9s} {'fails':>5s}")
    ok = False
    for bench in ("job", "extjob", "stack"):
        d = load(bench)
        if d is None:
            print(f"{bench:8s} -- missing (run repro.experiments.main_experiment)")
            continue
        ok = True
        base = totals(d["spark"])["total"]
        for m in METHODS:
            t = totals(d[m])
            print(f"{bench:8s} {m:10s} {t['total']:10.1f} {t['exec']:10.1f} "
                  f"{t['plan']:9.1f} {t['fails']:5d}"
                  + (f"   ({(base - t['total']) / base:+.1%} vs spark)"
                     if m != "spark" else ""))
        aq = totals(d["aqora"])["total"]
        csv_line(f"fig7_{bench}_aqora_vs_spark", 0, f"{(base - aq) / base:.3f}")
    return ok


def fig10_top10():
    print("\n== Fig. 10: top-10 queries improved by AQORA vs Spark default ==")
    for bench in ("job", "extjob", "stack"):
        d = load(bench)
        if d is None:
            continue
        sp = {r["query"]: r["total"] for r in d["spark"]}
        aq = {r["query"]: r["total"] for r in d["aqora"]}
        imp = sorted(((sp[q] - aq[q]) / sp[q], q) for q in sp)[::-1][:10]
        tops = ", ".join(f"{q.split('/')[-1]}:{d_:.0%}" for d_, q in imp)
        print(f"{bench:8s} {tops}")
        csv_line(f"fig10_{bench}_best_improvement", 0, f"{imp[0][0]:.3f}")


def bushy_proportion():
    print("\n== §VII-C3: proportion of test queries executed as bushy plans ==")
    for bench in ("job", "extjob", "stack"):
        d = load(bench)
        if d is None:
            continue
        n = len(d["aqora"])
        b = sum(r.get("bushy", False) for r in d["aqora"])
        print(f"{bench:8s} {b}/{n} ({b / n:.1%}) bushy under AQORA "
              f"(spark default: {sum(r.get('bushy', 0) for r in d['spark'])})")
        csv_line(f"bushy_{bench}", 0, f"{b / n:.3f}")


def main():
    ok = fig7()
    if ok:
        fig10_top10()
        bushy_proportion()
    return ok


if __name__ == "__main__":
    main()
