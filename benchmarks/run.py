"""Benchmark harness: one module per paper table/figure + the assignment's
roofline table. Each logs a readable table plus CSV lines
``CSV,name,us_per_call,derived``. Missing result files are reported with
the command that produces them (experiments run separately because they
train RL agents for minutes).

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys

from benchmarks.common import bench_logger

log = bench_logger("run")


def main() -> None:
    from benchmarks import (bench_ablation_actions, bench_ablation_net,
                            bench_ablation_rl, bench_ablation_strategy,
                            bench_cbo_cost, bench_delta_table, bench_drift,
                            bench_dynamic, bench_faults, bench_generalize,
                            bench_kernels, bench_monitor, bench_obs,
                            bench_online, bench_planmem, bench_qos,
                            bench_query_perf, bench_roofline, bench_serve,
                            bench_tails)
    ran, missing = [], []
    for mod in (bench_query_perf, bench_serve, bench_online, bench_planmem,
                bench_qos,
                bench_drift, bench_faults, bench_delta_table, bench_tails,
                bench_dynamic, bench_generalize, bench_ablation_rl,
                bench_ablation_net, bench_ablation_strategy,
                bench_ablation_actions, bench_cbo_cost, bench_roofline,
                bench_kernels):
        name = mod.__name__.split(".")[-1]
        try:
            ok = mod.main()
        except Exception as e:                       # pragma: no cover
            log.info(f"[{name}] ERROR: {type(e).__name__}: {e}")
            ok = False
        (ran if ok else missing).append(name)
    # the observability plane rides along non-gating: pricing overhead
    # (bench_obs) and watchdog attribution (bench_monitor) are
    # informative, not a pass/fail surface for the suite
    for mod in (bench_obs, bench_monitor):
        name = mod.__name__.split(".")[-1]
        try:
            obs_ok = mod.main(["--smoke"])
        except Exception as e:                       # pragma: no cover
            log.info(f"[{name}] ERROR: {type(e).__name__}: {e}")
            obs_ok = False
        log.info(f"[{name}] non-gating smoke: "
                 f"{'ok' if obs_ok else 'FAILED'}")
    log.info(f"\nbenchmarks complete: {len(ran)} ran, "
             f"{len(missing)} missing/failed"
             + (f" ({', '.join(missing)})" if missing else ""))
    sys.exit(0 if not missing else 1)


if __name__ == "__main__":
    main()
