"""Paper Fig. 9: dynamic evaluation — (row 1) train on IMDb-1950/-1980
snapshots, test on the full database with STALE statistics; (row 2)
cross-workload transfer JOB<->ExtJOB."""
import json

from benchmarks.common import AQORA, bench_logger, csv_line

log = bench_logger("dynamic")


def main():
    p = AQORA / "ablations.json"
    if not p.exists():
        log.info("bench_dynamic: missing results (run repro.experiments.ablations)")
        return False
    d = json.loads(p.read_text())
    log.info("\n== Fig. 9 row 1: data evolution (train old snapshot -> test full) ==")
    for year in (1950, 1980):
        k = f"dyn_imdb{year}"
        if k not in d:
            continue
        r = d[k]
        log.info(f"IMDb-{year}: spark C={r['spark']['total']:8.1f}s "
              f"(fails {r['spark']['fails']}) | lero C={r['lero']['total']:8.1f}s "
              f"(fails {r['lero']['fails']}) | aqora C={r['aqora']['total']:8.1f}s "
              f"(fails {r['aqora']['fails']})")
        csv_line(f"fig9_imdb{year}_aqora_over_spark", 0,
                 f"{(r['spark']['total'] - r['aqora']['total']) / r['spark']['total']:.3f}")
    log.info("\n== Fig. 9 row 2: cross-workload transfer ==")
    for k, label in (("dyn_job_to_extjob", "train JOB -> test ExtJOB"),
                     ("dyn_extjob_to_job", "train ExtJOB -> test JOB")):
        if k in d:
            r = d[k]
            log.info(f"{label}: C={r['total']:8.1f}s exec={r['exec']:8.1f}s "
                  f"fails={r['fails']}")
            csv_line(f"fig9_{k}", 0, f"{r['total']:.1f}")
    return True


if __name__ == "__main__":
    main()
