"""Failure-recovery benchmark: one seeded chaos storm replayed through
four recovery variants — feeds results/BENCH_faults.json.

The world is bench_drift's stale-stats serving setup (JOB-like db with
a young movie_info, catalog ANALYZEd at build; one growth delta
multiplies movie_info x25 mid-stream) plus a seeded `FaultInjector`
storm: per-stage transient errors and lane crashes, per-attempt
straggler slowdowns (x8-48), and stats-corruption events at admission.
Every "stats-trap" query admitted after the growth delta
deterministically OOMs under the stale catalog (the stale-CBO re-plan
puts the fact-fact join first and it blows the materialize cap), while
the title-filtered order completes — the failure the retry ladder's
fallback replan is built to exploit. OOMs are priced at DETECTION time
plus a spill penalty (`ClusterModel(oom_charge="detect")`) so the
comparison measures recovery, not timeout bookkeeping.

The SAME stream and the SAME chaos schedule (the injector is a pure
function of its seed — decisions are keyed by (seq, attempt, stage),
never by arrival order or lane count) run through four arms:

  none     faults fire, nothing recovers: any injected stage fault or
           trap OOM is a failed query (the PR-5 stack under chaos);
  blind    restart-only retries (resume and fallback disabled): what a
           bare retry loop buys — transients are re-rolled, but the
           deterministic trap OOM restarts into the SAME OOM;
  resume   the full retry ladder: stage-resume for transients (pay only
           the failed stage onwards), fallback replan for OOMs
           (broadcast hints stripped, the blown join pair banned,
           leaves re-folded smallest-first by actual bytes);
  full     resume + hedged stragglers: a lane whose elapsed exceeds
           `factor x` the calibrated `LatencyPredictor` estimate gets a
           speculative re-run on an idle lane; first finisher wins, the
           loser is cancelled at the winner's finish.

Per arm: p50/p99 latency, goodput (on-time successes / queries),
failure counts broken down by kind, and the recovery plane's own
counters (retries by mode, hedges, backoff seconds). Gates: the full
stack strictly beats `none` AND `blind` on both goodput and p99.

A separate scripted scenario exercises the post-swap circuit breaker
causally: an incumbent policy whose head is pinned to cbo-replan (traps
re-planned on fresh stats, sub-second) is hot-swapped for a candidate
pinned to noop (traps OOM); the breaker detects the post-swap failure
spike from live completions, rolls the store back to the incumbent's
exact params, and the traps recover. The same scripted outage runs with
and without the breaker: without, every post-swap trap fails to stream
end; with, the outage is bounded at the trip and the trailing stream is
clean.

  PYTHONPATH=src python -m benchmarks.bench_faults [--smoke]
"""
import tempfile
import time

import numpy as np

from benchmarks.common import (bench_args, bench_logger, csv_line,
                               emit_bench_json)

log = bench_logger("faults")

SLO = 30.0                     # per-query deadline (virtual seconds)
TIMEOUT = 60.0                 # shortened so failures complete mid-stream
SPILL_S = 10.0                 # OOM detect pricing: spill/cleanup charge
GROWTH_X = 24                  # movie_info appends 24x its rows at drift
SHRINK_SEED = 7                # the young-movie_info world build
TRAP_EVERY = 5
CHAOS_SEED = 23
P_CRASH, P_TRANSIENT = 0.01, 0.03     # per stage charge
P_SLOW, P_CORRUPT = 0.06, 0.03        # per attempt / per admission
SLOW_FACTOR = (8.0, 48.0)             # straggler slowdown range
HEDGE_FACTOR = 4.0

# the scripted breaker demo pins ITS world small: at scale 0.06 with
# cast_info grown by 120k rows, (ci x mi) is ~6M rows (blows a 400k cap)
# while the title<=1900-first order's final output is ~31k rows
DEMO_SCALE, DEMO_GROWTH_ROWS, DEMO_CAP = 0.06, 120_000, 400_000


# ------------------------------------------------------------------ world
def _build_world(scale):
    """bench_drift's world: movie_info shrunk young, statistics taken
    THEN — the catalog is in sync at serve start and goes stale the moment
    the mid-stream growth delta lands, arming the trap queries."""
    from repro.serve.deltas import DeltaBatch, apply_delta
    from repro.sql import datagen
    from repro.sql.catalog import analyze
    from repro.sql.cbo import Estimator

    db = datagen.make_job_like(scale=scale, seed=0)
    apply_delta(db, DeltaBatch("movie_info", delete_frac=0.9,
                               seed=SHRINK_SEED))
    db.stats = analyze(db, rng=np.random.default_rng(0))
    return db, Estimator(db, db.stats)


def _trap(i: int, year: int):
    """Fact-fact first syntactically — and by stale-stats CBO choice once
    movie_info has grown (the stale catalog keeps saying it is small).
    The title-filtered order's intermediates stay within the cap."""
    from repro.sql.query import Filter, JoinCond, Query, Relation
    return Query(f"statstrap_{i}",
                 (Relation("ci", "cast_info", ()),
                  Relation("mi", "movie_info", ()),
                  Relation("t", "title",
                           (Filter("production_year", "<=", (year,)),))),
                 (JoinCond("ci", "movie_id", "mi", "movie_id"),
                  JoinCond("t", "id", "ci", "movie_id")))


def _stream(wl, db, *, n_queries, rate, seed, drift_at):
    from repro.serve.deltas import DeltaBatch
    from repro.serve.scheduler import Arrival
    from benchmarks.bench_serve import fast_subset

    rng = np.random.default_rng(seed)
    fast = fast_subset(wl)[:10]
    traps = [_trap(i, 1940 + 5 * i) for i in range(5)]
    mi_rows = db.table("movie_info").nrows      # post-shrink
    t, out = 0.0, []
    for i in range(n_queries):
        t += float(rng.exponential(1.0 / rate))
        q = traps[(i // TRAP_EVERY) % len(traps)] if i % TRAP_EVERY == 0 \
            else fast[i % len(fast)]
        out.append(Arrival(t, query=q, seed=int(rng.integers(2 ** 31)),
                           deadline=t + SLO))
        if i + 1 == drift_at:
            out.append(Arrival(t, delta=DeltaBatch(
                "movie_info", n_append=GROWTH_X * mi_rows, seed=999)))
    return out


def _cluster(cap=None):
    from repro.sql.cluster import ClusterModel
    kw = {"materialize_cap": cap} if cap else {}
    return ClusterModel(timeout=TIMEOUT, oom_charge="detect",
                        oom_spill_penalty=SPILL_S, **kw)


# ------------------------------------------------------------- calibration
def _hedge_predictor(meta, stream, *, scale, cap, n_lanes, smoke):
    """Clean (fault-free) pass over the same stream, harvested into a
    replay buffer and fit one-shot: the `LatencyPredictor` the full arm's
    hedge policy compares elapsed time against."""
    from repro.baselines import CboReplanAgent
    from repro.learn import ReplayBuffer, TrajectoryHarvester
    from repro.serve.qos import LatencyPredictor
    from repro.serve.service import QueryService

    db, est = _build_world(scale)
    rb = ReplayBuffer(capacity=256)
    QueryService(db, CboReplanAgent(meta, max_steps=3), est=est,
                 n_lanes=n_lanes, cluster=_cluster(cap=cap),
                 hooks=[TrajectoryHarvester(rb)]).run(stream)
    pred = LatencyPredictor(meta, seed=5, lr=5e-3)
    rng = np.random.default_rng(7)
    for _ in range(4 if smoke else 10):
        pred.fit_from_replay(rb, rng, n_samples=48, batch_size=16,
                             epochs=3)
    return pred


# ------------------------------------------------------------------- arms
def _recovery(arm, predictor):
    from repro.serve.recover import (FaultInjector, HedgePolicy,
                                     RecoveryManager, RetryPolicy)
    injector = FaultInjector(
        seed=CHAOS_SEED, p_crash=P_CRASH, p_transient=P_TRANSIENT,
        p_slow=P_SLOW, p_corrupt=P_CORRUPT, slow_factor=SLOW_FACTOR)
    if arm == "none":
        return RecoveryManager(injector=injector)
    if arm == "blind":
        retry = RetryPolicy(max_attempts=3, backoff=0.5, resume=False,
                            fallback=False)
        return RecoveryManager(injector=injector, retry=retry)
    retry = RetryPolicy(max_attempts=3, backoff=0.5)
    if arm == "resume":
        return RecoveryManager(injector=injector, retry=retry)
    assert arm == "full", arm
    hedge = HedgePolicy(factor=HEDGE_FACTOR, predictor=predictor)
    return RecoveryManager(injector=injector, retry=retry, hedge=hedge)


def _serve_arm(arm, *, stream, meta, predictor, scale, cap, n_lanes):
    from repro.baselines import CboReplanAgent
    from repro.serve.service import QueryService

    db, est = _build_world(scale)
    mgr = _recovery(arm, predictor)
    svc = QueryService(db, CboReplanAgent(meta, max_steps=3), est=est,
                       n_lanes=n_lanes, cluster=_cluster(cap=cap),
                       recovery=mgr)
    t0 = time.perf_counter()
    comps, stats = svc.run(stream)
    host = time.perf_counter() - t0
    return comps, stats, mgr, host


def _metrics(comps, stats, mgr, host, n_queries):
    lats = [c.latency for c in comps]
    on_time = sum((not c.result.failed) and not c.slo_miss for c in comps)
    rs = mgr.stats.as_dict()
    return {
        "p50": round(float(np.percentile(lats, 50)), 3),
        "p99": round(float(np.percentile(lats, 99)), 3),
        "failed": int(stats.n_failed),
        "failure_kinds": stats.failure_kinds or {},
        "goodput": round(on_time / n_queries, 4),
        "slo_miss_rate": stats.slo_miss_rate,
        "attempts_total": stats.attempts_total,
        "n_retried": stats.n_retried,
        "n_recovered": stats.n_recovered,
        "n_hedged": stats.n_hedged,
        "recovery": {k: rs[k] for k in
                     ("n_failures", "n_retries", "n_resumed", "n_replanned",
                      "n_restarted", "n_given_up", "n_hedges",
                      "n_hedge_wins", "n_hedge_cancelled", "corruptions",
                      "backoff_s", "by_kind")},
        "host_seconds": round(host, 2),
    }


# ---------------------------------------------------------- breaker demo
def _force_head_action(agent, idx: int):
    """Pin the actor head to one action: zero the output weights and put
    a one-hot spike on its bias — argmax (explore=False serving) then
    picks `idx` wherever it is legal."""
    import jax.numpy as jnp
    head = dict(agent.actor["head"])
    head["w2"] = jnp.zeros_like(head["w2"])
    b2 = np.zeros(head["b2"].shape, np.float32)
    b2[idx] = 50.0
    head["b2"] = jnp.asarray(b2)
    agent.actor = {**agent.actor, "head": head}


def _breaker_serve(meta, wl, *, n_lanes, with_breaker):
    """One scripted bad-swap serve: incumbent pinned to cbo-replan (traps
    re-planned on fresh stats, sub-second), hot-swapped mid-stream for a
    candidate pinned to noop (traps run syntactically and OOM)."""
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.learn.policy_store import PolicyStore
    from repro.serve.deltas import DeltaBatch, apply_delta
    from repro.serve.recover import PolicyBreaker, RecoveryManager
    from repro.serve.scheduler import Arrival, LaneScheduler
    from repro.sql import datagen
    from repro.sql.catalog import analyze
    from repro.sql.cbo import Estimator
    from benchmarks.bench_serve import fast_subset

    db = datagen.make_job_like(scale=DEMO_SCALE, seed=0)
    apply_delta(db, DeltaBatch("cast_info", n_append=DEMO_GROWTH_ROWS,
                               seed=999))
    db.stats = analyze(db, rng=np.random.default_rng(0))
    est = Estimator(db, db.stats)

    agent = AqoraAgent(meta, AgentConfig(max_steps=1), seed=0)
    _force_head_action(agent, 0)                 # action 0 == cbo(1)
    store = PolicyStore(tempfile.mkdtemp(prefix="bench_faults_ps_"),
                        probe=[], mode="gate")
    store.commit(agent, 1)

    brk = PolicyBreaker(store, agent, window=12, min_post=5,
                        fail_margin=0.15, cooldown=10) if with_breaker \
        else None
    sched = LaneScheduler(db, est, agent, n_lanes=n_lanes,
                          cluster=_cluster(cap=DEMO_CAP),
                          recovery=RecoveryManager(breaker=brk)
                          if with_breaker else None)

    # host-cheap either way (sub-second queries at the demo scale): a
    # fixed-length stream keeps the trip comfortably clear of the
    # trailing-third healing window in smoke mode too
    n = 48
    swap_at = n // 3
    traps = [_trap(i, 1896 + i) for i in range(5)]
    fast = fast_subset(wl)[:6]
    rng = np.random.default_rng(41)
    t, stream = 0.0, []
    for i in range(n):
        t += float(rng.exponential(0.5))
        q = traps[(i // 2) % 5] if i % 2 == 0 else fast[i % 6]
        stream.append(Arrival(t, query=q, seed=int(rng.integers(2 ** 31))))

    def swapper(comp):
        if comp.seq == swap_at - 1 and store.serving_step == 1:
            _force_head_action(agent, agent.space.noop_idx)
            store.commit(agent, 2)
    sched.on_complete.insert(0, swapper)
    return sched.run(stream), brk, store, n, swap_at


def _breaker_demo(meta, wl, *, n_lanes):
    """Post-swap regression, detected and rolled back from live traffic.

    No injected faults: every post-swap failure is CAUSED by the swapped
    policy, so the breaker's value is the with/without delta on the SAME
    scripted outage — without it every post-swap trap fails to stream
    end; with it the outage is bounded at the trip and the trailing
    stream is clean. (Buckets are by completion seq; a query planned in
    the same scheduler tick the trip lands in may still carry the bad
    plan, which is why the bound is 'trailing third clean', not 'zero
    after the trip instant'.)"""
    comps_nb, _, _, n, swap_at = _breaker_serve(
        meta, wl, n_lanes=n_lanes, with_breaker=False)
    comps, brk, store, n, swap_at = _breaker_serve(
        meta, wl, n_lanes=n_lanes, with_breaker=True)

    fails = lambda cs: sum(c.result.failed for c in cs)
    tail = [c for c in comps if c.seq >= (2 * n) // 3]
    out = {
        "n_queries": n, "swap_at": swap_at,
        "trips": [{"seq": s, "bad_step": b, "restored_step": r,
                   "reason": why} for s, b, r, why in brk.trips],
        "failed_without_breaker": fails(comps_nb),
        "failed_with_breaker": fails(comps),
        "pre_swap_failed": fails([c for c in comps if c.seq < swap_at]),
        "tail_third_failed": fails(tail),
        "serving_step_final": store.serving_step,
        "mode_final": store.mode,
    }
    healed = (len(brk.trips) == 1 and out["pre_swap_failed"] == 0
              and out["failed_with_breaker"] > 0
              and 2 * out["failed_with_breaker"] <=
              out["failed_without_breaker"]
              and out["tail_third_failed"] == 0
              and store.serving_step == 1)
    return out, healed


# ------------------------------------------------------------------- main
def main(argv=None):
    args = bench_args(argv, lanes=6)
    from repro.core.encoding import WorkloadMeta
    from repro.sql import workloads

    scale = 0.06 if args.smoke else 0.2
    n_queries = 40 if args.smoke else 150
    drift_at = 10 if args.smoke else 25
    rate = 1.0
    # full scale: grown (ci x mi) is ~13.9M rows, over the default 10M
    # cap; at smoke scale it is only ~2.2M, so the cap drops to 1.5M to
    # keep the trap armed (the safe order's final output is ~0.6M)
    cap = 1_500_000 if args.smoke else None

    wl = workloads.make_workload("job", n_train=48, n_test_per_template=1,
                                 seed=7)
    meta = WorkloadMeta.from_workload(wl)
    db0, _ = _build_world(scale)
    stream = _stream(wl, db0, n_queries=n_queries, rate=rate, seed=31,
                     drift_at=drift_at)
    n_traps = sum(a.query is not None and
                  a.query.name.startswith("statstrap") for a in stream)
    log.info(f"== failure recovery: {n_queries} queries ({n_traps} stats-trap,"
          f" OOM post-drift), chaos seed {CHAOS_SEED} "
          f"(crash {P_CRASH}/stage, transient {P_TRANSIENT}/stage, "
          f"slow {P_SLOW}/run x{SLOW_FACTOR[0]:.0f}-{SLOW_FACTOR[1]:.0f}, "
          f"corrupt {P_CORRUPT}/query), "
          f"{args.lanes} lanes, SLO {SLO:.0f}s, timeout {TIMEOUT:.0f}s, "
          f"OOM priced at detect+{SPILL_S:.0f}s ==")

    predictor = _hedge_predictor(meta, stream, scale=scale, cap=cap,
                                 n_lanes=args.lanes, smoke=args.smoke)

    arms = {}
    for arm in ("none", "blind", "resume", "full"):
        comps, stats, mgr, host = _serve_arm(
            arm, stream=stream, meta=meta, predictor=predictor,
            scale=scale, cap=cap, n_lanes=args.lanes)
        arms[arm] = _metrics(comps, stats, mgr, host, n_queries)
        m = arms[arm]
        kinds = ",".join(f"{k}:{v}" for k, v in
                         sorted(m["failure_kinds"].items())) or "-"
        log.info(f"{arm:7s} p50={m['p50']:6.2f}s p99={m['p99']:6.2f}s "
              f"goodput={m['goodput']:.2f} failed={m['failed']:3d} "
              f"[{kinds}] retried={m['n_retried']:3d} "
              f"recovered={m['n_recovered']:3d} hedged={m['n_hedged']:2d}")

    breaker, breaker_heals = _breaker_demo(meta, wl,
                                           n_lanes=args.lanes)
    log.info(f"breaker: trips={len(breaker['trips'])} "
          f"bad-swap failures without={breaker['failed_without_breaker']} "
          f"with={breaker['failed_with_breaker']} "
          f"(pre-swap={breaker['pre_swap_failed']}, "
          f"tail third={breaker['tail_third_failed']}) "
          f"serving_step={breaker['serving_step_final']} -> "
          f"healed={breaker_heals}")

    # ------------------------------------------------------------- gates
    nn, bl, fl = arms["none"], arms["blind"], arms["full"]
    rs = arms["resume"]
    full_beats_none = (fl["goodput"] > nn["goodput"]
                       and fl["p99"] < nn["p99"])
    full_beats_blind = (fl["goodput"] > bl["goodput"]
                        and fl["p99"] < bl["p99"])
    fallback_rescues = (rs["recovery"]["n_replanned"] > 0
                        and rs["failed"] < bl["failed"])
    # smoke gates on the mechanics only (a 40-query stream is too short
    # for stable p99 ordering); the full run must clear everything
    ok = bool(fallback_rescues and breaker_heals) if args.smoke else bool(
        full_beats_none and full_beats_blind and fallback_rescues
        and breaker_heals)
    log.info(f"gates: full_beats_none={full_beats_none} "
          f"full_beats_blind={full_beats_blind} "
          f"fallback_rescues={fallback_rescues} "
          f"breaker_heals={breaker_heals} -> ok={ok}")

    csv_line("faults_none_goodput", 0, nn["goodput"])
    csv_line("faults_full_goodput", 0, fl["goodput"])
    csv_line("faults_none_p99_s", 0, nn["p99"])
    csv_line("faults_full_p99_s", 0, fl["p99"])
    emit_bench_json({
        "smoke": args.smoke, "scale": scale, "n_queries": n_queries,
        "n_lanes": args.lanes, "rate_qps": rate, "drift_at": drift_at,
        "growth_x": GROWTH_X, "slo_s": SLO, "timeout_s": TIMEOUT,
        "oom_spill_s": SPILL_S, "chaos": {
            "seed": CHAOS_SEED, "p_crash": P_CRASH,
            "p_transient": P_TRANSIENT, "p_slow": P_SLOW,
            "p_corrupt": P_CORRUPT, "slow_factor": list(SLOW_FACTOR)},
        "hedge_factor": HEDGE_FACTOR,
        "arms": arms, "breaker": breaker,
        "gates": {"full_beats_none": full_beats_none,
                  "full_beats_blind": full_beats_blind,
                  "fallback_rescues": fallback_rescues,
                  "breaker_heals": breaker_heals, "ok": ok},
    }, name="BENCH_faults.json")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
