"""Assignment §Roofline: the 40-cell baseline table (single-pod 16x16) with
three terms per cell, bottleneck, useful-FLOPs ratio, MFU bound; plus the
multi-pod (2x16x16) pass/fail summary and the §Perf hillclimb deltas."""
import json

from benchmarks.common import DRYRUN, PERF, bench_logger, csv_line

log = bench_logger("roofline")


def _fmt_row(r):
    ro = r["roofline"]
    return (f"{r['arch']:24s} {r['shape']:12s} {ro['t_compute_s']:8.3f} "
            f"{ro['t_memory_s']:8.3f} {ro['t_collective_s']:8.3f} "
            f"{ro['bottleneck']:10s} {ro['useful_flops_ratio']:7.3f} "
            f"{ro['mfu_bound']:7.4f}")


def main():
    single = DRYRUN / "single"
    if not single.exists():
        log.info("bench_roofline: run repro.launch.dryrun --all --mesh both first")
        return False
    log.info("\n== Roofline baseline: 16x16 pod (256 chips), all 40 cells ==")
    log.info(f"{'arch':24s} {'shape':12s} {'comp(s)':>8s} {'mem(s)':>8s} "
          f"{'coll(s)':>8s} {'bound':10s} {'useful':>7s} {'mfu':>7s}")
    recs = []
    for f in sorted(single.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("skipped"):
            log.info(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{'— skipped: sub-quadratic-only cell (DESIGN.md)':>40s}")
            continue
        if not r.get("ok"):
            log.info(f"{r['arch']:24s} {r['shape']:12s} FAILED: {r.get('error')}")
            continue
        recs.append(r)
        log.info(_fmt_row(r))
    n_mem_ok = sum(1 for r in recs
                   if r["memory"]["argument_size_in_bytes"]
                   + r["memory"]["temp_size_in_bytes"] < 16e9)
    log.info(f"\ncells compiled: {len(recs)}; within 16 GB HBM "
          f"(args+temps): {n_mem_ok}/{len(recs)}")
    csv_line("roofline_cells_compiled", 0, len(recs))

    multi = DRYRUN / "multi"
    if multi.exists():
        ms = [json.loads(f.read_text()) for f in sorted(multi.glob("*.json"))]
        ok = sum(1 for r in ms if r.get("ok"))
        log.info(f"multi-pod 2x16x16 (512 chips): {ok}/{len(ms)} cells pass "
              f"(incl. sanctioned skips)")
        csv_line("multipod_cells_ok", 0, ok)

    if PERF.exists():
        logs = sorted(PERF.glob("*__log.json"))
        if logs:
            log.info("\n== §Perf hillclimbs (full logs in EXPERIMENTS.md) ==")
            for lf in logs:
                entries = json.loads(lf.read_text())
                cell = lf.stem.replace("__log", "")
                confirmed = sum(1 for e in entries
                                if e["verdict"].startswith("confirmed"))
                log.info(f"{cell}: {len(entries)} iterations, {confirmed} confirmed")
        opt = sorted(set(PERF.glob("*__moesm.json")) | set(PERF.glob("*__kvseq.json"))
                     | set(PERF.glob("*__iter*.json")))
        if opt:
            log.info("\n== §Perf optimized records (baseline vs beyond-paper) ==")
            for f in opt:
                r = json.loads(f.read_text())
                base = PERF / f"{r['arch']}__{r['shape']}__baseline.json"
                if not base.exists():
                    base = DRYRUN / "single" / f"{r['arch']}__{r['shape']}.json"
                b = json.loads(base.read_text()) if base.exists() else None
                b_bound = b["roofline"]["t_bound_s"] if b else float("nan")
                o = r["roofline"]
                d = (b_bound - o["t_bound_s"]) / b_bound if b else 0.0
                log.info(f"{r['arch']:24s} {r['shape']:12s} "
                      f"{b_bound:8.3f}s -> {o['t_bound_s']:8.3f}s ({d:+.1%}) "
                      f"[{r.get('layout','')}]")
                csv_line(f"perf_{r['arch']}_{r['shape']}", 0, f"{d:.3f}")
    return True


if __name__ == "__main__":
    main()
