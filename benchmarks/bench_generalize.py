"""Cross-schema generalization benchmark: train on one sampled schema
family, serve a DISJOINT family — feeds results/BENCH_generalize.json.

The world generator (`repro.gen`) samples two worlds from different
schema families (star -> person by default): different table names,
arities, skews and FK shapes, so the serving policy meets queries whose
table-identity bits are all zero in its encoding (the paper's unseen-
table story, §V-B2) and whose join structures it never trained on. The
agent first ADAPTS online over world A's delta/tenant stream; the
post-adaptation parameters are snapshotted and then serve world B's
stream three ways on identical fresh databases:

  cbo     CboReplanAgent — scripted re-plan-at-admission baseline; its
          plans are a pure function of B's catalog, so it prices world
          B's intrinsic hardness and NORMALIZES the learned arms
          (cross-family latency scales differ by construction);
  frozen  the world-A parameters, learning off: what pure policy
          transfer is worth on a schema the agent has never seen;
  online  the same parameters plus the full PR-3 loop (harvest,
          prioritized replay, background PPO, gated hot-swap, adaptive
          curriculum): re-adaptation closing the gap live.

Reported gap metrics (all from virtual-clock latencies):

  frozen_gap_p99 = frozen_p99 / cbo_p99 - 1   on world B
  online_gap_p99 = online_p99 / cbo_p99 - 1   on world B
  gap_closed     = frozen_gap - online_gap (positive: adaptation helped)

Gates (full run): the frozen pass is bit-deterministic across two runs
(the generator's worlds are a pure function of the seed, so the whole
serve is), and online p99 is no worse than 5% over frozen p99 — online
re-adaptation must never make cross-schema serving materially worse.
Smoke gates only determinism.

  PYTHONPATH=src python -m benchmarks.bench_generalize [--smoke]
"""
import tempfile
import time

import numpy as np

from benchmarks.common import (bench_args, bench_logger, csv_line,
                               emit_bench_json)

log = bench_logger("generalize")

FAMILY_A, FAMILY_B = "star", "person"
SEED_A, SEED_B = 101, 202


def _world(seed, family, args, *, with_stream=True):
    """Re-materializing a world == a fresh identical database (deltas in
    a serving pass mutate it, so every pass re-samples)."""
    from repro.gen.world import sample_world
    return sample_world(
        seed, family=family,
        scale=0.04 if args.smoke else 0.07,
        n_templates=4 if args.smoke else 8,
        n_train=8 if args.smoke else 24,
        n_test_per_template=1,
        t_min=3, t_max=4 if args.smoke else 5,
        n_queries=12 if args.smoke else 72,
        with_stream=with_stream)


def _serve(world, agent, stream, *, lanes, explore=False, hooks=()):
    from repro.serve.service import QueryService
    from repro.sql.cbo import Estimator
    svc = QueryService(world.db, agent, est=Estimator(world.db,
                                                      world.db.stats),
                       n_lanes=lanes, policy="async", explore=explore,
                       hooks=list(hooks))
    t0 = time.perf_counter()
    comps, stats = svc.run(stream)
    return comps, stats, time.perf_counter() - t0


def _pcts(comps):
    lat = np.asarray([c.latency for c in comps])
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def _sig(comps):
    """Completion signature for the determinism gate."""
    return [(c.seq, c.admit_t, c.finish_t, tuple(c.traj.actions))
            for c in comps]


def main(argv=None):
    args = bench_args(argv, lanes=4)

    from repro.baselines import CboReplanAgent
    from repro.checkpoint import agent_state, copy_tree, install_agent_state
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    from repro.learn import (AdaptiveCurriculum, PolicyStore, ReplayBuffer,
                             make_online_loop)

    wa = _world(SEED_A, FAMILY_A, args)
    wb = _world(SEED_B, FAMILY_B, args)
    assert wa.spec.family != wb.spec.family
    assert not ({t.name for t in wa.spec.tables} &
                {t.name for t in wb.spec.tables} - {"hub"})
    # cross-schema encoding context: world A's table identities (world
    # B's tables all encode as zero bits), action space sized for both
    meta = WorkloadMeta(wa.meta.table_index,
                        max(wa.meta.n_tables_max, wb.meta.n_tables_max))
    log.info(f"== cross-schema generalization: adapt on "
             f"{wa.spec.name} ({len(wa.spec.tables)} tables), serve "
             f"{wb.spec.name} ({len(wb.spec.tables)} tables), "
             f"{sum(a.delta is None for a in wb.stream)} queries / "
             f"{sum(a.delta is not None for a in wb.stream)} deltas, "
             f"{args.lanes} lanes ==")

    serving_agent = AqoraAgent(meta, AgentConfig(), seed=0)
    learner_agent = AqoraAgent(meta, AgentConfig(), seed=1)
    tmp_root = tempfile.TemporaryDirectory(prefix="bench_generalize_ps_")
    n_stores = [0]

    def loop_hooks(probe):
        n_stores[0] += 1
        store = PolicyStore(f"{tmp_root.name}/store{n_stores[0]}", probe,
                            mode="gate")
        return make_online_loop(
            serving_agent, store=store,
            curriculum=AdaptiveCurriculum(window=8, min_dwell=8),
            replay=ReplayBuffer(capacity=256, regret_scale=2.0),
            update_every=3, sample_size=8, gate_every=2, seed=3,
            learner_agent=learner_agent)

    # -- adaptation pass: the agent lives on world A's stream
    comps_a, _, host_a = _serve(wa, serving_agent, wa.stream,
                                lanes=args.lanes, explore=True,
                                hooks=loop_hooks(wa.workload.test[:4]))
    trained = copy_tree(agent_state(serving_agent))
    p50_a, p99_a = _pcts(comps_a)
    log.info(f"adapted on {wa.spec.name}: p50={p50_a:.2f}s "
             f"p99={p99_a:.2f}s host={host_a:.1f}s")

    # -- world B arms (fresh identical db per pass; same stream object
    #    is safe — the scheduler copies arrivals per run)
    stream_b = wb.stream
    rows = {}

    cbo_comps, _, host = _serve(_world(SEED_B, FAMILY_B, args),
                                CboReplanAgent(meta), stream_b,
                                lanes=args.lanes)

    def frozen_pass():
        install_agent_state(serving_agent, trained, copy=True)
        return _serve(_world(SEED_B, FAMILY_B, args), serving_agent,
                      stream_b, lanes=args.lanes, explore=False)

    fr_comps, _, fr_host = frozen_pass()
    fr2_comps, _, _ = frozen_pass()
    deterministic = _sig(fr_comps) == _sig(fr2_comps)

    install_agent_state(serving_agent, trained, copy=True)
    install_agent_state(learner_agent, trained, copy=True)
    on_comps, _, on_host = _serve(_world(SEED_B, FAMILY_B, args),
                                  serving_agent, stream_b,
                                  lanes=args.lanes, explore=True,
                                  hooks=loop_hooks(wb.workload.test[:4]))

    for name, comps, host in (("cbo", cbo_comps, host),
                              ("frozen", fr_comps, fr_host),
                              ("online", on_comps, on_host)):
        p50, p99 = _pcts(comps)
        rows[name] = {"p50": round(p50, 3), "p99": round(p99, 3),
                      "failed": int(sum(c.result.failed for c in comps)),
                      "host_seconds": round(host, 2)}
        log.info(f"{name:7s} on {wb.spec.name}: p50={p50:7.2f}s "
                 f"p99={p99:7.2f}s fails={rows[name]['failed']:3d} "
                 f"host={host:5.1f}s")

    cbo99 = max(rows["cbo"]["p99"], 1e-9)
    frozen_gap = rows["frozen"]["p99"] / cbo99 - 1.0
    online_gap = rows["online"]["p99"] / cbo99 - 1.0
    gap_closed = frozen_gap - online_gap
    log.info(f"frozen deterministic: {deterministic};  frozen gap "
             f"{frozen_gap:+.3f};  online gap {online_gap:+.3f};  "
             f"gap closed {gap_closed:+.3f}")

    ok_online = rows["online"]["p99"] <= 1.05 * rows["frozen"]["p99"]
    ok = bool(deterministic and (args.smoke or ok_online))

    csv_line("generalize_frozen_gap_p99", 0, f"{frozen_gap:+.3f}")
    csv_line("generalize_online_gap_p99", 0, f"{online_gap:+.3f}")
    emit_bench_json({
        "smoke": args.smoke,
        "train_world": {"name": wa.spec.name, "family": wa.spec.family,
                        "n_tables": len(wa.spec.tables),
                        "adapt_p50": round(p50_a, 3),
                        "adapt_p99": round(p99_a, 3)},
        "serve_world": {"name": wb.spec.name, "family": wb.spec.family,
                        "n_tables": len(wb.spec.tables),
                        "n_queries": sum(a.delta is None
                                         for a in stream_b),
                        "n_deltas": sum(a.delta is not None
                                        for a in stream_b)},
        **rows,
        "frozen_deterministic": deterministic,
        "frozen_gap_p99": round(frozen_gap, 3),
        "online_gap_p99": round(online_gap, 3),
        "gap_closed_p99": round(gap_closed, 3),
        "gates_ok": ok,
    }, name="BENCH_generalize.json")
    tmp_root.cleanup()
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
