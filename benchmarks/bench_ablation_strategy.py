"""Paper Fig. 11(c): learning-strategy ablation — default (curriculum +
3-step limit) vs no-step-limit vs no-curriculum."""
import json

from benchmarks.common import AQORA, bench_logger, csv_line

log = bench_logger("ablation_strategy")


def main():
    p = AQORA / "ablations.json"
    if not p.exists():
        log.info("bench_ablation_strategy: missing results")
        return False
    d = json.loads(p.read_text())
    log.info("\n== Fig. 11(c): learning strategies (ExtJOB) ==")
    for key, label in (("rl_ppo", "default (curriculum + step limit 3)"),
                       ("strat_no_step_limit", "no step limit (8 steps)"),
                       ("strat_no_curriculum", "no curriculum (full space)")):
        if key not in d:
            continue
        r = d[key]
        fails_curve = r.get("train_fail_curve", [])
        log.info(f"{label:38s} test C={r['total']:8.1f}s fails={r['fails']} "
              f"train-failure curve: {fails_curve[:10]}")
        csv_line(f"fig11c_{key}", 0, f"{r['total']:.1f}")
    return True


if __name__ == "__main__":
    main()
