"""QoS control-plane benchmark: SLO-aware admission + EDF + degradation
vs plain async serving on an overloaded multi-tenant mix — feeds
results/BENCH_qos.json.

Segment A (SLO-miss under overload): two tenants share a small lane pool
at an overload factor where plain async FCFS misses a large fraction of
deadlines: "interactive" (tight SLO, high rate, and every STRAG_EVERY-th
query a 300s straggler — a monster query behind an interactive deadline)
and "analytics" (loose SLO, background rate). The SAME trace is replayed
through (1) plain async — the PR-2 path, deadlines observed but ignored;
(2) EDF scheduling alone; (3) EDF + QoS admission: a latency predictor
(warm-started from the serving agent's value head, trained on latencies
harvested from a calibration serving pass via the PR-3 replay buffer)
rejects predicted-hopeless queries at admission and the degradation
ladder shrinks the re-optimization hook budget for predicted SLO
missers. Gates: plain async misses >= 25% of deadlines at this load, QoS
cuts the SLO-miss rate AND raises goodput (on-time completions / all
submitted, rejects counted as lost), and the p50 of NON-degraded
completions stays within noise of plain async per tenant.

Segment B (noisy neighbor): a "victim" tenant with a small repeated
working set shares the cache with a "flood" tenant streaming distinct
queries. With per-tenant partitions the victim's partition records ZERO
evictions and its whole working set stays resident; with one shared
cache of the same total bytes, the flood provably evicts the victim's
entries (residency probed by signature).

Segment C (pay-for-what-you-use): the same stream served with the QoS
machinery constructed but DISABLED (tenant registry + partitioned cache,
no admission policy, policy="async") is bit-identical to the plain
PR-2/PR-3 async path — completions, actions and finish times.

All latencies are virtual-clock, so every comparison is deterministic.

  PYTHONPATH=src python -m benchmarks.bench_qos [--smoke]
"""
import time

import numpy as np

from benchmarks.bench_serve import STRAG_EVERY, _build, _straggler, \
    fast_subset
from benchmarks.common import (bench_args, bench_logger, csv_line,
                               emit_bench_json)

log = bench_logger("qos")

SLO_INT = 40.0                  # interactive deadline (virtual seconds)
SLO_ANL = 400.0                 # analytics deadline
SLO_REP = 200.0                 # reports deadline: between the ladder's
#   rungs for a ~300s straggler-class query (severity ~1.8), so reports
#   are admitted DEGRADED (shrunken hook budget) instead of rejected


# -------------------------------------------------------------- predictor
def _fit_predictor(agent, wl, *, scale, smoke):
    """Calibration pass: serve a mixed trace, harvest trajectories into
    the PR-3 replay buffer, and fit the admission-time latency predictor
    (warm-started from the agent's critic) on the realized latencies."""
    from repro.learn import ReplayBuffer, TrajectoryHarvester
    from repro.serve.driver import open_loop_stream
    from repro.serve.qos import LatencyPredictor
    from repro.serve.service import QueryService
    from repro.sql import datagen
    from repro.sql.cbo import Estimator

    db = datagen.make_job_like(scale=scale, seed=0)
    est = Estimator(db, db.stats)
    fast = fast_subset(wl)
    n_cal = 24 if smoke else 60
    stream = open_loop_stream(fast, rate=4.0, n_queries=n_cal, seed=29)
    strag = _straggler()
    for i, a in enumerate(stream):
        if (i + 1) % 6 == 0:
            a.query = strag
    harv = TrajectoryHarvester(ReplayBuffer(capacity=256))
    QueryService(db, agent, est=est, n_lanes=4, hooks=[harv]).run(stream)

    pred = LatencyPredictor(agent.meta, agent=agent, lr=5e-3)
    rng = np.random.default_rng(7)
    for _ in range(8 if smoke else 12):
        loss = pred.fit_from_replay(harv.replay, rng, n_samples=48,
                                    batch_size=16, epochs=3)
    p_strag = pred.predict_query(strag)
    p_fast = pred.predict_query(fast[0])
    log.info(f"predictor: {harv.n_harvested} harvested trajectories, final "
          f"loss {loss:.3f}; straggler->{p_strag:.0f}s fast->{p_fast:.1f}s")
    return pred, p_strag, p_fast


# ------------------------------------------------------------- segment A
def _slo_stream(wl, *, n_inter, n_anl, n_rep, seed):
    """The three-tenant overload trace (rebuilt per pass for clarity; the
    scheduler copies arrivals per run, so replaying one list is also
    safe)."""
    from repro.serve.driver import TenantTraffic, multi_tenant_stream
    fast = fast_subset(wl)
    traffics = [
        TenantTraffic("interactive", fast[:6], rate=3.0, n_queries=n_inter,
                      slo=SLO_INT, seed=seed),
        TenantTraffic("analytics", fast[6:12] or fast, rate=1.0,
                      n_queries=n_anl, slo=SLO_ANL, seed=seed + 1)]
    if n_rep:
        traffics.append(TenantTraffic("reports", [_straggler()], rate=0.3,
                                      n_queries=n_rep, slo=SLO_REP,
                                      seed=seed + 2))
    stream = multi_tenant_stream(traffics)
    strag, k = _straggler(), 0
    for a in stream:
        if a.tenant == "interactive":
            k += 1
            if k % STRAG_EVERY == 0:
                a.query = strag
    return stream


def _registry():
    from repro.serve.qos import TenantRegistry, TenantSpec
    return TenantRegistry([
        TenantSpec("interactive", weight=2.0, slo=SLO_INT),
        TenantSpec("analytics", weight=1.0, slo=SLO_ANL),
        TenantSpec("reports", weight=1.0, slo=SLO_REP)])


def _outcome(comps, rejects, n_queries):
    on_time = sum(not c.slo_miss for c in comps)
    missed = sum(c.slo_miss for c in comps)
    return {"completed": len(comps), "rejected": len(rejects),
            "slo_missed": missed,
            "slo_miss_rate": round(missed / max(len(comps), 1), 4),
            "goodput": round(on_time / n_queries, 4)}


def bench_slo(wl, agent, pred, *, scale, n_lanes, smoke):
    from repro.serve.qos import DegradationLadder, QoSAdmission
    from repro.serve.service import QueryService
    from repro.sql import datagen
    from repro.sql.cbo import Estimator

    # enough stragglers to block EVERY lane (the overload): one straggler
    # per STRAG_EVERY interactive arrivals, so n_inter/STRAG_EVERY >=
    # n_lanes leaves plain async with no free lane for the tail
    n_inter, n_anl, n_rep = (48, 12, 2) if smoke else (96, 24, 3)
    n_queries = n_inter + n_anl + n_rep
    log.info(f"\n== QoS: SLO misses under overload ({n_inter}+{n_anl}+{n_rep} "
          f"queries, 1 straggler per {STRAG_EVERY} interactive, {n_lanes} "
          f"lanes, SLOs {SLO_INT:.0f}/{SLO_ANL:.0f}/{SLO_REP:.0f}s) ==")
    out, comps_by_mode = {}, {}
    for mode in ("async", "edf", "edf+qos"):
        db = datagen.make_job_like(scale=scale, seed=0)
        est = Estimator(db, db.stats)
        reg = _registry()
        adm = QoSAdmission(reg, predictor=pred,
                           ladder=DegradationLadder()) \
            if mode == "edf+qos" else None
        svc = QueryService(db, agent, est=est, n_lanes=n_lanes,
                           policy="async" if mode == "async" else "edf",
                           tenants=reg, admission=adm)
        t0 = time.perf_counter()
        comps, stats = svc.run(_slo_stream(wl, n_inter=n_inter,
                                           n_anl=n_anl, n_rep=n_rep,
                                           seed=11))
        host = time.perf_counter() - t0
        o = _outcome(comps, svc.scheduler.rejections, n_queries)
        o["degraded"] = stats.n_degraded
        o["queue_wait_mean"] = stats.queue_wait_mean
        o["per_tenant_miss_rate"] = {
            t: ts.slo_miss_rate for t, ts in stats.per_tenant.items()}
        o["hook_seconds"] = stats.hook_seconds
        out[mode] = o
        comps_by_mode[mode] = comps
        log.info(f"{mode:8s} miss_rate={o['slo_miss_rate']:.2f} "
              f"goodput={o['goodput']:.2f} rejected={o['rejected']:3d} "
              f"degraded={o['degraded']:3d} "
              f"queue_wait={o['queue_wait_mean']:7.2f}s host={host:.1f}s")

    # matched-population p50: the queries served at FULL budget under
    # edf+qos, compared against the very same seqs in each other mode —
    # the control plane must not tax the queries it didn't touch
    matched = {c.seq for c in comps_by_mode["edf+qos"] if not c.degraded}
    for mode, comps in comps_by_mode.items():
        sel = [c for c in comps if c.seq in matched]
        out[mode]["p50_non_degraded"] = {
            t: round(float(np.percentile(
                [c.latency for c in sel if c.tenant == t], 50)), 3)
            for t in ("interactive", "analytics")
            if any(c.tenant == t for c in sel)}
    return out


# ------------------------------------------------------------- segment B
def _victim_queries():
    from repro.sql.query import Filter, JoinCond, Query, Relation
    return [Query(f"victim{i}",
                  (Relation("t", "title",
                            (Filter("production_year", "<=", (y,)),)),
                   Relation("kt", "kind_type", ())),
                  (JoinCond("t", "kind_id", "kt", "id"),))
            for i, y in enumerate((1950, 1961, 1972))]


def _flood_queries(n):
    from repro.sql.query import Filter, JoinCond, Query, Relation
    return [Query(f"flood{i}",
                  (Relation("t", "title",
                            (Filter("production_year", "<=", (1900 + i,)),)),
                   Relation("kt", "kind_type", ())),
                  (JoinCond("t", "kind_id", "kt", "id"),))
            for i in range(n)]


def bench_isolation(agent, *, scale, n_lanes, smoke):
    from repro.serve.driver import TenantTraffic, multi_tenant_stream
    from repro.serve.qos import TenantRegistry, TenantSpec
    from repro.serve.service import QueryService
    from repro.sql import datagen
    from repro.sql.cbo import Estimator

    n_vic, n_flood = (12, 40) if smoke else (24, 120)
    victims = _victim_queries()
    floods = _flood_queries(n_flood)

    # solo pass: measure the victim's working set (bytes + signatures)
    db = datagen.make_job_like(scale=scale, seed=0)
    svc = QueryService(db, agent, est=Estimator(db, db.stats), n_lanes=2)
    svc.run_queries(victims * 2, seeds=range(len(victims) * 2))
    sigs = list(svc.cache._entries.keys())
    ws = svc.cache.bytes
    vic_budget = 2 * ws
    flood_budget = max(ws // 2, 64 * 1024)
    log.info(f"\n== QoS: noisy-neighbor cache isolation (victim working set "
          f"{ws / 1e3:.0f} KB / {len(sigs)} entries; budgets "
          f"victim={vic_budget / 1e3:.0f} KB flood={flood_budget / 1e3:.0f} "
          f"KB; {n_flood} distinct flood queries) ==")

    def mixed_stream():
        # the victim's trace ends well before the flood's: the tail is
        # pure neighbor noise, exactly when a shared LRU forgets the
        # victim and a partition doesn't
        return multi_tenant_stream([
            TenantTraffic("victim", victims, rate=4.0, n_queries=n_vic,
                          seed=3),
            TenantTraffic("flood", floods, rate=4.0, n_queries=n_flood,
                          seed=4)])

    def resident(cache):
        return sum(s in cache for s in sigs)

    # partitioned: per-tenant budgets, shared version tags
    db = datagen.make_job_like(scale=scale, seed=0)
    reg = TenantRegistry([TenantSpec("victim", cache_bytes=vic_budget),
                          TenantSpec("flood", cache_bytes=flood_budget)])
    svc_p = QueryService(db, agent, est=Estimator(db, db.stats),
                         n_lanes=n_lanes, tenants=reg)
    _, stats_p = svc_p.run(mixed_stream())
    parts = svc_p.cache.partitions()
    vic_part, flood_part = parts["victim"], parts["flood"]
    res_p = resident(vic_part)

    # shared single cache of the same TOTAL budget
    db = datagen.make_job_like(scale=scale, seed=0)
    svc_s = QueryService(db, agent, est=Estimator(db, db.stats),
                         n_lanes=n_lanes,
                         cache_bytes=vic_budget + flood_budget)
    _, stats_s = svc_s.run(mixed_stream())
    res_s = resident(svc_s.cache)

    out = {
        "victim_ws_bytes": ws, "victim_ws_entries": len(sigs),
        "victim_budget": vic_budget, "flood_budget": flood_budget,
        "partitioned": {
            "victim": vic_part.stats.as_dict(),
            "flood": flood_part.stats.as_dict(),
            "victim_resident": res_p,
            "cross_tenant_evictions": vic_part.stats.evictions},
        "shared": {"cache": stats_s.cache, "victim_resident": res_s},
    }
    log.info(f"partitioned: victim evictions={vic_part.stats.evictions} "
          f"hit_rate={vic_part.stats.hit_rate:.2f} resident="
          f"{res_p}/{len(sigs)}; flood evictions={flood_part.stats.evictions}")
    log.info(f"shared:      victim resident={res_s}/{len(sigs)} "
          f"(flood evicted {len(sigs) - res_s}) "
          f"total evictions={stats_s.cache['evictions']}")
    ok = vic_part.stats.evictions == 0 and res_p == len(sigs) \
        and res_s < len(sigs) and flood_part.stats.evictions > 0
    return out, ok


# ------------------------------------------------------------- segment C
def bench_qos_off_identical(wl, agent, *, scale, n_lanes, smoke):
    from repro.serve.service import QueryService
    from repro.sql import datagen
    from repro.sql.cbo import Estimator

    n_inter, n_anl = (16, 6) if smoke else (32, 12)
    n = n_inter + n_anl
    log.info(f"\n== QoS disabled == plain async: bit-identity ({n} queries) ==")

    def serve(**kw):
        db = datagen.make_job_like(scale=scale, seed=0)
        svc = QueryService(db, agent, est=Estimator(db, db.stats),
                           n_lanes=n_lanes, policy="async", **kw)
        comps, _ = svc.run(_slo_stream(wl, n_inter=n_inter, n_anl=n_anl,
                                       n_rep=0, seed=23))
        return comps

    plain = serve()                          # the PR-2/PR-3 path
    off = serve(tenants=_registry())         # QoS built but disabled
    identical = (
        [c.finish_t for c in plain] == [c.finish_t for c in off] and
        [c.traj.actions for c in plain] == [c.traj.actions for c in off] and
        [c.lane for c in plain] == [c.lane for c in off])
    log.info(f"qos-off completions identical to plain async: {identical}")
    return identical


# ------------------------------------------------------------------ main
def main(argv=None):
    args = bench_args(argv, lanes=4)
    scale = 0.04 if args.smoke else 0.1

    db, wl, est, agent = _build(scale)
    # warm the jit caches (policy batch + predictor shapes)
    from repro.serve.service import QueryService
    QueryService(db, agent, est=est, n_lanes=args.lanes).run_queries(
        wl.train[:args.lanes])

    pred, p_strag, p_fast = _fit_predictor(agent, wl, scale=scale,
                                           smoke=args.smoke)
    slo = bench_slo(wl, agent, pred, scale=scale, n_lanes=args.lanes,
                    smoke=args.smoke)
    iso, iso_ok = bench_isolation(agent, scale=scale, n_lanes=args.lanes,
                                  smoke=args.smoke)
    identical = bench_qos_off_identical(wl, agent, scale=scale,
                                        n_lanes=args.lanes, smoke=args.smoke)

    a, q = slo["async"], slo["edf+qos"]
    overloaded = a["slo_miss_rate"] >= 0.25
    qos_wins = (q["slo_miss_rate"] < a["slo_miss_rate"]
                and q["goodput"] > a["goodput"])
    # non-degraded completions must not pay for the control plane: per
    # tenant, their p50 stays within 5% (or absolutely better) of async
    p50_ok = all(
        q["p50_non_degraded"].get(t, 0.0) <=
        1.05 * a["p50_non_degraded"].get(t, np.inf)
        for t in q["p50_non_degraded"])
    ok = bool(overloaded and qos_wins and p50_ok and iso_ok and identical)

    log.info(f"\nasync miss_rate={a['slo_miss_rate']:.2f} -> edf+qos "
          f"{q['slo_miss_rate']:.2f}; goodput {a['goodput']:.2f} -> "
          f"{q['goodput']:.2f}; overloaded={overloaded} p50_ok={p50_ok} "
          f"isolation_ok={iso_ok} qos_off_identical={identical}")
    csv_line("qos_async_miss_rate", 0, f"{a['slo_miss_rate']:.3f}")
    csv_line("qos_edfqos_miss_rate", 0, f"{q['slo_miss_rate']:.3f}")
    csv_line("qos_goodput_gain", 0,
             f"{q['goodput'] - a['goodput']:.3f}")
    csv_line("qos_victim_cross_evictions", 0,
             iso["partitioned"]["cross_tenant_evictions"])
    emit_bench_json({
        "smoke": args.smoke, "scale": scale, "n_lanes": args.lanes,
        "slo_interactive_s": SLO_INT, "slo_analytics_s": SLO_ANL,
        "straggler_every": STRAG_EVERY,
        "predictor": {"straggler_pred_s": round(p_strag, 1),
                      "fast_pred_s": round(p_fast, 2)},
        "slo": slo, "isolation": iso,
        "qos_off_identical_to_async": identical,
        "gates": {"overloaded": overloaded, "qos_wins": qos_wins,
                  "p50_non_degraded_ok": p50_ok, "isolation_ok": iso_ok,
                  "ok": ok},
    }, name="BENCH_qos.json")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
