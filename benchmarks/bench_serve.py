"""Online serving benchmark: async lanes vs lockstep batching, plus the
delta-table dynamic segment — feeds results/BENCH_serve.json.

Segment A (straggler-heavy mix): one open-loop trace — mostly small
dimension-join queries with a guaranteed heavy straggler (a triple
Zipf-skewed fact join that blows past the materialize cap and eats the
300s timeout) injected every STRAG_EVERY queries — replayed through the
SAME agent under policy="async" and policy="lockstep". Latencies are
virtual-clock (deterministic), so the comparison isolates scheduling:
lockstep barriers every wave behind its slowest member, async refills
each lane the moment it frees.

Segment B (dynamic deltas): the same service with append/delete batches
interleaved into the stream; reports the cache's hit/miss/evict/
invalidate counters and cross-checks one post-delta query bit-for-bit
against a cache-off run.

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke]
"""
import time

from benchmarks.common import (bench_args, bench_logger, csv_line,
                               emit_bench_json)

log = bench_logger("serve")

STRAG_EVERY = 8


def _build(scale: float, seed: int = 0):
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    from repro.sql import datagen, workloads
    from repro.sql.cbo import Estimator

    db = datagen.make_job_like(scale=scale, seed=seed)
    wl = workloads.make_workload("job", n_train=48, n_test_per_template=1,
                                 seed=7)
    est = Estimator(db, db.stats)
    agent = AqoraAgent(WorkloadMeta.from_workload(wl), AgentConfig(),
                       seed=seed)
    return db, wl, est, agent


def fast_subset(wl):
    """Dimension-join-ish templates: the sub-second traffic every serving
    bench mixes around its stragglers."""
    return [q for q in wl.train if q.n_relations <= 6] or wl.train


def _straggler():
    from repro.sql.query import JoinCond, Query, Relation
    return Query("straggler",
                 (Relation("ci", "cast_info", ()),
                  Relation("mi", "movie_info", ()),
                  Relation("mk", "movie_keyword", ())),
                 (JoinCond("ci", "movie_id", "mi", "movie_id"),
                  JoinCond("ci", "movie_id", "mk", "movie_id")))


def _mix_stream(wl, n_queries: int, rate: float, seed: int):
    """Small-template queries with a deterministic straggler every
    STRAG_EVERY arrivals."""
    from repro.serve.driver import open_loop_stream
    stream = open_loop_stream(fast_subset(wl), rate=rate,
                              n_queries=n_queries, seed=seed)
    strag = _straggler()
    for i, a in enumerate(stream):
        if (i + 1) % STRAG_EVERY == 0:
            a.query = strag
    return stream


def bench_straggler_mix(db, wl, est, agent, *, n_queries: int, rate: float,
                        n_lanes: int):
    from repro.serve.service import QueryService

    log.info(f"\n== serving: async lanes vs lockstep batching "
          f"({n_queries} queries, 1 straggler per {STRAG_EVERY}, "
          f"{n_lanes} lanes, open-loop {rate} qps) ==")
    out = {}
    for policy in ("lockstep", "async"):
        stream = _mix_stream(wl, n_queries, rate, seed=11)
        svc = QueryService(db, agent, est=est, n_lanes=n_lanes,
                           policy=policy)
        t0 = time.perf_counter()
        _, stats = svc.run(stream)
        host = time.perf_counter() - t0
        out[policy] = stats
        log.info(f"{policy:9s} qps={stats.qps:7.2f}  p50={stats.latency_p50:8.2f}s "
              f"p99={stats.latency_p99:8.2f}s  makespan={stats.makespan:8.1f}s "
              f"queue_wait={stats.queue_wait_mean:7.2f}s "
              f"in-lane={stats.service_mean:6.2f}s "
              f"hit_rate={stats.cache['hit_rate']:.2f}  "
              f"mean_batch={stats.mean_decide_batch:.1f}  host={host:.1f}s")
    a, l = out["async"], out["lockstep"]
    log.info(f"async/lockstep: qps {a.qps / l.qps:.2f}x, "
          f"p99 {l.latency_p99 / max(a.latency_p99, 1e-9):.2f}x lower")
    csv_line("serve_async_qps", 0, f"{a.qps:.2f}")
    csv_line("serve_async_p99_s", 0, f"{a.latency_p99:.2f}")
    csv_line("serve_qps_speedup_vs_lockstep", 0, f"{a.qps / l.qps:.2f}")
    return out


def bench_dynamic(db, wl, est, agent, *, n_queries: int, rate: float,
                  n_lanes: int, delta_every: int, delta_rows: int):
    from repro.serve.driver import open_loop_stream
    from repro.serve.service import QueryService
    from repro.sql.executor import run_adaptive
    from repro.sql.plans import syntactic_plan

    log.info(f"\n== serving: delta-table dynamic workload "
          f"(delta every {delta_every} queries, +{delta_rows} rows) ==")
    fast = fast_subset(wl)
    stream = open_loop_stream(fast, rate=rate, n_queries=n_queries, seed=13,
                              delta_every=delta_every,
                              delta_tables=("movie_info", "movie_keyword",
                                            "cast_info"),
                              delta_rows=delta_rows, delete_frac=0.02)
    svc = QueryService(db, agent, est=est, n_lanes=n_lanes, policy="async")
    _, stats = svc.run(stream)
    cache = stats.cache
    log.info(f"qps={stats.qps:7.2f}  p99={stats.latency_p99:8.2f}s  "
          f"cache: hits={cache['hits']} misses={cache['misses']} "
          f"evictions={cache['evictions']} "
          f"invalidations={cache['invalidations']} "
          f"hit_rate={cache['hit_rate']:.2f}")
    # correctness sentinel: post-delta execution must equal a cache-off run
    q = fast[0]
    warm = run_adaptive(db, q, syntactic_plan(q), est)
    cold = run_adaptive(db, q, syntactic_plan(q), est, reuse_stages=False)
    ok = ([s.out_rows for s in warm.stages] ==
          [s.out_rows for s in cold.stages]) and warm.latency == cold.latency
    log.info(f"post-delta cache-on == cache-off: {'OK' if ok else 'MISMATCH'}")
    csv_line("serve_dynamic_hit_rate", 0, f"{cache['hit_rate']:.3f}")
    csv_line("serve_dynamic_invalidations", 0, cache["invalidations"])
    return stats, ok


def main(argv=None):
    args = bench_args(argv, lanes=8)
    scale = 0.04 if args.smoke else 0.1
    n_queries = 24 if args.smoke else 96
    rate = 4.0

    db, wl, est, agent = _build(scale)
    # warm the jit caches so host timings reflect steady state
    from repro.serve.service import QueryService
    QueryService(db, agent, est=est, n_lanes=args.lanes).run_queries(
        wl.train[:args.lanes])

    mix = bench_straggler_mix(db, wl, est, agent, n_queries=n_queries,
                              rate=rate, n_lanes=args.lanes)
    dyn, ok = bench_dynamic(db, wl, est, agent,
                            n_queries=max(n_queries // 2, 12), rate=rate,
                            n_lanes=args.lanes,
                            delta_every=6 if args.smoke else 10,
                            delta_rows=2000)
    a, l = mix["async"], mix["lockstep"]
    emit_bench_json({
        "smoke": args.smoke, "n_lanes": args.lanes, "n_queries": n_queries,
        "straggler_every": STRAG_EVERY, "rate_qps": rate,
        "async": a.as_dict(), "lockstep": l.as_dict(),
        "qps_speedup_async_vs_lockstep": round(a.qps / l.qps, 2),
        "p99_ratio_lockstep_over_async":
            round(l.latency_p99 / max(a.latency_p99, 1e-9), 2),
        "dynamic": dyn.as_dict(),
        "dynamic_invalidation_consistent": ok,
    }, name="BENCH_serve.json")
    return a.qps > l.qps and ok


if __name__ == "__main__":
    raise SystemExit(0 if main() else 1)
