"""Paper Fig. 3: CBO optimization time vs execution time as join count
grows (the DP blowup that motivates adaptive re-optimization)."""
import json

from benchmarks.common import AQORA, bench_logger, csv_line

log = bench_logger("cbo_cost")


def main():
    p = AQORA / "ablations.json"
    if not p.exists() or "cbo_cost" not in json.loads(p.read_text()):
        log.info("bench_cbo_cost: missing results")
        return False
    rows = json.loads(p.read_text())["cbo_cost"]
    log.info("\n== Fig. 3: CBO planning vs execution time by join count ==")
    log.info(f"{'relations':>9s} {'C_plan (s)':>11s} {'exec no-CBO':>12s} "
          f"{'exec CBO':>9s}")
    by_n = {}
    for r in rows:
        by_n.setdefault(r["n"], []).append(r)
    for n in sorted(by_n):
        g = by_n[n]
        tp = sum(r["plan_time"] for r in g) / len(g)
        e0 = sum(r["exec_no_cbo"] for r in g) / len(g)
        e1 = sum(r["exec_cbo"] for r in g) / len(g)
        log.info(f"{n:9d} {tp:11.3f} {e0:12.1f} {e1:9.1f}")
    big = max(by_n)
    small = min(by_n)
    ratio = (sum(r['plan_time'] for r in by_n[big]) /
             max(sum(r['plan_time'] for r in by_n[small]), 1e-9))
    csv_line("fig3_plan_time_blowup", 0, f"{ratio:.0f}x")
    return True


if __name__ == "__main__":
    main()
