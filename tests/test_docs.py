"""Documentation integrity: every artifact DESIGN.md's per-experiment index
references must exist; the required deliverable files are present."""
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_deliverable_files_exist():
    for p in ("README.md", "DESIGN.md", "EXPERIMENTS.md", "pyproject.toml",
              "src/repro/launch/mesh.py", "src/repro/launch/dryrun.py",
              "benchmarks/run.py", "examples/quickstart.py"):
        assert (ROOT / p).exists(), p


def test_design_experiment_index_targets_exist():
    text = (ROOT / "DESIGN.md").read_text()
    refs = re.findall(r"`(benchmarks/[\w/.]+?\.py)", text)
    assert refs, "DESIGN.md must index benchmark modules"
    for r in set(refs):
        assert (ROOT / r).exists(), f"DESIGN.md references missing {r}"


def test_arch_configs_cover_assignment():
    from repro.configs import registry
    assert len(registry.ARCHS) == 10
    cells = registry.assigned_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8              # long_500k on quadratic archs
    for arch, shape, ok, why in skipped:
        assert shape == "long_500k" and "quadratic" in why


def test_dryrun_sets_xla_flags_first():
    src = (ROOT / "src/repro/launch/dryrun.py").read_text().splitlines()
    assert src[0].startswith("import os")
    assert "xla_force_host_platform_device_count=512" in src[1]


def test_no_global_device_count_override():
    """Only the dry-run drivers may force 512 devices (tests/benches must
    see 1 device)."""
    allowed = {"dryrun.py", "perf_climb.py", "test_docs.py"}
    for p in ROOT.rglob("*.py"):
        if p.name in allowed:
            continue
        if ".tmp" in str(p):
            continue
        txt = p.read_text()
        assert "xla_force_host_platform_device_count" not in txt, p
