"""Failure-recovery control plane (serve/recover): fault injection,
stage-resume retries, re-planned OOM fallbacks, hedged stragglers, and
the post-swap policy circuit breaker.

Everything runs on the scenario harness (tests/scenarios.py) and the
virtual clock, so each property is pinned exactly:

  * the injector is a pure function of its seed — the same chaos replays
    bit-identically through any scheduler shape;
  * with the injector disabled (and default failure pricing) the whole
    recovery plane is INERT: completions bit-identical to a scheduler
    with no recovery plane at all;
  * a resume retry pays only the failed stage onwards; a crash restarts
    from scratch; an OOM fallback re-plans around the blown join while a
    blind retry deterministically re-OOMs;
  * a hedge's loser is cancelled at the winner's finish and the race is
    priced honestly;
  * a tripped breaker restores the incumbent's exact parameters.
"""
import numpy as np
import pytest

from scenarios import (fast_query, fresh_db, mi_join_query, noop_agent_for,
                       straggler_query, trap_query)

from repro.serve.deltas import DeltaBatch, apply_delta
from repro.serve.recover import (FaultInjector, HedgePolicy, PolicyBreaker,
                                 RecoveryManager, RetryPolicy, ScriptedFaults)
from repro.serve.scheduler import Arrival, LaneScheduler
from repro.sql.cbo import Estimator
from repro.sql.cluster import ClusterModel


def _world(scale=0.06, seed=0):
    db = fresh_db(scale=scale, seed=seed)
    return db, Estimator(db, db.stats)


def _serve(agent, stream, *, recovery=None, n_lanes=1, cluster=None,
           world=None):
    db, est = world if world is not None else _world()
    sched = LaneScheduler(db, est, agent, n_lanes=n_lanes, cluster=cluster,
                          recovery=recovery)
    return sched.run(stream), sched


def _comp_key(c):
    return (c.seq, c.query.name, c.admit_t, c.finish_t, c.lane,
            c.result.latency, c.result.failed, c.result.failure_kind,
            c.attempts, c.recovered, c.hedged, c.failure_kind,
            c.first_admit_t, tuple(c.traj.actions))


# ------------------------------------------------------------- injector
def test_fault_injector_is_a_pure_function_of_its_seed():
    kw = dict(p_crash=0.05, p_transient=0.2, p_slow=0.3, p_corrupt=0.1)
    a, b = FaultInjector(seed=42, **kw), FaultInjector(seed=42, **kw)
    other = FaultInjector(seed=43, **kw)
    keys = [(s, att, k) for s in range(40) for att in (1, 2, 1001)
            for k in range(4)]
    draws_a = [(a.stage_fault(s, att, k), a.run_slowdown(s, att))
               for s, att, k in keys]
    # query b in REVERSE order: decisions are keyed, not sequential
    draws_b = [(b.stage_fault(s, att, k), b.run_slowdown(s, att))
               for s, att, k in reversed(keys)]
    assert draws_a == list(reversed(draws_b))
    assert draws_a != [(other.stage_fault(s, att, k),
                        other.run_slowdown(s, att)) for s, att, k in keys]
    # a retry rolls fresh dice: attempts are independent key dimensions
    fired = [ev for ev, _ in draws_a if ev is not None]
    assert fired, "chaos at these rates must fire somewhere in 480 draws"
    assert any(a.stage_fault(s, 1, k) != a.stage_fault(s, 2, k)
               for s in range(40) for k in range(4))
    # corruption picks are stream-independent too
    tabs = ["title", "cast_info", "movie_info"]
    assert [a.admit_corruption(s, tabs) for s in range(40)] == \
        [b.admit_corruption(s, tabs) for s in range(40)]


def test_chaos_replays_bit_identically_across_runs():
    q = mi_join_query()
    agent = noop_agent_for(q, *[fast_query(i) for i in range(4)],
                           max_steps=2)
    stream = [Arrival(0.2 * i, query=(q if i % 2 else fast_query(i)),
                      seed=i + 1) for i in range(8)]

    def chaos_run():
        inj = FaultInjector(seed=5, p_crash=0.05, p_transient=0.3,
                            p_slow=0.2)
        mgr = RecoveryManager(injector=inj,
                              retry=RetryPolicy(max_attempts=3))
        comps, _ = _serve(agent, stream, recovery=mgr, n_lanes=2)
        return [_comp_key(c) for c in comps], mgr.stats.as_dict()

    (ca, sa), (cb, sb) = chaos_run(), chaos_run()
    assert ca == cb and sa == sb
    assert any(k[8] > 1 for k in ca), "the storm must force retries"


def test_disabled_injector_is_bit_identical_to_no_recovery_plane():
    """ISSUE gate: with the FaultInjector disabled and default pricing the
    serve path is completion-bit-identical to the PR-5 stack — across a
    natural OOM straggler AND a delta write barrier."""
    q = mi_join_query()
    agent = noop_agent_for(q, straggler_query(),
                           *[fast_query(i) for i in range(3)], max_steps=2)
    stream = [Arrival(0.0, query=straggler_query(), seed=9)] + \
        [Arrival(0.05 * (i + 1), query=fast_query(i), seed=i + 1)
         for i in range(3)] + \
        [Arrival(0.3, delta=DeltaBatch("movie_info", n_append=900, seed=3)),
         Arrival(0.35, query=q, seed=8)]

    base, _ = _serve(agent, stream, n_lanes=2, world=_world(seed=1))
    inert = RecoveryManager(injector=FaultInjector(
        seed=7, p_crash=0.5, p_transient=0.4, p_slow=0.9, p_corrupt=0.9,
        enabled=False))
    got, _ = _serve(agent, stream, recovery=inert, n_lanes=2,
                    world=_world(seed=1))
    assert [_comp_key(c) for c in base] == [_comp_key(c) for c in got]
    # the straggler's natural OOM is priced at the full timeout by default
    oom = [c for c in base if c.result.failed]
    assert oom and all(c.result.latency == ClusterModel().timeout
                       for c in oom)
    assert ClusterModel().failure_charge("oom", 3.0) == \
        ClusterModel().timeout


# ------------------------------------------------------- pricing (cluster)
def test_oom_detect_pricing_charges_elapsed_plus_spill():
    cl = ClusterModel(oom_charge="detect", oom_spill_penalty=2.5)
    assert cl.failure_charge("oom", 3.0) == 5.5
    assert cl.failure_charge("transient", 3.0) == 3.0
    assert cl.failure_charge("timeout", 3.0) == cl.timeout
    # capped at the timeout — detection can't cost more than giving up
    assert cl.failure_charge("oom", cl.timeout + 10) == cl.timeout
    # default stays the legacy pricing, bit for bit
    assert ClusterModel().failure_charge("oom", 123.0) == \
        ClusterModel().timeout


# ---------------------------------------------------------------- retries
def test_resume_retry_pays_only_the_failed_stage():
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    stream = [Arrival(0.0, query=q, seed=5)]

    base, _ = _serve(agent, stream)
    stages = base[0].result.stages
    assert len(stages) >= 2 and not base[0].result.failed

    # kill the FINAL join charge (3-table left-deep: scan, scan, join,
    # scan, join -> charge index 4) on attempt 1; resume on attempt 2
    faults = ScriptedFaults(stage={(0, 1, 4): "transient"})
    mgr = RecoveryManager(injector=faults,
                          retry=RetryPolicy(max_attempts=2, backoff=0.25))
    comps, _ = _serve(agent, stream, recovery=mgr)
    c = comps[0]
    assert (c.attempts, c.recovered, c.failure_kind) == (2, True,
                                                         "transient")
    assert mgr.stats.n_resumed == 1 and mgr.stats.n_failures == 1
    # the resumed attempt re-ran ONLY the failed final join
    assert c.finish_t - c.admit_t == pytest.approx(stages[-1].seconds,
                                                   abs=1e-12)
    # and was re-admitted exactly at failure + backoff: the failed attempt
    # burned everything but the final join, plus the injected half-charge
    fail_t = c.first_admit_t + base[0].result.latency \
        - 0.5 * stages[-1].seconds
    assert c.admit_t == pytest.approx(fail_t + 0.25, abs=1e-9)


def test_crash_retry_restarts_from_scratch():
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    stream = [Arrival(0.0, query=q, seed=5)]
    base, _ = _serve(agent, stream)

    faults = ScriptedFaults(stage={(0, 1, 4): "crash"})
    mgr = RecoveryManager(injector=faults,
                          retry=RetryPolicy(max_attempts=2, backoff=0.0))
    comps, _ = _serve(agent, stream, recovery=mgr)
    c = comps[0]
    assert c.attempts == 2 and c.recovered and c.failure_kind == "crash"
    assert mgr.stats.n_restarted == 1 and mgr.stats.n_resumed == 0
    # in-flight state was lost: the retry re-pays the FULL run
    assert c.finish_t - c.admit_t == pytest.approx(
        base[0].result.latency, abs=1e-12)


def test_retry_gives_up_after_max_attempts_and_emits_the_failure():
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    # every attempt dies at its first charge
    faults = ScriptedFaults(stage={(0, att, 0): "transient"
                                   for att in range(1, 10)})
    mgr = RecoveryManager(injector=faults,
                          retry=RetryPolicy(max_attempts=3, backoff=0.5,
                                            backoff_mult=2.0))
    comps, _ = _serve(agent, [Arrival(0.0, query=q, seed=5)], recovery=mgr)
    c = comps[0]
    assert c.result.failed and not c.recovered
    assert c.attempts == 3 and c.failure_kind == "transient"
    assert mgr.stats.n_retries == 2 and mgr.stats.n_given_up == 1
    assert mgr.stats.backoff_s == pytest.approx(0.5 + 1.0)
    assert len(comps) == 1            # ONE completion, even for a give-up


def _oom_trap_world():
    """Stale-stats OOM trap: cast_info grows after ANALYZE, so the
    syntactic (ci x mi) first join blows a small materialize cap while
    the title-filtered order stays tiny (tests/scenarios.trap_query)."""
    db = fresh_db()
    est = Estimator(db, db.stats)          # catalog frozen pre-growth
    apply_delta(db, DeltaBatch("cast_info", n_append=120_000, seed=9))
    return db, est


_TRAP_CLUSTER = ClusterModel(materialize_cap=400_000, timeout=60.0)


def test_oom_fallback_replans_around_the_blown_join():
    q = trap_query(0, 1900)
    agent = noop_agent_for(q)
    stream = [Arrival(0.0, query=q, seed=5)]

    # rung 0 — no recovery: the trap OOMs and eats the full timeout
    comps, _ = _serve(agent, stream, cluster=_TRAP_CLUSTER,
                      world=_oom_trap_world())
    assert comps[0].result.failed and comps[0].failure_kind == "oom"
    assert comps[0].result.latency == _TRAP_CLUSTER.timeout

    # rung 1 — blind retry (fallback off): the OOM is deterministic,
    # restarting the same plan fails identically
    mgr = RecoveryManager(retry=RetryPolicy(max_attempts=2, backoff=0.0,
                                            fallback=False))
    comps, _ = _serve(agent, stream, recovery=mgr, cluster=_TRAP_CLUSTER,
                      world=_oom_trap_world())
    assert comps[0].result.failed and comps[0].attempts == 2
    assert mgr.stats.n_restarted == 1

    # rung 2 — fallback replan: broadcast hints stripped, the blown
    # (ci x mi) pair banned, leaves re-folded smallest-first -> recovered
    mgr = RecoveryManager(retry=RetryPolicy(max_attempts=2, backoff=0.0))
    comps, _ = _serve(agent, stream, recovery=mgr, cluster=_TRAP_CLUSTER,
                      world=_oom_trap_world())
    c = comps[0]
    assert not c.result.failed and c.recovered and c.attempts == 2
    assert mgr.stats.n_replanned == 1
    assert c.finish_t - c.admit_t < 2.0    # vs the 60s timeout
    # the replanned attempt's first join is NOT the banned fact-fact pair
    first = c.result.stages[0].covered
    assert first != frozenset({"ci", "mi"})


# ---------------------------------------------------------------- hedging
class _TinyPredictor:
    def predict_query(self, query):
        return 0.05


def test_hedge_winner_emits_and_loser_is_cancelled_at_winner_finish():
    q = mi_join_query()
    agent = noop_agent_for(q, *[fast_query(i) for i in range(3)],
                           max_steps=3)
    stream = [Arrival(0.0, query=q, seed=5)] + \
        [Arrival(0.01 * (i + 1), query=fast_query(i), seed=i + 1)
         for i in range(3)]

    # attempt 1 of seq 0 is a x40 straggler; the hedge (attempt keyed
    # 1001) rolls clean dice and runs at full speed
    def chaos():
        return ScriptedFaults(slow={(0, 1): 40.0})

    base, _ = _serve(agent, stream, n_lanes=3,
                     recovery=RecoveryManager(injector=chaos()))
    slow_finish = base[0].finish_t

    mgr = RecoveryManager(injector=chaos(),
                          hedge=HedgePolicy(factor=3.0,
                                            predictor=_TinyPredictor()))
    comps, sched = _serve(agent, stream, n_lanes=3, recovery=mgr)
    c = comps[0]
    assert c.hedged and not c.result.failed and c.attempts == 1
    assert mgr.stats.n_hedges == 1 and mgr.stats.n_hedge_wins == 1
    assert mgr.stats.n_hedge_cancelled == 1
    assert c.finish_t < slow_finish        # the race actually helped
    assert c.first_admit_t == 0.0          # latency priced from attempt 1
    # honest pricing: the slow primary's lane was freed AT the winner's
    # finish, not at the primary's own (later) finish
    primary_lane = [l for l in sched.lanes if l.idx != c.lane]
    assert all(l.free_at <= c.finish_t for l in primary_lane)
    # fast traffic was never starved by the race
    assert all(not comps[i].hedged for i in range(1, 4))


def test_hedge_does_not_fire_without_an_idle_lane_or_under_prediction():
    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=3)
    stream = [Arrival(0.0, query=q, seed=5)]
    # single lane: nowhere to hedge, the straggler just runs long
    mgr = RecoveryManager(injector=ScriptedFaults(slow={(0, 1): 40.0}),
                          hedge=HedgePolicy(factor=3.0,
                                            predictor=_TinyPredictor()))
    comps, _ = _serve(agent, stream, n_lanes=1, recovery=mgr)
    assert mgr.stats.n_hedges == 0 and not comps[0].hedged
    # two lanes but an accurate (large) prediction: no overrun observed
    class Honest:
        def predict_query(self, query):
            return 1e4
    mgr = RecoveryManager(injector=ScriptedFaults(slow={(0, 1): 40.0}),
                          hedge=HedgePolicy(factor=3.0, predictor=Honest()))
    comps, _ = _serve(agent, stream, n_lanes=2, recovery=mgr)
    assert mgr.stats.n_hedges == 0


# ---------------------------------------------------------------- breaker
def test_breaker_trips_on_post_swap_failures_and_restores_incumbent(
        tmp_path):
    import jax
    from repro.learn.policy_store import PolicyStore

    qs = [fast_query(i) for i in range(6)]
    from repro.sql.workloads import Workload
    from repro.core.encoding import WorkloadMeta
    from repro.core.agent import AgentConfig, AqoraAgent
    wl = Workload(name="brk", max_tables=3, train=qs, test=[])
    agent = AqoraAgent(WorkloadMeta.from_workload(wl),
                       AgentConfig(max_steps=2), seed=0)

    store = PolicyStore(tmp_path / "ps", probe=[], mode="gate")
    store.commit(agent, 1)
    incumbent = jax.tree_util.tree_map(np.array, agent.actor)

    # post-swap sabotage: every query admitted after the swap dies on
    # every stage -> failure-rate spike causally follows the swap
    n = 16
    faults = ScriptedFaults(stage={(s, 1, k): "transient"
                                   for s in range(8, n) for k in range(6)})
    brk = PolicyBreaker(store, agent, window=8, min_post=4, cooldown=5)
    mgr = RecoveryManager(injector=faults, breaker=brk)
    db, est = _world()
    sched = LaneScheduler(db, est, agent, n_lanes=1, recovery=mgr)

    def swapper(comp):
        if comp.seq == 7 and store.serving_step == 1:
            agent.actor = jax.tree_util.tree_map(lambda x: x + 1.0,
                                                 agent.actor)
            store.commit(agent, 2)
    sched.on_complete.insert(0, swapper)

    stream = [Arrival(0.3 * i, query=qs[i % 6], seed=i + 1)
              for i in range(n)]
    comps = sched.run(stream)
    assert len(comps) == n
    assert len(brk.trips) == 1
    seq, bad_step, restored, reason = brk.trips[0]
    assert (bad_step, restored) == (2, 1) and "failure rate" in reason
    assert store.serving_step == 1
    # the incumbent's parameters are restored EXACTLY (not approximately)
    same = jax.tree_util.tree_map(
        lambda a, b: bool(np.array_equal(np.array(a), np.array(b))),
        incumbent, agent.actor)
    assert all(jax.tree_util.tree_leaves(same))
    # cooldown held the store in shadow mode, then restored gate mode
    assert store.mode == "gate"


def test_breaker_stays_quiet_without_a_regression(tmp_path):
    from repro.core.agent import AgentConfig, AqoraAgent
    from repro.core.encoding import WorkloadMeta
    from repro.learn.policy_store import PolicyStore
    from repro.sql.workloads import Workload

    wl = Workload(name="quiet", max_tables=3,
                  train=[fast_query(i) for i in range(4)], test=[])
    agent = AqoraAgent(WorkloadMeta.from_workload(wl),
                       AgentConfig(max_steps=2), seed=0)
    store = PolicyStore(tmp_path / "ps", probe=[], mode="gate")
    store.commit(agent, 1)
    brk = PolicyBreaker(store, agent, window=8, min_post=4)
    mgr = RecoveryManager(breaker=brk)
    stream = [Arrival(0.2 * i, query=fast_query(i % 4), seed=i + 1)
              for i in range(10)]
    comps, _ = _serve(agent, stream, recovery=mgr, n_lanes=2)
    assert len(comps) == 10 and not brk.trips
    assert store.serving_step == 1 and store.mode == "gate"


# ------------------------------------------------------- service + learn
def test_service_stats_carry_the_failure_breakdown():
    from repro.learn.harvest import TrajectoryHarvester
    from repro.serve.service import QueryService

    q = mi_join_query()
    agent = noop_agent_for(q, *[fast_query(i) for i in range(4)],
                           max_steps=2)
    db, est = _world()
    # seq 0 recovers after one transient; seq 2 crashes on every attempt
    # and gives up at max_attempts=3
    faults = ScriptedFaults(stage={(0, 1, 4): "transient", (2, 1, 0): "crash",
                                   (2, 2, 0): "crash", (2, 3, 0): "crash"})
    mgr = RecoveryManager(injector=faults,
                          retry=RetryPolicy(max_attempts=3, backoff=0.0))
    harv = TrajectoryHarvester()
    svc = QueryService(db, agent, est=est, n_lanes=2, recovery=mgr,
                       hooks=[harv])
    stream = [Arrival(0.0, query=q, seed=5)] + \
        [Arrival(0.05 * i, query=fast_query(i % 4), seed=i + 1)
         for i in range(1, 5)]
    comps, stats = svc.run(stream)

    assert stats.n_completed == 5
    assert stats.n_recovered == 1          # seq 0: transient, resumed
    assert stats.n_retried == 2            # seqs 0 and 2
    assert stats.attempts_total == 5 + 1 + 2
    assert stats.failure_kinds == {"crash": 1}   # seq 2 gave up
    assert stats.n_failed == 1

    # the harvester sees each retried query ONCE — never duplicated
    assert harv.n_seen == 5
    assert len({e.seq for e in harv.replay.all()}) == \
        len(harv.replay.all())


def test_replay_experience_is_tagged_not_duplicated():
    from repro.learn.harvest import TrajectoryHarvester
    from repro.learn.replay import ReplayBuffer

    q = mi_join_query()
    agent = noop_agent_for(q, max_steps=2)
    faults = ScriptedFaults(stage={(0, 1, 4): "transient"})
    mgr = RecoveryManager(injector=faults,
                          retry=RetryPolicy(max_attempts=2, backoff=0.0))
    db, est = _world()
    sched = LaneScheduler(db, est, agent, n_lanes=1, recovery=mgr)
    rb = ReplayBuffer()
    harv = TrajectoryHarvester(rb)
    harv.attach(sched)
    comps = sched.run([Arrival(0.0, query=q, seed=5)])
    assert comps[0].attempts == 2 and comps[0].recovered
    assert harv.n_seen == 1                # ONE completion for the query
    if harv.n_harvested:                   # non-empty traj -> buffered once
        exps = rb.all()
        assert len(exps) == 1 and exps[0].attempts == 2
        assert exps[0].recovered and harv.n_retried == 1
