"""Sharding-rule invariants: every produced PartitionSpec divides its dim
over the assigned mesh axis, for every architecture x both meshes; batch
and cache rules; activation-policy no-op behaviour."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch import steps as steps_mod
from repro.sharding import MeshAxes, act, batch_specs, cache_specs, param_specs

AX_SINGLE = MeshAxes(sizes=(("data", 16), ("model", 16)))
AX_MULTI = MeshAxes(pod="pod", sizes=(("pod", 2), ("data", 16), ("model", 16)))


def _axis_size(axes, name):
    if isinstance(name, tuple):
        return int(np.prod([axes.size(a) for a in name]))
    return axes.size(name)


def _check(tree_sds, spec_tree, axes):
    leaves_s = jax.tree_util.tree_leaves(tree_sds)
    specs = jax.tree_util.tree_leaves(spec_tree,
                                      is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_s) == len(specs)
    for sds, spec in zip(leaves_s, specs):
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            n = _axis_size(axes, ax)
            assert sds.shape[d] % n == 0, (sds.shape, spec, d, ax)
            # never shard across "pod" for parameters (checked by caller
            # passing the right axes)


@pytest.mark.parametrize("arch", registry.ARCHS)
@pytest.mark.parametrize("axes", [AX_SINGLE, AX_MULTI], ids=["single", "multi"])
def test_param_specs_divisible(arch, axes):
    cfg = registry.get_config(arch)
    p = steps_mod.params_struct(cfg)
    specs = param_specs(p, axes)
    _check(p, specs, axes)
    # params never use the pod axis (pure-DP across pods)
    for spec in jax.tree_util.tree_leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P)):
        assert "pod" not in [a for a in spec if isinstance(a, str)]


@pytest.mark.parametrize("arch", ["qwen3-8b", "jamba-1.5-large-398b",
                                  "whisper-tiny", "minicpm3-4b"])
@pytest.mark.parametrize("shape", list(SHAPES))
@pytest.mark.parametrize("axes", [AX_SINGLE, AX_MULTI], ids=["single", "multi"])
def test_batch_and_cache_specs_divisible(arch, shape, axes):
    cfg = registry.get_config(arch)
    sh = SHAPES[shape]
    b = steps_mod.batch_struct(cfg, sh)
    _check(b, batch_specs(b, axes), axes)
    if sh.kind == "decode":
        c = steps_mod.cache_struct(cfg, sh)
        _check(c, cache_specs(c, axes), axes)


def test_stack_axis_never_sharded():
    cfg = registry.get_config("qwen3-8b")
    p = steps_mod.params_struct(cfg)
    specs = param_specs(p, AX_SINGLE)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    for path, spec in flat:
        keys = [str(getattr(k, "key", "")) for k in path]
        if keys and keys[0] == "stack":
            assert spec[0] is None, (keys, spec)


def test_long500k_batch1_falls_back_to_seq_sharding():
    cfg = registry.get_config("jamba-1.5-large-398b")
    sh = SHAPES["long_500k"]
    c = steps_mod.cache_struct(cfg, sh)
    specs = cache_specs(c, AX_SINGLE)
    flat = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    # at least one KV cache tensor must be sequence-sharded over data
    assert any("data" in [a for a in spec if isinstance(a, str)]
               for spec in flat)


def test_constrain_noop_without_policy():
    x = jax.numpy.ones((8, 4))
    assert act.constrain(x, {0: "dp"}) is x


def test_constrain_skips_indivisible_dims():
    pol = act.ActivationPolicy(dp_axes=("data",), dp_size=16, tp_size=16)
    x = jax.numpy.ones((6, 4))           # 6 % 16 != 0
    with act.policy(pol):
        y = act.constrain(x, {0: "dp", 1: "tp"})
    assert y.shape == x.shape            # no crash; constraint skipped
