import numpy as np
import pytest


@pytest.fixture(scope="session")
def job_db():
    from repro.sql import datagen
    return datagen.make_job_like(scale=0.12, seed=0)


@pytest.fixture(scope="session")
def job_workload():
    from repro.sql import workloads
    return workloads.make_workload("job", n_train=24, n_test_per_template=1,
                                   seed=7)


@pytest.fixture(scope="session")
def stack_db():
    from repro.sql import datagen
    return datagen.make_stack_like(scale=0.12, seed=1)


@pytest.fixture(scope="session")
def estimator(job_db):
    from repro.sql.cbo import Estimator
    return Estimator(job_db, job_db.stats)


@pytest.fixture(scope="session")
def agent(job_workload):
    """The shared cold serving agent (seed 0) the serving-stack suites
    (test_serve/test_qos/test_drift) decide with; session-scoped so its
    jit cache warms once."""
    from scenarios import make_agent
    return make_agent(job_workload, seed=0)
